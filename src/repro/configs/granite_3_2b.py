"""granite-3-2b [dense] — GQA.

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base]
"""
from repro.configs.base import ModelConfig, scale_down

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_head=64,
    d_ff=8192,
    vocab=49_155,
    attn_kind="gqa",
    layer_pattern=("attn",),
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke():
    return scale_down(CONFIG)
