"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512(expert) vocab=49155, MoE 32e top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig, MoEConfig, scale_down

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab=49_155,
    attn_kind="gqa",
    moe=MoEConfig(n_experts=32, top_k=8, n_shared=0, d_ff_expert=512,
                  score_fn="softmax", capacity_factor=1.25,
                  dispatch="einsum"),
    layer_pattern=("moe",),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def smoke():
    return scale_down(CONFIG)
