"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 + MTP.

61L d_model=7168 128H (GQA kv=128 → MLA) d_ff=2048(expert) vocab=129280,
MoE 256e top-8.  [arXiv:2412.19437; hf]
First 3 layers use dense FFN (d_ff_dense=18432 per the release); MoE layers
use 2048-wide experts with 1 shared expert.  Scoring: sigmoid + aux-loss-free
bias; MTP depth 1.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, scale_down

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=18432,                      # dense layers (first 3)
    vocab=129_280,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, d_ff_expert=2048,
                  score_fn="sigmoid", aux_free_bias=True,
                  capacity_factor=1.25, dispatch="einsum", n_dense_layers=3),
    prefix_pattern=("attn",) * 3,
    layer_pattern=("moe",),
    mtp_depth=1,
    rope_theta=10_000.0,
    source="arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3",
)


def smoke():
    return scale_down(CONFIG, prefix_pattern=("attn",), n_layers=3)
