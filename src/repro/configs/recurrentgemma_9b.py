"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec.

38L d_model=4096 16H (GQA kv=1 → MQA) d_ff=12288 vocab=256000, window 2048.
[arXiv:2402.19427 (Griffin); hf:google/recurrentgemma-9b]
Pattern: (rec, rec, attn_local) repeating, starting with two recurrent
blocks — 38 = 2 + 12·3.
"""
from repro.configs.base import ModelConfig, scale_down

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,                      # MQA
    d_head=256,
    d_ff=12_288,
    vocab=256_000,
    attn_kind="gqa",
    window=2048,
    prefix_pattern=("rec", "rec"),
    layer_pattern=("attn_local", "rec", "rec"),
    activation="gelu",
    source="arXiv:2402.19427; hf:google/recurrentgemma-9b",
)


def smoke():
    return scale_down(CONFIG, n_kv_heads=1, prefix_pattern=("rec", "rec"))
