"""Model / run configuration dataclasses shared by all architectures.

Each assigned architecture file (``src/repro/configs/<id>.py``) exports:

* ``CONFIG``  — the exact published configuration,
* ``smoke()`` — a reduced same-family config for CPU smoke tests,
* (shapes come from :data:`SHAPES`, shared by all LM archs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "MLAConfig",
           "SparsityConfig", "ShapeConfig", "SHAPES", "scale_down"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0                  # shared (always-on) experts
    d_ff_expert: int = 0               # per-expert hidden dim
    score_fn: str = "softmax"          # softmax | sigmoid (DeepSeek-V3)
    aux_free_bias: bool = False        # DeepSeek-V3 aux-loss-free balancing
    capacity_factor: float = 1.25
    dispatch: str = "einsum"           # einsum (GShard baseline) | scatter (optimized)
    n_dense_layers: int = 0            # leading dense-FFN layers (DeepSeek-V3: 3)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256                   # SSD chunk length


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 0               # 0 = full-rank q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """The paper's technique as a first-class feature: store selected weight
    matrices in RgCSR (pruned) and run SpMM through the Pallas kernel."""
    enabled: bool = False
    format: str = "rgcsr"
    density: float = 0.25              # kept fraction after magnitude pruning
    group_size: int = 128
    targets: Tuple[str, ...] = ("ffn",)  # which layer families to sparsify
    impl: str = "ref"                  # ref (jnp oracle, SPMD) | kernel (Pallas)

    def impl_is_kernel(self) -> bool:
        return self.impl == "kernel"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                    # 0 -> d_model // n_heads
    # --- attention ---
    attn_kind: str = "gqa"             # gqa | mla
    qkv_bias: bool = False             # Qwen1.5
    rope_theta: float = 10_000.0
    window: Optional[int] = None       # local-attention window
    # --- block pattern ---
    layer_pattern: Tuple[str, ...] = ("attn",)   # period, repeated
    prefix_pattern: Tuple[str, ...] = ()          # unrolled leading layers
    # --- ffn ---
    activation: str = "silu"           # silu | gelu | relu2 (Nemotron squared-ReLU)
    gated_ffn: bool = True             # SwiGLU/GeGLU vs plain MLP
    # --- submodule configs ---
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    mla: MLAConfig = MLAConfig()
    sparsity: SparsityConfig = SparsityConfig()
    # --- embeddings / output ---
    tie_embeddings: bool = True
    mtp_depth: int = 0                 # DeepSeek-V3 multi-token prediction modules
    # --- multimodal frontend stubs ---
    frontend: str = "none"             # none | vision | audio
    d_frontend: int = 0                # embedding dim delivered by the stub
    frontend_tokens: int = 0           # how many positions the stub fills (vlm)
    # --- enc-dec (seamless) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    # --- numerics / serving ---
    pad_vocab_to: int = 256            # Megatron-style: pad embedding rows so
                                       # the vocab dim shards evenly over any
                                       # mesh axis (logits past `vocab` are
                                       # masked to -inf in the loss/sampler)
    dtype: str = "bfloat16"            # compute dtype
    param_dtype: str = "float32"
    kv_cache_dtype: str = "bfloat16"   # int8 available (beyond-paper opt)
    long_context_fallback: str = "window"  # full-attn archs at 500k (DESIGN §9)
    fallback_window: int = 32_768
    remat: str = "none"                # none | full | dots  (set by trainer)
    # --- activation sharding (set by the launcher per mesh/cell) ---
    act_shard: bool = False            # emit with_sharding_constraint()s
    attn_shard_mode: str = "none"      # heads | repeat | seq | none
    shard_batch: bool = True           # batch dim divisible by batch axes?
    mesh_batch_axes: Tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    # --- notes for DESIGN/EXPERIMENTS provenance ---
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_to
        return -(-self.vocab // m) * m

    @property
    def pattern_repeats(self) -> int:
        body = self.n_layers - len(self.prefix_pattern)
        assert body % len(self.layer_pattern) == 0, (
            f"{self.name}: {body} body layers not divisible by pattern "
            f"{self.layer_pattern}")
        return body // len(self.layer_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state is O(1)/O(window) in sequence length."""
        kinds = set(self.layer_pattern) | set(self.prefix_pattern)
        return kinds <= {"ssm", "rec", "attn_local"}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode | long_decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}


def scale_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build a reduced same-family smoke config.

    Keeps the block pattern / attention kind / MoE-ness, shrinks widths.
    """
    period = len(cfg.layer_pattern)
    n_prefix = len(cfg.prefix_pattern)
    defaults = dict(
        n_layers=n_prefix + 2 * period,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_head=16,
        d_ff=128,
        vocab=512,
        window=min(cfg.window, 32) if cfg.window else None,
        fallback_window=64,
    )
    if cfg.moe.n_experts:
        defaults["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=32,
            n_dense_layers=min(cfg.moe.n_dense_layers, 1))
    if cfg.attn_kind == "mla":
        defaults["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                    qk_nope_head_dim=16, qk_rope_head_dim=8,
                                    v_head_dim=16)
    if "ssm" in cfg.layer_pattern:
        defaults["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                              chunk=16)
    if cfg.frontend != "none":
        defaults["d_frontend"] = 32
        defaults["frontend_tokens"] = min(cfg.frontend_tokens, 8)
    if cfg.enc_dec:
        defaults["n_enc_layers"] = 2
    defaults.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **defaults)
