"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-NeMo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409]
The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (d_vit=1024) for the first 1024 positions of
the sequence; the backbone projects and consumes them.
"""
from repro.configs.base import ModelConfig, scale_down

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14_336,
    vocab=131_072,
    attn_kind="gqa",
    layer_pattern=("attn",),
    frontend="vision",
    d_frontend=1024,
    frontend_tokens=1024,
    source="hf:mistralai/Pixtral-12B-2409",
)


def smoke():
    return scale_down(CONFIG)
