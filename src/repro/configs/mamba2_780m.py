"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128.  [arXiv:2405.21060]
d_inner = 2·d_model = 3072, head_dim 64 → 48 SSD heads.
"""
from repro.configs.base import ModelConfig, SSMConfig, scale_down

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,                        # attention-free; kept for API shape
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab=50_280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    layer_pattern=("ssm",),
    gated_ffn=False,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-780m",
)


def smoke():
    return scale_down(CONFIG, d_model=64, n_heads=1, n_kv_heads=1)
