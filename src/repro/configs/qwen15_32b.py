"""qwen1.5-32b [dense] — MHA with QKV bias.

64L d_model=5120 40H (kv=40, i.e. full MHA) d_ff=27392 vocab=152064.
[hf:Qwen/Qwen1.5-32B family; bias per Qwen1.5 reference config]
"""
from repro.configs.base import ModelConfig, scale_down

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_head=128,
    d_ff=27_392,
    vocab=152_064,
    attn_kind="gqa",
    qkv_bias=True,
    layer_pattern=("attn",),
    source="hf:Qwen/Qwen1.5-32B",
)


def smoke():
    return scale_down(CONFIG, n_heads=4, n_kv_heads=4)
