"""nemotron-4-15b [dense] — GQA, squared-ReLU, untied embeddings.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.  [arXiv:2402.16819]
Squared-ReLU MLP (no gating), RoPE.
"""
from repro.configs.base import ModelConfig, scale_down

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=24_576,
    vocab=256_000,
    attn_kind="gqa",
    activation="relu2",
    gated_ffn=False,
    tie_embeddings=False,
    layer_pattern=("attn",),
    source="arXiv:2402.16819",
)


def smoke():
    return scale_down(CONFIG)
