"""seamless-m4t-medium [audio] — encoder-decoder, multimodal (stub frontend).

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  [arXiv:2308.11596]
Encoder-decoder: 12 encoder + 12 decoder layers; the speech frontend is a
STUB — ``input_specs()`` provides precomputed frame embeddings (d=1024)
consumed by the encoder.
"""
from repro.configs.base import ModelConfig, scale_down

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                      # decoder layers
    n_enc_layers=12,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=256_206,
    attn_kind="gqa",
    activation="gelu",
    layer_pattern=("dec_attn",),
    frontend="audio",
    d_frontend=1024,
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-medium",
)


def smoke():
    return scale_down(CONFIG, n_layers=2, n_enc_layers=2)
