"""minicpm3-4b [dense] — MLA (MiniCPM3 uses DeepSeek-style latent attention).

62L d_model=2560 40H d_ff=6400 vocab=73448.  [hf:openbmb/MiniCPM3-4B]
MLA dims per release: q_lora 768, kv_lora 256, nope 64, rope 32, v 64.
"""
from repro.configs.base import MLAConfig, ModelConfig, scale_down

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=64,
    d_ff=6400,
    vocab=73_448,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    layer_pattern=("attn",),
    source="hf:openbmb/MiniCPM3-4B",
)


def smoke():
    return scale_down(CONFIG)
