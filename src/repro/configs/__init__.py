"""Architecture registry + per-(arch × shape) input specs.

``get_config(arch)`` returns the exact published config; ``get_smoke(arch)``
the reduced same-family smoke config.  ``input_specs(cfg, shape)`` returns
``jax.ShapeDtypeStruct`` stand-ins for every model input of that cell —
weak-type-correct, shardable, no device allocation (the dry-run path).
``concrete_inputs`` materializes small real batches for smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "mamba2-780m": "mamba2_780m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "granite-3-2b": "granite_3_2b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen1.5-32b": "qwen15_32b",
    "minicpm3-4b": "minicpm3_4b",
    "pixtral-12b": "pixtral_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

__all__ = ["ARCH_IDS", "SHAPES", "get_config", "get_smoke", "input_specs",
           "concrete_inputs"]


def _module(arch: str):
    if arch not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; options: {sorted(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{ARCH_IDS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str) -> Dict:
    """ShapeDtypeStruct inputs for one (arch × shape) cell.

    * train/prefill: the full token batch (+ frontend stubs).
    * decode/long_decode: the one-token step input; the KV cache is built
      separately (abstract) by the launcher via ``jax.eval_shape``.
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32

    if shape.kind in ("train", "prefill"):
        specs = {}
        if cfg.family == "vlm":
            ft = cfg.frontend_tokens
            specs["patch_embeds"] = jax.ShapeDtypeStruct((b, ft, cfg.d_frontend), f32)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - ft), i32)
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s - ft), i32)
        elif cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_frontend), f32)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs

    # decode / long_decode: one new token per sequence
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


def concrete_inputs(cfg: ModelConfig, *, batch: int, seq: int,
                    kind: str = "train", seed: int = 0) -> Dict:
    """Small real batches for smoke tests (numpy → device)."""
    rng = np.random.default_rng(seed)
    toks = lambda b, s: jnp.asarray(
        rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)
    out = {}
    if cfg.family == "vlm":
        ft = cfg.frontend_tokens
        assert seq > ft, f"seq {seq} must exceed frontend_tokens {ft}"
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, ft, cfg.d_frontend)), jnp.float32)
        out["tokens"] = toks(batch, seq - ft)
        if kind == "train":
            out["labels"] = toks(batch, seq - ft)
    elif cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_frontend)), jnp.float32)
        out["tokens"] = toks(batch, seq)
        if kind == "train":
            out["labels"] = toks(batch, seq)
    else:
        out["tokens"] = toks(batch, seq)
        if kind == "train":
            out["labels"] = toks(batch, seq)
    return out
