"""Typed metrics registry: counters, gauges, histograms (DESIGN.md §13.1).

The serving stack used to carry its counters as ad-hoc dicts — the
session's ``stats``, the router's ``counters``, and
``paging.merge_replica_stats``'s hand-rolled sum/max/first merge.  This
module gives those three shapes one model:

* a **metric** is a named cell with a merge semantic: :class:`Counter`
  (monotonic, merges by sum), :class:`Gauge` (level, merges by max), or
  :class:`Histogram` (sample distribution, merges by concatenation —
  percentiles come from the merged samples, never from averaged
  percentiles).  Labels (``registry.counter("faults", replica=1)``)
  distinguish children of one logical metric.
* a :class:`MetricsRegistry` owns the metrics and round-trips them
  through JSON (:meth:`~MetricsRegistry.snapshot` /
  :meth:`~MetricsRegistry.restore`) so cumulative counters survive the
  §7.6 crash-consistent snapshots with no resets or double counts.
* a :class:`StatsView` is a ``MutableMapping`` facade over a registry's
  scalar metrics — existing ``stats["preemptions"] += 1`` call sites and
  ``dict(stats)`` consumers keep working unchanged while the values live
  in typed cells.
* :func:`merge_stats` replaces the ad-hoc replica merge with a
  declarative spec: each key names its :class:`MergeRule` (sum / max /
  first / histogram-map, optional per-replica list, optional gate key),
  and ``paging.merge_replica_stats`` is now a spec application.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, MutableMapping, Optional, Sequence

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
           "MergeRule", "merge_stats", "percentile_summary",
           "timing_percentiles", "PERCENTILES"]

PERCENTILES = (50, 95, 99)


def _labels_key(labels) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in dict(labels).items()))


class Counter:
    """Monotonic scalar (events since birth).  Merge semantic: sum."""

    kind = "counter"

    def __init__(self, name: str, labels=()):
        self.name = name
        self.labels = dict(labels)
        self.value = 0

    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r}: negative inc {n}")
        self.value += n

    def state(self):
        return self.value

    def load(self, state) -> None:
        self.value = state


class Gauge(Counter):
    """Level (current/peak capacity figure).  Merge semantic: max."""

    kind = "gauge"

    def set(self, v) -> None:
        self.value = v

    def set_max(self, v) -> None:
        self.value = max(self.value, v)


class Histogram:
    """Sample distribution with exact percentiles over retained samples.

    Raw samples are retained up to ``MAX_SAMPLES`` (the serving mixes sit
    far below it); overflow keeps ``count``/``sum`` exact and counts the
    discarded samples in ``dropped`` so truncated percentiles are
    *visible*, never silent.
    """

    kind = "histogram"
    MAX_SAMPLES = 4096

    def __init__(self, name: str, labels=()):
        self.name = name
        self.labels = dict(labels)
        self.count = 0
        self.total = 0.0
        self.dropped = 0
        self.samples: List[float] = []

    def observe(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if len(self.samples) < self.MAX_SAMPLES:
            self.samples.append(v)
        else:
            self.dropped += 1

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples, float), q))

    def state(self) -> Dict:
        return {"count": self.count, "sum": self.total,
                "dropped": self.dropped, "samples": list(self.samples)}

    def load(self, state: Dict) -> None:
        self.count = int(state.get("count", 0))
        self.total = float(state.get("sum", 0.0))
        self.dropped = int(state.get("dropped", 0))
        self.samples = [float(v) for v in state.get("samples", ())]

    @staticmethod
    def merge_states(states: Sequence[Dict]) -> Dict:
        """Concatenate histogram states (cross-replica merge): counts and
        sums add; samples concatenate up to the cap, the excess lands in
        ``dropped``."""
        merged = {"count": 0, "sum": 0.0, "dropped": 0, "samples": []}
        for st in states:
            if not st:
                continue
            merged["count"] += int(st.get("count", 0))
            merged["sum"] += float(st.get("sum", 0.0))
            merged["dropped"] += int(st.get("dropped", 0))
            room = Histogram.MAX_SAMPLES - len(merged["samples"])
            samples = list(st.get("samples", ()))
            merged["samples"].extend(samples[:room])
            merged["dropped"] += max(0, len(samples) - room)
        return merged


def percentile_summary(state, qs: Sequence[int] = PERCENTILES) -> Dict:
    """``{"p50": ..., "p95": ..., "p99": ...}`` from a histogram (or its
    :meth:`Histogram.state` dict).  Empty histogram → ``{}``."""
    samples = state.get("samples", ()) if isinstance(state, dict) \
        else state.samples
    if not samples:
        return {}
    arr = np.asarray(samples, float)
    return {f"p{q}": round(float(np.percentile(arr, q)), 6) for q in qs}


def timing_percentiles(timing_map: Dict) -> Dict:
    """Per-metric percentile summaries for a ``{name: hist_state}`` map
    (the session's ``request_timing``), skipping empty histograms."""
    out = {}
    for name in sorted(timing_map):
        pcts = percentile_summary(timing_map[name])
        if pcts:
            out[name] = pcts
    return out


class StatsView(MutableMapping):
    """Dict-compatible facade over a registry's unlabeled scalar metrics.

    ``view[k] += 1`` increments the underlying cell; assigning to an
    unseen key creates it on the fly (counter by default, gauge when the
    key was declared in ``gauges``); ``dict(view)`` and iteration walk
    the cells in creation order.  This is what keeps every existing
    ``session.stats["x"] += 1`` / snapshot-restore assignment site
    working unchanged on top of the typed registry.
    """

    def __init__(self, registry: "MetricsRegistry", gauges=()):
        self._reg = registry
        self._gauges = set(gauges)
        self._cells: Dict[str, Counter] = {}

    def _cell(self, key: str) -> Counter:
        cell = self._cells.get(key)
        if cell is None:
            maker = self._reg.gauge if key in self._gauges \
                else self._reg.counter
            cell = maker(key)
            self._cells[key] = cell
        return cell

    def __getitem__(self, key: str):
        cell = self._cells.get(key)
        if cell is None:
            raise KeyError(key)
        return cell.value

    def __setitem__(self, key: str, value) -> None:
        self._cell(key).value = value

    def __delitem__(self, key: str) -> None:
        raise TypeError("stats keys cannot be deleted — metrics are "
                        "registered for the session's lifetime")

    def __iter__(self) -> Iterator[str]:
        return iter(self._cells)

    def __len__(self) -> int:
        return len(self._cells)


class MetricsRegistry:
    """Owner of one process component's metrics (a session, a router).

    ``counter/gauge/histogram(name, **labels)`` get-or-create the typed
    cell; re-registering a name under a different kind is an error.
    :meth:`snapshot` / :meth:`restore` round-trip every cell through a
    JSON-serializable dict (deterministically ordered), which is how the
    serving session's cumulative counters and latency histograms ride
    the §7.6 host-state snapshots.
    """

    _KINDS = None  # filled below

    def __init__(self):
        self._metrics: Dict[tuple, Counter] = {}

    def _get(self, cls, name: str, labels):
        key = (name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, dict(labels))
            self._metrics[key] = m
        elif not isinstance(m, cls) or m.kind != cls.kind:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def view(self, counters: Sequence[str] = (),
             gauges: Sequence[str] = ()) -> StatsView:
        """A :class:`StatsView` pre-seeded with zeroed cells for
        ``counters`` (sum-merged) and ``gauges`` (max-merged)."""
        view = StatsView(self, gauges=gauges)
        for key in list(counters) + list(gauges):
            view[key] = 0
        return view

    def snapshot(self) -> Dict:
        entries = []
        for (name, lk), m in sorted(self._metrics.items()):
            entry = {"name": name, "kind": m.kind, "state": m.state()}
            if lk:
                entry["labels"] = dict(lk)
            entries.append(entry)
        return {"version": 1, "metrics": entries}

    def restore(self, snap: Dict) -> None:
        for entry in snap.get("metrics", ()):
            cls = self._KINDS[entry["kind"]]
            m = self._get(cls, entry["name"], entry.get("labels", {}))
            m.load(entry["state"])

    def scalars(self) -> Dict[str, float]:
        """Flat ``{name: value}`` of every counter/gauge; labeled cells
        flatten as ``name{k=v,...}``."""
        out = {}
        for (name, lk), m in sorted(self._metrics.items()):
            if m.kind == "histogram":
                continue
            key = name if not lk else \
                name + "{" + ",".join(f"{k}={v}" for k, v in lk) + "}"
            out[key] = m.value
        return out


MetricsRegistry._KINDS = {"counter": Counter, "gauge": Gauge,
                          "histogram": Histogram}


# ---------------------------------------------------------------------------
# declarative cross-replica merge (the merge_replica_stats semantics)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MergeRule:
    """How one stats key aggregates across replica snapshots.

    ``kind``: ``"sum"`` (counters), ``"max"`` (gauges/high-waters),
    ``"first"`` (shared geometry/config — replicas agree by construction),
    ``"hist_map"`` (a ``{name: hist_state}`` map, merged per name by
    :meth:`Histogram.merge_states`).  ``list_as`` additionally emits the
    raw per-replica values under that key (skew visibility — a hot
    replica shows up as an outlier entry, not just a bigger aggregate).
    ``gate`` merges whenever *any* replica carries the gate key, even if
    this key is absent everywhere (missing entries contribute 0) — used
    for values that only exist alongside another metric family.
    """

    kind: str
    list_as: Optional[str] = None
    gate: Optional[str] = None


def merge_stats(per_replica: Sequence[Dict],
                spec: Dict[str, MergeRule]) -> Dict:
    """Apply a merge spec over per-replica stats dicts.

    Keys absent from every replica are omitted (unless gated in); keys
    outside the spec are dropped — the spec is the authoritative schema
    of the merged view."""
    merged: Dict = {}
    if not per_replica:
        return merged
    for key, rule in spec.items():
        if rule.gate is not None:
            if not any(rule.gate in s for s in per_replica):
                continue
        elif not any(key in s for s in per_replica):
            continue
        if rule.kind == "first":
            if key in per_replica[0]:
                merged[key] = per_replica[0][key]
        elif rule.kind == "sum":
            merged[key] = sum(s.get(key, 0) for s in per_replica)
        elif rule.kind == "max":
            merged[key] = max(s.get(key, 0) for s in per_replica)
        elif rule.kind == "hist_map":
            maps = [s.get(key) or {} for s in per_replica]
            names = sorted({n for m in maps for n in m})
            merged[key] = {
                n: Histogram.merge_states([m[n] for m in maps if n in m])
                for n in names}
        else:
            raise ValueError(f"unknown merge kind {rule.kind!r} for "
                             f"{key!r}")
        if rule.list_as is not None and rule.kind in ("sum", "max"):
            merged[rule.list_as] = [s.get(key, 0) for s in per_replica]
    return merged
