"""Unified observability layer (DESIGN.md §13).

Three pieces, deliberately dependency-free below the serving stack so
every layer (kernels, core timing, serve, train, launch) can import them:

* :mod:`repro.obs.metrics` — typed registry of counters / gauges /
  histograms with labels, a dict-compatible scalar view (the serving
  session's ``stats`` mapping is one), declarative cross-replica merge
  rules, and JSON snapshot/restore that rides the §7.6 host-state
  snapshots.
* :mod:`repro.obs.trace` — structured span/event recorder driven by the
  injectable engine clock, so traces are deterministic under FakeClock.
* :mod:`repro.obs.export` — Chrome trace-event JSON export (loadable in
  Perfetto / chrome://tracing; one track per replica, one lane per slot),
  schema validation, and the counter↔event cross-check the CI trace lane
  gates on.
"""
from repro.obs import export, metrics, trace  # noqa: F401

__all__ = ["metrics", "trace", "export"]
