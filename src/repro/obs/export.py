"""Chrome trace-event JSON export + validation (DESIGN.md §13.3).

:func:`chrome_trace` turns a :class:`~repro.obs.trace.Tracer`'s raw
events into the Chrome trace-event format (the JSON-object flavour with
a ``traceEvents`` array plus ``metadata``), loadable in Perfetto or
``chrome://tracing``.  Each distinct track process (replica, router)
becomes a pid with a ``process_name`` metadata record; each lane
(session, slot*k*, device) becomes a tid with a ``thread_name`` record —
so the timeline renders as one track per replica with per-slot lanes.

pids/tids are assigned by first appearance in the event stream, which is
itself deterministic under FakeClock, so
:func:`export_chrome_trace`'s canonical JSON (sorted keys, no
whitespace) is byte-identical across identical runs — the property the
determinism tests pin.

:func:`validate_chrome_trace` and :func:`cross_check_counters` are the
CI trace-lane gates: schema + monotonic-timestamps + balanced spans, and
"every counted migration/preemption/restore appears as a trace event on
the right replica track".
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["chrome_trace", "export_chrome_trace", "validate_chrome_trace",
           "cross_check_counters", "span_summary", "DEFAULT_COUNTER_EVENTS"]


def _events_of(source) -> List[Dict]:
    return list(source.events if hasattr(source, "events") else source)


def _close_abandoned(events: Sequence[Dict]) -> List[Dict]:
    """Synthesize closing events for spans still open at the end of the
    recording (a crash drill kills the process mid-request), so exported
    traces always balance.  Synthesized closers carry
    ``args.abandoned = true`` and the last seen timestamp."""
    open_sync: Dict[tuple, List[Dict]] = {}
    open_async: Dict[tuple, Dict] = {}
    last_ts = 0
    for ev in events:
        last_ts = max(last_ts, ev["ts"])
        ph = ev["ph"]
        if ph == "B":
            open_sync.setdefault(tuple(ev["track"]), []).append(ev)
        elif ph == "E":
            stack = open_sync.get(tuple(ev["track"]))
            if stack:
                stack.pop()
        elif ph == "b":
            open_async[(ev.get("cat"), ev.get("id"))] = ev
        elif ph == "e":
            open_async.pop((ev.get("cat"), ev.get("id")), None)
    closers: List[Dict] = []
    for track, stack in sorted(open_sync.items()):
        for ev in reversed(stack):
            closers.append({"ph": "E", "name": ev["name"], "ts": last_ts,
                            "track": track, "args": {"abandoned": True}})
    for (cat, uid), ev in sorted(open_async.items(),
                                 key=lambda kv: (kv[0][0] or "", kv[0][1])):
        closers.append({"ph": "e", "name": ev["name"], "ts": last_ts,
                        "track": tuple(ev["track"]), "cat": cat, "id": uid,
                        "args": {"abandoned": True}})
    return list(events) + closers


def chrome_trace(source, close_open: bool = True) -> Dict:
    """Build the Chrome trace-event document from a tracer (or a raw
    event list).  ``close_open`` finalizes abandoned spans (see
    :func:`_close_abandoned`) so crash-drill traces still validate."""
    events = _events_of(source)
    if close_open:
        events = _close_abandoned(events)

    pids: Dict[str, int] = {}
    tids: Dict[Tuple[str, str], int] = {}
    meta: List[Dict] = []
    body: List[Dict] = []
    for ev in events:
        proc, lane = ev["track"]
        pid = pids.get(proc)
        if pid is None:
            pid = pids[proc] = len(pids) + 1
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "ts": 0, "args": {"name": proc}})
        tid = tids.get((proc, lane))
        if tid is None:
            tid = tids[(proc, lane)] = \
                sum(1 for p, _ in tids if p == proc) + 1
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "ts": 0, "args": {"name": lane}})
        out: Dict = {"name": ev["name"], "ph": ev["ph"], "ts": ev["ts"],
                     "pid": pid, "tid": tid,
                     "cat": ev.get("cat", "serve")}
        if ev["ph"] == "i":
            out["s"] = "t"
        if ev["ph"] in ("b", "n", "e"):
            out["id"] = ev["id"]
        if "args" in ev:
            out["args"] = ev["args"]
        body.append(out)
    return {"traceEvents": meta + body, "displayTimeUnit": "ms",
            "metadata": {"format": "repro.obs chrome-trace", "version": 1}}


def export_chrome_trace(source, path: Optional[str] = None) -> str:
    """Canonical JSON text of the trace (sorted keys, compact separators
    — the byte-identical form the determinism tests compare); optionally
    written to ``path``."""
    doc = source if isinstance(source, dict) else chrome_trace(source)
    text = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    if path is not None:
        with open(path, "w") as f:
            f.write(text)
    return text


# ---------------------------------------------------------------------------
# validation (CI trace-export smoke lane)
# ---------------------------------------------------------------------------

_REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


def validate_chrome_trace(doc: Dict) -> List[str]:
    """Return a list of problems (empty == valid): required keys on every
    event, non-decreasing timestamps per (pid, tid) track, balanced and
    name-matched B/E duration stacks, and balanced async b/e pairs per
    (cat, id)."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]

    last_ts: Dict[tuple, int] = {}
    stacks: Dict[tuple, List[Dict]] = {}
    async_open: Dict[tuple, Dict] = {}
    for i, ev in enumerate(events):
        for k in _REQUIRED_KEYS:
            if k not in ev:
                problems.append(f"event {i}: missing key {k!r}")
        ph = ev.get("ph")
        if ph == "M":
            continue
        track = (ev.get("pid"), ev.get("tid"))
        ts = ev.get("ts", 0)
        if track in last_ts and ts < last_ts[track]:
            problems.append(
                f"event {i} ({ev.get('name')}): ts {ts} < {last_ts[track]} "
                f"on track pid={track[0]} tid={track[1]}")
        last_ts[track] = max(last_ts.get(track, 0), ts)
        if ph == "B":
            stacks.setdefault(track, []).append(ev)
        elif ph == "E":
            stack = stacks.get(track) or []
            if not stack:
                problems.append(
                    f"event {i}: E {ev.get('name')!r} with no open B on "
                    f"track pid={track[0]} tid={track[1]}")
            else:
                b = stack.pop()
                if b.get("name") != ev.get("name"):
                    problems.append(
                        f"event {i}: E {ev.get('name')!r} closes B "
                        f"{b.get('name')!r} (bad nesting)")
        elif ph == "b":
            key = (ev.get("cat"), ev.get("id"))
            if key in async_open:
                problems.append(f"event {i}: duplicate async begin {key}")
            async_open[key] = ev
        elif ph == "e":
            key = (ev.get("cat"), ev.get("id"))
            if key not in async_open:
                problems.append(f"event {i}: async end with no begin {key}")
            else:
                del async_open[key]
        elif ph == "n":
            key = (ev.get("cat"), ev.get("id"))
            if key not in async_open:
                problems.append(
                    f"event {i}: async instant outside lifeline {key}")
        elif ph in ("i", "C"):
            pass
        else:
            problems.append(f"event {i}: unknown phase {ph!r}")
    for track, stack in stacks.items():
        for ev in stack:
            problems.append(
                f"unclosed B {ev.get('name')!r} on track pid={track[0]} "
                f"tid={track[1]}")
    for key in async_open:
        problems.append(f"unclosed async lifeline {key}")
    return problems


# (stats counter key, trace event name) pairs the CI lane gates on:
# every counted occurrence must appear as exactly that many trace events.
DEFAULT_COUNTER_EVENTS = (
    ("migrations", "migrate"),
    ("preemptions", "preempt"),
    ("restores", "restore"),
    ("replica_faults", "replica_fault"),
    ("replica_restarts", "replica_restart"),
    ("shed", "shed"),
    ("timed_out", "deadline_expired"),
    ("pages_quarantined", "page_quarantine"),
)


def _process_names(doc: Dict) -> Dict[int, str]:
    return {ev["pid"]: ev["args"]["name"]
            for ev in doc.get("traceEvents", ())
            if ev.get("ph") == "M" and ev.get("name") == "process_name"}


def cross_check_counters(doc: Dict, stats: Dict,
                         checks=DEFAULT_COUNTER_EVENTS,
                         mode: str = "exact") -> List[str]:
    """Gate that the trace and the merged stats agree: for each (counter,
    event-name) pair with the counter present in ``stats``, the trace
    must contain exactly that many events of that name; and any event
    carrying an ``args.replica`` attribution must sit on the pid whose
    process_name is ``replica<r>``.

    ``mode="at_least"`` relaxes the count check to ``trace >= counter``:
    a crash drill restores counters from the last snapshot, so work done
    (and traced) after that snapshot rolls back in the stats but its
    events legitimately remain in the continuous trace."""
    if mode not in ("exact", "at_least"):
        raise ValueError(f"mode must be 'exact' or 'at_least', got {mode!r}")
    problems: List[str] = []
    names = _process_names(doc)
    by_name: Dict[str, int] = {}
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") == "M":
            continue
        point = (ev.get("args") or {}).get("point")
        key = point if point is not None else ev.get("name")
        by_name[key] = by_name.get(key, 0) + 1
        rep = (ev.get("args") or {}).get("replica")
        if rep is not None:
            proc = names.get(ev.get("pid"), "")
            if proc != f"replica{rep}":
                problems.append(
                    f"event {ev.get('name')!r} tagged replica={rep} sits "
                    f"on process {proc!r}")
    for counter, event_name in checks:
        if counter not in stats:
            continue
        want = int(stats[counter])
        got = by_name.get(event_name, 0)
        if (got < want) if mode == "at_least" else (got != want):
            problems.append(
                f"counter {counter}={want} but trace has {got} "
                f"{event_name!r} events" +
                (" (at_least mode)" if mode == "at_least" else ""))
    return problems


def span_summary(source) -> Dict:
    """Per-name span duration stats + instant counts for the launcher's
    drill report (works on a tracer or a chrome-trace doc)."""
    if isinstance(source, dict):
        events = [dict(ev, track=(ev.get("pid"), ev.get("tid")))
                  for ev in source.get("traceEvents", ())
                  if ev.get("ph") != "M"]
    else:
        events = _close_abandoned(_events_of(source))
    spans: Dict[str, List[float]] = {}
    instants: Dict[str, int] = {}
    stacks: Dict[tuple, List[Dict]] = {}
    for ev in events:
        track = tuple(ev["track"])
        ph = ev["ph"]
        if ph == "B":
            stacks.setdefault(track, []).append(ev)
        elif ph == "E":
            stack = stacks.get(track)
            if stack:
                b = stack.pop()
                spans.setdefault(b["name"], []).append(
                    (ev["ts"] - b["ts"]) / 1e6)
        elif ph in ("i", "n"):
            name = (ev.get("args") or {}).get("point") or ev["name"]
            instants[name] = instants.get(name, 0) + 1
    out_spans = {}
    for name in sorted(spans):
        ds = spans[name]
        out_spans[name] = {"n": len(ds),
                           "total_s": round(sum(ds), 6),
                           "mean_s": round(sum(ds) / len(ds), 6),
                           "max_s": round(max(ds), 6)}
    return {"spans": out_spans,
            "events": {k: instants[k] for k in sorted(instants)}}
