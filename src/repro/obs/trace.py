"""Structured span/event recorder for the serving stack (DESIGN.md §13.2).

A :class:`Tracer` records raw events — duration spans (``B``/``E``),
instants (``i``), counters (``C``), and async request lifelines
(``b``/``n``/``e``) — stamped with microsecond timestamps from an
*injectable* clock.  The engine passes its own ``Engine.clock``, so a
test that drives the engine with a FakeClock gets byte-identical traces
across runs: no wall-clock, no ``id()``-derived identifiers, no dict
ordering leaks.  Export to Chrome trace-event JSON lives in
:mod:`repro.obs.export`; this module only records.

Tracks are ``(process, thread)`` string pairs: one process per replica
(``replica0`` ...) plus ``router``, and within a replica one lane per
slot (``slot0`` ...) plus ``session`` for engine-level work and
``device`` for fused-loop dispatch marks.

Every emission site goes through a tracer attribute that defaults to the
module-level :data:`NOOP` (a :class:`NullTracer`), so the serving hot
path pays one attribute load + truthiness check when tracing is off.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

__all__ = ["NullTracer", "Tracer", "NOOP"]

Track = Tuple[str, str]


class NullTracer:
    """Disabled tracer: every method is a no-op.

    Emission sites are written as ``if tracer.enabled: tracer.begin(...)``
    or call methods directly; either way a NullTracer makes tracing-off
    runs behave exactly like the pre-observability code path.
    """

    enabled = False

    def begin(self, name, track, **args):
        pass

    def end(self, name, track, **args):
        pass

    def instant(self, name, track, **args):
        pass

    def counter(self, name, track, **values):
        pass

    def request_begin(self, req, track, **args):
        pass

    def request_point(self, req, name, track, **args):
        pass

    def request_end(self, req, track, **args):
        pass


NOOP = NullTracer()


class Tracer(NullTracer):
    """Event recorder with deterministic ids and injectable time.

    ``clock`` returns seconds (same contract as ``Engine.clock``);
    timestamps are recorded as integer microseconds.  Request lifelines
    use async events keyed by a tracer-assigned uid (a simple counter,
    stamped onto the request as ``_trace_uid``) — never ``id(req)``,
    which would differ between runs and break byte-identical exports.
    """

    enabled = True

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else _default_clock
        self.events: List[Dict] = []
        self._uids = itertools.count(1)
        self._open_async: set = set()

    # -- core emitters ----------------------------------------------------

    def _ts(self) -> int:
        return int(round(self.clock() * 1e6))

    def _emit(self, ph: str, name: str, track: Track, args=None,
              cat: Optional[str] = None, uid: Optional[int] = None) -> None:
        ev: Dict = {"ph": ph, "name": name, "ts": self._ts(),
                    "track": (str(track[0]), str(track[1]))}
        if args:
            ev["args"] = dict(args)
        if cat is not None:
            ev["cat"] = cat
        if uid is not None:
            ev["id"] = uid
        self.events.append(ev)

    def begin(self, name, track, **args):
        """Open a duration span on ``track`` (must nest: close in LIFO
        order with :meth:`end`)."""
        self._emit("B", name, track, args)

    def end(self, name, track, **args):
        self._emit("E", name, track, args)

    def instant(self, name, track, **args):
        """A point event (preemption, migration, quarantine, ...)."""
        self._emit("i", name, track, args)

    def counter(self, name, track, **values):
        """A sampled counter series (e.g. free pages over time)."""
        self._emit("C", name, track, {k: v for k, v in values.items()})

    # -- per-request lifelines (async events) -----------------------------

    def _uid(self, req) -> int:
        uid = getattr(req, "_trace_uid", None)
        if uid is None:
            uid = next(self._uids)
            try:
                req._trace_uid = uid
            except AttributeError:
                pass
        return uid

    def request_begin(self, req, track, **args):
        """Open the request's async lifeline (idempotent: a request that
        passes through ``Router.submit`` and then ``session.submit`` only
        opens once)."""
        uid = self._uid(req)
        if uid in self._open_async:
            return
        self._open_async.add(uid)
        self._emit("b", "request", track, args, cat="request", uid=uid)

    def request_point(self, req, name, track, **args):
        uid = self._uid(req)
        if uid not in self._open_async:
            return
        args = dict(args)
        args["point"] = name
        self._emit("n", "request", track, args, cat="request", uid=uid)

    def request_end(self, req, track, **args):
        uid = self._uid(req)
        if uid not in self._open_async:
            return
        self._open_async.discard(uid)
        self._emit("e", "request", track, args, cat="request", uid=uid)


def _default_clock() -> float:
    import time

    return time.time()
