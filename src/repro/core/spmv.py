"""Pure-jnp SpMV / SpMM reference implementations for every format.

These are the *oracles*: jit-compatible, vectorized, numerically identical to
``A @ x`` up to floating-point reassociation.  The Pallas kernels in
:mod:`repro.kernels` are validated against these; higher layers (SparseLinear,
the benchmark harness) dispatch here on CPU and to the kernels on TPU.

The CSR path mirrors the paper's "scalar CSR" only in semantics — a data-
parallel segment-sum, since a literal one-thread-per-row walk has no TPU
analogue (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Union

import jax
import jax.numpy as jnp

from repro.core.formats import (
    COO,
    CSR,
    ELLPACK,
    BlockedCSR,
    HybridEllCoo,
    RgCSR,
    ShardedRgCSR,
    SlicedEllpack,
)

Matrix = Union[CSR, COO, ELLPACK, HybridEllCoo, BlockedCSR, RgCSR,
               SlicedEllpack, ShardedRgCSR]

__all__ = ["spmv", "spmm"]


def _segment_matvec(values, columns, row_ids, x, n_rows):
    """y[r] = sum_{i: row_ids[i]==r} values[i] * x[columns[i]]."""
    prods = values * jnp.take(x, columns, axis=0)
    return jax.ops.segment_sum(prods, row_ids, num_segments=n_rows)


def _segment_matmat(values, columns, row_ids, x, n_rows):
    """Y[r, :] = sum values[i] * X[columns[i], :]."""
    gathered = jnp.take(x, columns, axis=0)            # (nnz, d)
    prods = gathered * values[:, None]
    return jax.ops.segment_sum(prods, row_ids, num_segments=n_rows)


# ---------------------------------------------------------------------------
# per-format spmv
# ---------------------------------------------------------------------------


def spmv_csr(a: CSR, x):
    return _segment_matvec(a.values, a.columns, a.row_ids, x, a.shape[0])


def spmv_coo(a: COO, x):
    return _segment_matvec(a.values, a.columns, a.rows, x, a.shape[0])


def spmv_ellpack(a: ELLPACK, x):
    # slot-major: y = sum_k values[k, :] * x[columns[k, :]]
    gathered = jnp.take(x, a.columns, axis=0)           # (K, N)
    y = jnp.sum(a.values * gathered, axis=0)
    return y[: a.shape[0]]


def spmv_hybrid(a: HybridEllCoo, x):
    gathered = jnp.take(x, a.ell_columns, axis=0)
    y = jnp.sum(a.ell_values * gathered, axis=0)[: a.shape[0]]
    if a.coo_values.shape[0]:
        y = y + _segment_matvec(a.coo_values, a.coo_columns, a.coo_rows, x,
                                a.shape[0])
    return y


def spmv_blocked_csr(a: BlockedCSR, x):
    bs = a.block_size
    n_cols_pad = (-a.shape[1]) % bs
    xp = jnp.pad(x, (0, n_cols_pad))
    xb = xp.reshape(-1, bs)                              # (n_block_cols, bs)
    gathered = jnp.take(xb, a.block_columns, axis=0)     # (n_blocks, bs)
    prods = jnp.einsum("bij,bj->bi", a.values, gathered)  # (n_blocks, bs)
    nbr = a.block_row_pointers.shape[0] - 1
    yb = jax.ops.segment_sum(prods, a.block_row_ids, num_segments=nbr)
    return yb.reshape(-1)[: a.shape[0]]


def spmv_rgcsr(a: RgCSR, x):
    """Slot-major grouped SpMV.  Padding values are exact zeros, so summing
    them is a no-op — semantically identical to the paper's rowLengths
    early-exit (which saves *work*, not correctness).  The Pallas kernel
    realizes the actual work-skip via its chunk table."""
    return _segment_matvec(a.values, a.columns, a.row_of_element, x, a.shape[0])


def spmv_sliced_ellpack(a: SlicedEllpack, x):
    return _segment_matvec(a.values, a.columns, a.row_of_element, x, a.shape[0])


# ---------------------------------------------------------------------------
# per-format spmm (A @ X, X dense (n, d)) — needed by SparseLinear
# ---------------------------------------------------------------------------


def spmm_csr(a: CSR, x):
    return _segment_matmat(a.values, a.columns, a.row_ids, x, a.shape[0])


def spmm_coo(a: COO, x):
    return _segment_matmat(a.values, a.columns, a.rows, x, a.shape[0])


def spmm_ellpack(a: ELLPACK, x):
    gathered = jnp.take(x, a.columns, axis=0)            # (K, N, d)
    y = jnp.sum(a.values[..., None] * gathered, axis=0)
    return y[: a.shape[0]]


def spmm_hybrid(a: HybridEllCoo, x):
    gathered = jnp.take(x, a.ell_columns, axis=0)
    y = jnp.sum(a.ell_values[..., None] * gathered, axis=0)[: a.shape[0]]
    if a.coo_values.shape[0]:
        y = y + _segment_matmat(a.coo_values, a.coo_columns, a.coo_rows, x,
                                a.shape[0])
    return y


def spmm_blocked_csr(a: BlockedCSR, x):
    bs = a.block_size
    d = x.shape[1]
    n_cols_pad = (-a.shape[1]) % bs
    xp = jnp.pad(x, ((0, n_cols_pad), (0, 0)))
    xb = xp.reshape(-1, bs, d)
    gathered = jnp.take(xb, a.block_columns, axis=0)     # (n_blocks, bs, d)
    prods = jnp.einsum("bij,bjd->bid", a.values, gathered)
    nbr = a.block_row_pointers.shape[0] - 1
    yb = jax.ops.segment_sum(prods, a.block_row_ids, num_segments=nbr)
    return yb.reshape(-1, d)[: a.shape[0]]


def spmm_rgcsr(a: RgCSR, x):
    return _segment_matmat(a.values, a.columns, a.row_of_element, x, a.shape[0])


def spmm_sliced_ellpack(a: SlicedEllpack, x):
    return _segment_matmat(a.values, a.columns, a.row_of_element, x, a.shape[0])


_SPMV = {
    CSR: spmv_csr,
    COO: spmv_coo,
    ELLPACK: spmv_ellpack,
    HybridEllCoo: spmv_hybrid,
    BlockedCSR: spmv_blocked_csr,
    RgCSR: spmv_rgcsr,
    SlicedEllpack: spmv_sliced_ellpack,
}

_SPMM = {
    CSR: spmm_csr,
    COO: spmm_coo,
    ELLPACK: spmm_ellpack,
    HybridEllCoo: spmm_hybrid,
    BlockedCSR: spmm_blocked_csr,
    RgCSR: spmm_rgcsr,
    SlicedEllpack: spmm_sliced_ellpack,
}


@functools.partial(jax.jit, static_argnames=())
def _identity(x):
    return x


def _use_kernel(a, impl: str) -> bool:
    """Kernel dispatch policy.

    ``impl='ref'`` — always the jnp oracle.  ``impl='kernel'`` — the Pallas
    kernel via the process-wide PlanCache (interpret mode on CPU).
    ``impl='auto'`` — kernel on TPU, oracle elsewhere.  Kernel dispatch is
    host-side (plans index host metadata), so it requires concrete arrays:
    under jit tracing auto/kernel fall back to the oracle, which XLA shards
    and fuses like any segment-sum.
    """
    if impl not in ("auto", "ref", "kernel"):   # validate unconditionally,
        raise ValueError(                        # even on oracle-only paths
            f"unknown impl {impl!r}; options: auto/ref/kernel")
    if impl == "ref" or not isinstance(a, RgCSR):
        return False
    if isinstance(a.values, jax.core.Tracer):
        return False
    if impl == "kernel":
        return True      # explicit request: let make_plan raise if unrunnable
    # auto: only matrices the TPU kernel can actually run (group_size a
    # multiple of 128 lanes, slots sublane-packed); others — e.g. the small
    # modeled group sizes the format tests sweep — stay on the oracle
    # instead of crashing in make_plan.
    return (jax.default_backend() == "tpu"
            and a.group_size % 128 == 0 and a.slot_pad % 8 == 0)


def _sharded_dispatch(a: ShardedRgCSR, mesh, mesh_axis,
                      chunks_per_step, ordering, spill_threshold, x_mode,
                      shard_configs=None):
    """Resolve the sharded plan + mesh axis for a ShardedRgCSR call."""
    from repro.kernels import ops as kops
    if mesh is None:
        raise ValueError(
            "ShardedRgCSR spmv/spmm needs mesh= (and usually mesh_axis=): "
            "the row shards execute under shard_map over a 1-D mesh axis "
            "(DESIGN.md §11)")
    if mesh_axis is None:
        from repro.sharding import resolve_spmv_shard_axis
        mesh_axis = resolve_spmv_shard_axis(mesh)
    plan = kops.get_sharded_plan(a, chunks_per_step=chunks_per_step,
                                 ordering=ordering,
                                 spill_threshold=spill_threshold,
                                 x_mode=x_mode, shard_configs=shard_configs)
    return plan, mesh_axis


def spmv(a: Matrix, x, *, impl: str = "auto", chunks_per_step: int = 1,
         ordering: str = "block", spill_threshold: int = 0,
         mesh=None, mesh_axis: str | None = None,
         x_mode: str = "replicated", shard_configs=None):
    """``y = A @ x`` for any of the paper's formats.

    RgCSR matrices can dispatch to the Pallas kernel through the process-wide
    :data:`repro.kernels.ops.PLAN_CACHE` (see ``impl`` in :func:`_use_kernel`)
    so repeated SpMV on the same matrix — the serving / iterative-solver
    pattern — builds its host-side execution plan exactly once.

    ``ordering='adaptive'`` selects the length-aware regrouped plan (and,
    with ``spill_threshold > 0``, the pathological-row COO spill); results
    are identical up to fp reassociation — the plan's fused inverse gather
    restores the original row order.  Oracle paths ignore both knobs.

    :class:`ShardedRgCSR` matrices run the multi-device shard_map path
    (DESIGN.md §11/§12): ``mesh`` is required, ``mesh_axis`` defaults to
    the partitioner's ``sparse_rows`` rule, ``x_mode`` picks replicated-x
    vs the local/remote split with its plan-driven sparse exchange, and
    ``shard_configs`` (one ``(chunks_per_step, ordering, spill_threshold)``
    per shard — e.g. the per-shard autotune winners) overrides the global
    schedule knobs shard-by-shard.
    """
    if isinstance(a, ShardedRgCSR):
        from repro.kernels import ops as kops
        plan, axis = _sharded_dispatch(a, mesh, mesh_axis, chunks_per_step,
                                       ordering, spill_threshold, x_mode,
                                       shard_configs)
        return kops.sharded_rgcsr_spmv(plan, x, mesh=mesh, axis=axis)
    if _use_kernel(a, impl):
        from repro.kernels import ops as kops
        plan = kops.get_plan(a, chunks_per_step=chunks_per_step,
                             ordering=ordering,
                             spill_threshold=spill_threshold)
        return kops.rgcsr_spmv(plan, x)
    return _SPMV[type(a)](a, x)


def spmm(a: Matrix, x, *, impl: str = "auto", chunks_per_step: int = 1,
         ordering: str = "block", spill_threshold: int = 0,
         mesh=None, mesh_axis: str | None = None,
         x_mode: str = "replicated", shard_configs=None):
    """``Y = A @ X`` (X dense ``(n, d)``) for any of the paper's formats.

    Same PlanCache-backed kernel dispatch (and adaptive-plan / sharded
    knobs, including per-shard ``shard_configs``) as :func:`spmv`.
    """
    if isinstance(a, ShardedRgCSR):
        from repro.kernels import ops as kops
        plan, axis = _sharded_dispatch(a, mesh, mesh_axis, chunks_per_step,
                                       ordering, spill_threshold, x_mode,
                                       shard_configs)
        return kops.sharded_rgcsr_spmm(plan, x, mesh=mesh, axis=axis)
    if _use_kernel(a, impl):
        from repro.kernels import ops as kops
        plan = kops.get_plan(a, chunks_per_step=chunks_per_step,
                             ordering=ordering,
                             spill_threshold=spill_threshold)
        return kops.rgcsr_spmm(plan, x)
    return _SPMM[type(a)](a, x)
