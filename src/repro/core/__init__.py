"""Core: the paper's contribution — sparse formats + SpMV/SpMM + analytics."""
from repro.core.formats import (  # noqa: F401
    COO,
    CSR,
    ELLPACK,
    FORMATS,
    BlockedCSR,
    HybridEllCoo,
    RgCSR,
    ShardedRgCSR,
    SlicedEllpack,
    from_dense,
)
from repro.core.spmv import spmv, spmm  # noqa: F401
