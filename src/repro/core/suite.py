"""Synthetic sparse-matrix corpus emulating the paper's 1,596-matrix sets.

The paper draws from the UF collection + NEP collection [12, 13] and splits
into "small" (< 10,000 rows) and "large" (>= 10,000) sets (Table 2).  Offline
we generate structurally equivalent families:

* ``stencil``      — multi-diagonal FD/FEM stencils (3/5/9/27-point): the
                     well-structured case where every format does well.
* ``fem2d``        — 2-D 5-point Laplacian on an nx×ny grid (fd18-like).
* ``powerlaw``     — Zipf row degrees (graph-mining-like; moderate variance).
* ``uniform``      — iid Bernoulli sparsity.
* ``circuit``      — near-diagonal + a few (almost) dense rows:
                     IBM_EDA/trans4- and Rajat/Raj1-like, the RgCSR
                     pathological case (row-length variance → huge fill).
* ``blockrand``    — random bs×bs dense blocks (favours BlockedCSR).
* ``banded``       — random band matrices.

Every generator is deterministic given its seed.  ``paper_twins()`` returns
synthetic stand-ins whose (rows, nnz/row max/mean/min) match the paper's
Table 6 characterization to within sampling noise, scaled down by
``scale`` for CPU runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Tuple

import numpy as np

__all__ = ["MatrixSpec", "generate", "corpus", "small_corpus", "paper_twins"]


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    family: str
    n: int
    seed: int
    params: Tuple[Tuple[str, float], ...] = ()

    def build(self) -> np.ndarray:
        return generate(self.family, self.n, seed=self.seed,
                        **dict(self.params))


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def _stencil(n: int, seed: int, points: int = 5) -> np.ndarray:
    """Multi-diagonal stencil matrix (paper §1: the 'simple' structured case)."""
    offsets = {
        3: [-1, 0, 1],
        5: [-int(np.sqrt(n)), -1, 0, 1, int(np.sqrt(n))],
        9: [-int(np.sqrt(n)) - 1, -int(np.sqrt(n)), -int(np.sqrt(n)) + 1,
            -1, 0, 1,
            int(np.sqrt(n)) - 1, int(np.sqrt(n)), int(np.sqrt(n)) + 1],
        27: list(range(-13, 14)),
    }[int(points)]
    rng = _rng(seed)
    a = np.zeros((n, n), dtype=np.float32)
    for off in offsets:
        diag = rng.uniform(0.5, 1.5, size=n - abs(off)).astype(np.float32)
        if off >= 0:
            a[np.arange(n - off), np.arange(off, n)] = diag
        else:
            a[np.arange(-off, n), np.arange(n + off)] = diag
    return a


def _fem2d(n: int, seed: int) -> np.ndarray:
    """5-point Laplacian on a grid with ~n unknowns (fd18/G2_circuit-like)."""
    nx = max(2, int(np.sqrt(n)))
    ny = max(2, n // nx)
    m = nx * ny
    a = np.zeros((m, m), dtype=np.float32)
    idx = lambda i, j: i * ny + j
    for i in range(nx):
        for j in range(ny):
            r = idx(i, j)
            a[r, r] = 4.0
            if i > 0:
                a[r, idx(i - 1, j)] = -1.0
            if i < nx - 1:
                a[r, idx(i + 1, j)] = -1.0
            if j > 0:
                a[r, idx(i, j - 1)] = -1.0
            if j < ny - 1:
                a[r, idx(i, j + 1)] = -1.0
    return a


def _powerlaw(n: int, seed: int, avg_deg: float = 8.0, alpha: float = 1.5) -> np.ndarray:
    rng = _rng(seed)
    raw = rng.zipf(alpha, size=n).astype(np.float64)
    deg = np.minimum(np.maximum((raw / raw.mean()) * avg_deg, 1), n - 1).astype(int)
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        cols = rng.choice(n, size=deg[i], replace=False)
        a[i, cols] = rng.uniform(0.1, 1.0, size=deg[i]).astype(np.float32)
        a[i, i] = 1.0
    return a


def _uniform(n: int, seed: int, density: float = 0.01) -> np.ndarray:
    rng = _rng(seed)
    a = (rng.uniform(size=(n, n)) < density).astype(np.float32)
    a *= rng.uniform(0.1, 1.0, size=(n, n)).astype(np.float32)
    np.fill_diagonal(a, 1.0)
    return a


def _circuit(n: int, seed: int, n_dense_rows: int = 3,
             dense_frac: float = 0.6, base_deg: int = 5) -> np.ndarray:
    """Near-diagonal + a few nearly dense rows: the trans4/Raj1 pathology
    (paper §4.4.2) — max row nnz ≫ mean row nnz."""
    rng = _rng(seed)
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        k = max(1, int(rng.poisson(base_deg)))
        lo = max(0, i - 3 * base_deg)
        hi = min(n, i + 3 * base_deg)
        cols = rng.choice(np.arange(lo, hi), size=min(k, hi - lo), replace=False)
        a[i, cols] = rng.uniform(0.1, 1.0, size=len(cols)).astype(np.float32)
        a[i, i] = 1.0
    dense_rows = rng.choice(n, size=n_dense_rows, replace=False)
    for r in dense_rows:
        cols = rng.choice(n, size=int(dense_frac * n), replace=False)
        a[r, cols] = rng.uniform(0.1, 1.0, size=len(cols)).astype(np.float32)
    return a


def _blockrand(n: int, seed: int, bs: int = 4, block_density: float = 0.02) -> np.ndarray:
    rng = _rng(seed)
    nb = max(1, n // bs)
    mask = rng.uniform(size=(nb, nb)) < block_density
    np.fill_diagonal(mask, True)
    a = np.zeros((nb * bs, nb * bs), dtype=np.float32)
    bi, bj = np.nonzero(mask)
    for r, c in zip(bi, bj):
        a[r * bs:(r + 1) * bs, c * bs:(c + 1) * bs] = (
            rng.uniform(0.1, 1.0, size=(bs, bs)).astype(np.float32))
    return a[:n, :n]


def _banded(n: int, seed: int, bandwidth: int = 16, density: float = 0.4) -> np.ndarray:
    rng = _rng(seed)
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        lo, hi = max(0, i - bandwidth), min(n, i + bandwidth + 1)
        mask = rng.uniform(size=hi - lo) < density
        vals = rng.uniform(0.1, 1.0, size=hi - lo).astype(np.float32) * mask
        a[i, lo:hi] = vals
        a[i, i] = 1.0
    return a


_FAMILIES: Dict[str, Callable[..., np.ndarray]] = {
    "stencil": _stencil,
    "fem2d": _fem2d,
    "powerlaw": _powerlaw,
    "uniform": _uniform,
    "circuit": _circuit,
    "blockrand": _blockrand,
    "banded": _banded,
}


def generate(family: str, n: int, seed: int = 0, **params) -> np.ndarray:
    try:
        fn = _FAMILIES[family]
    except KeyError:
        raise ValueError(f"unknown family {family!r}; options: {sorted(_FAMILIES)}")
    return fn(n, seed=seed, **params)


def corpus(small_n: Tuple[int, ...] = (64, 256, 512, 1024, 2048),
           large_n: Tuple[int, ...] = (4096, 8192),
           seeds: Tuple[int, ...] = (0, 1)) -> List[MatrixSpec]:
    """The benchmark corpus.  Structured like the paper's complete set: a mix
    of families across a size range, split small/large at the (scaled-down)
    boundary.  ~120 specs by default; scale with ``seeds``/sizes for more.

    Note: the paper's boundary is 10,000 rows on a 141 GB/s GPU; we scale
    sizes down ~one order of magnitude for single-core-CPU runtime and keep
    the small:large ratio (≈2:1, Table 2)."""
    specs: List[MatrixSpec] = []
    fam_params: Dict[str, Tuple[Tuple[str, float], ...]] = {
        "stencil": (("points", 5),),
        "fem2d": (),
        "powerlaw": (("avg_deg", 8.0),),
        "uniform": (("density", 0.01),),
        "circuit": (("n_dense_rows", 3),),
        "blockrand": (("bs", 4),),
        "banded": (("bandwidth", 16),),
    }
    for fam, params in fam_params.items():
        for n in list(small_n) + list(large_n):
            for seed in seeds:
                specs.append(MatrixSpec(
                    name=f"{fam}_n{n}_s{seed}", family=fam, n=n, seed=seed,
                    params=params))
    # extra stencil widths (the paper's multi-diagonal matrices)
    for points in (3, 9, 27):
        for n in (256, 1024, 4096):
            specs.append(MatrixSpec(name=f"stencil{points}_n{n}", family="stencil",
                                    n=n, seed=7, params=(("points", points),)))
    return specs


def small_corpus() -> List[MatrixSpec]:
    """Fast corpus for tests/CI."""
    return corpus(small_n=(64, 256), large_n=(1024,), seeds=(0,))


def paper_twins(scale: int = 16) -> Dict[str, np.ndarray]:
    """Synthetic twins of the paper's Table 6 matrices, scaled down by
    ``scale``.  The structural signature (max/mean/min nnz per row) is what
    drives the paper's conclusions, and it is preserved:

    =================  ========  =====  =====  ===  =========================
    matrix             rows      max    mean   min  character
    =================  ========  =====  =====  ===  =========================
    Hohn/fd18          16,248    6      3.86   1    FD mesh, low variance
    AMD/G2_circuit     150,102   6      4.84   2    circuit mesh, low variance
    IBM_EDA/trans4     116,835   114k   6.6    1    few dense rows (max≈rows)
    Rajat/Raj1         263,743   40k    4.94   1    few dense rows
    =================  ========  =====  =====  ===  =========================
    """
    return {
        "fd18_twin": _fem2d(16248 // scale, seed=18),
        "g2_circuit_twin": _stencil(150102 // scale, seed=2, points=5),
        "trans4_twin": _circuit(116835 // scale, seed=4, n_dense_rows=2,
                                dense_frac=0.95, base_deg=5),
        "raj1_twin": _circuit(263743 // scale, seed=1, n_dense_rows=4,
                              dense_frac=0.15, base_deg=4),
    }
