"""Sparse-matrix storage formats from the paper, as JAX pytrees.

Implements every format the paper discusses (§3):

* :class:`CSR`            — common Compressed Sparse Rows (Fig. 1).
* :class:`COO`            — coordinate format (Fig. 4).
* :class:`ELLPACK`        — fixed-K padded format (Fig. 3), stored slot-major
                            ``(K, N)`` which is the TPU-lane-friendly layout.
* :class:`HybridEllCoo`   — Bell–Garland Hybrid (ELL + COO spill) [1].
* :class:`BlockedCSR`     — 4x4-style BSR (Fig. 2) [Buatois et al.].
* :class:`SlicedEllpack`  — Monakov et al. sliced ELLPACK (no rowLengths).
* :class:`RgCSR`          — the paper's Row-grouped CSR (Fig. 5): slot-major
                            groups + ``group_pointers`` + ``row_lengths``.

Construction happens on the host in numpy (as a real framework builds formats
at load time); the resulting containers hold ``jnp`` arrays and are registered
pytrees, so they can be passed through ``jax.jit`` boundaries, donated,
sharded and checkpointed like any other parameter tree.

TPU adaptation notes (DESIGN.md §2): within one RgCSR group of ``G`` rows the
data for slot ``k`` occupies ``G`` consecutive lanes — i.e. a group is a dense
``(K_g, G)`` tile in (sublane, lane) layout.  We additionally pad each group's
slot count to a multiple of ``slot_pad`` (default 8) so tiles are full VREGs.
The padding is *accounted* exactly like the paper's "artificial zeros".
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = Any

# Lane width of the TPU vector unit; RgCSR groups default to one lane-row.
TPU_LANES = 128
# Sublane packing: slots per group are padded to a multiple of this.
TPU_SUBLANES = 8

__all__ = [
    "CSR",
    "COO",
    "ELLPACK",
    "HybridEllCoo",
    "BlockedCSR",
    "SlicedEllpack",
    "RgCSR",
    "ShardedRgCSR",
    "from_dense",
    "FORMATS",
]


def _as_2d(dense: np.ndarray) -> np.ndarray:
    dense = np.asarray(dense)
    if dense.ndim != 2:
        raise ValueError(f"expected a 2-D matrix, got shape {dense.shape}")
    return dense


def _csr_arrays(dense: np.ndarray):
    """Host-side CSR triplet from a dense matrix (row-major nonzero walk)."""
    rows, cols = np.nonzero(dense)
    values = dense[rows, cols]
    n_rows = dense.shape[0]
    row_ptr = np.zeros(n_rows + 1, dtype=np.int32)
    np.add.at(row_ptr, rows + 1, 1)
    row_ptr = np.cumsum(row_ptr, dtype=np.int64).astype(np.int32)
    return values, cols.astype(np.int32), rows.astype(np.int32), row_ptr


def _tree_dataclass(cls):
    """Register a dataclass as a pytree: array fields dynamic, rest static."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    array_fields = [f.name for f in dataclasses.fields(cls) if f.metadata.get("array")]
    static_fields = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("array")]

    def flatten(obj):
        children = tuple(getattr(obj, n) for n in array_fields)
        aux = tuple(getattr(obj, n) for n in static_fields)
        return children, aux

    def unflatten(aux, children):
        kwargs = dict(zip(array_fields, children))
        kwargs.update(dict(zip(static_fields, aux)))
        return cls(**kwargs)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    cls._array_fields = array_fields
    cls._static_fields = static_fields
    return cls


def _arr():
    return dataclasses.field(metadata={"array": True})


def _static():
    return dataclasses.field(metadata={"array": False})


# ---------------------------------------------------------------------------
# CSR
# ---------------------------------------------------------------------------


@_tree_dataclass
class CSR:
    """Common CSR (paper §3.1). ``row_ids`` is a derived array used only by the
    vectorized jnp oracle (scalar-CSR has no data-parallel TPU analogue); it is
    NOT counted in the format's storage footprint."""

    values: Array = _arr()
    columns: Array = _arr()
    row_pointers: Array = _arr()
    row_ids: Array = _arr()  # derived: row index of each stored nonzero
    shape: Tuple[int, int] = _static()

    name: ClassVar[str] = "csr"

    @classmethod
    def from_dense(cls, dense: np.ndarray) -> "CSR":
        dense = _as_2d(dense)
        values, cols, rows, row_ptr = _csr_arrays(dense)
        return cls(
            values=jnp.asarray(values),
            columns=jnp.asarray(cols),
            row_pointers=jnp.asarray(row_ptr),
            row_ids=jnp.asarray(rows),
            shape=dense.shape,
        )

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    @property
    def stored_elements(self) -> int:
        return self.nnz

    def storage_bytes(self) -> int:
        """values + columns + rowPointers, per the paper's byte accounting."""
        itemsize = jnp.dtype(self.values.dtype).itemsize
        return self.nnz * itemsize + self.nnz * 4 + (self.shape[0] + 1) * 4

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.asarray(self.values).dtype)
        np.add.at(out, (np.asarray(self.row_ids), np.asarray(self.columns)),
                  np.asarray(self.values))
        return out


# ---------------------------------------------------------------------------
# COO
# ---------------------------------------------------------------------------


@_tree_dataclass
class COO:
    """Coordinate format (paper Fig. 4): fully explicit (row, col, value)."""

    values: Array = _arr()
    rows: Array = _arr()
    columns: Array = _arr()
    shape: Tuple[int, int] = _static()

    name: ClassVar[str] = "coo"

    @classmethod
    def from_dense(cls, dense: np.ndarray) -> "COO":
        dense = _as_2d(dense)
        values, cols, rows, _ = _csr_arrays(dense)
        return cls(
            values=jnp.asarray(values),
            rows=jnp.asarray(rows),
            columns=jnp.asarray(cols),
            shape=dense.shape,
        )

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    @property
    def stored_elements(self) -> int:
        return self.nnz

    def storage_bytes(self) -> int:
        itemsize = jnp.dtype(self.values.dtype).itemsize
        return self.nnz * (itemsize + 8)  # value + row idx + col idx

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.asarray(self.values).dtype)
        np.add.at(out, (np.asarray(self.rows), np.asarray(self.columns)),
                  np.asarray(self.values))
        return out


# ---------------------------------------------------------------------------
# ELLPACK
# ---------------------------------------------------------------------------


@_tree_dataclass
class ELLPACK:
    """ELLPACK (paper Fig. 3), stored slot-major ``(K, N)``.

    Slot-major is the coalesced/GPU layout and equally the TPU-lane layout:
    slot ``k`` of all rows is one contiguous vector.  ``columns`` padding uses
    the row's own index ("ghost index") so gathers stay in-bounds.
    """

    values: Array = _arr()   # (K, N)
    columns: Array = _arr()  # (K, N) int32
    shape: Tuple[int, int] = _static()

    name: ClassVar[str] = "ellpack"

    @classmethod
    def from_dense(cls, dense: np.ndarray) -> "ELLPACK":
        dense = _as_2d(dense)
        n_rows, _ = dense.shape
        row_lens = (dense != 0).sum(axis=1)
        k = int(row_lens.max()) if n_rows else 0
        k = max(k, 1)
        values = np.zeros((k, n_rows), dtype=dense.dtype)
        columns = np.zeros((k, n_rows), dtype=np.int32)
        for i in range(n_rows):
            cols_i = np.nonzero(dense[i])[0]
            values[: len(cols_i), i] = dense[i, cols_i]
            columns[: len(cols_i), i] = cols_i
        return cls(values=jnp.asarray(values), columns=jnp.asarray(columns),
                   shape=dense.shape)

    @property
    def nnz(self) -> int:
        return int((np.asarray(self.values) != 0).sum())

    @property
    def stored_elements(self) -> int:
        return int(np.prod(self.values.shape))

    def storage_bytes(self) -> int:
        itemsize = jnp.dtype(self.values.dtype).itemsize
        return self.stored_elements * (itemsize + 4)

    def to_dense(self) -> np.ndarray:
        k, n_rows = self.values.shape
        out = np.zeros(self.shape, dtype=np.asarray(self.values).dtype)
        vals = np.asarray(self.values)
        cols = np.asarray(self.columns)
        for slot in range(k):
            mask = vals[slot] != 0
            out[np.arange(n_rows)[mask], cols[slot][mask]] += vals[slot][mask]
        return out


# ---------------------------------------------------------------------------
# Hybrid (ELL + COO)
# ---------------------------------------------------------------------------


def _hybrid_split_k(row_lens: np.ndarray, relative_speed: float = 3.0,
                    breakeven_threshold: int = 4096) -> int:
    """Bell–Garland / CUSP heuristic for K1 (paper §3.3).

    Choose the largest K such that at least ``max(N/relative_speed,
    breakeven_threshold)`` rows still have >= K nonzeros — i.e. the ELL part
    stays mostly dense and the spill goes to COO.
    """
    n = len(row_lens)
    if n == 0:
        return 0
    hist = np.bincount(np.minimum(row_lens, row_lens.max()), minlength=row_lens.max() + 2)
    # rows_with_at_least[k] = number of rows with >= k nonzeros
    rows_with_at_least = n - np.cumsum(hist)[:-1]
    threshold = min(n, max(n / relative_speed, breakeven_threshold))
    ks = np.nonzero(rows_with_at_least >= threshold)[0]
    return int(ks.max()) if len(ks) else 0


@_tree_dataclass
class HybridEllCoo:
    """Hybrid format [Bell & Garland 2008] (paper §3.3): ELLPACK for the first
    ``k1`` nonzeros of each row, COO for the spill."""

    ell_values: Array = _arr()   # (K1, N)
    ell_columns: Array = _arr()  # (K1, N)
    coo_values: Array = _arr()
    coo_rows: Array = _arr()
    coo_columns: Array = _arr()
    shape: Tuple[int, int] = _static()
    k1: int = _static()

    name: ClassVar[str] = "hybrid"

    @classmethod
    def from_dense(cls, dense: np.ndarray, k1: int | None = None) -> "HybridEllCoo":
        dense = _as_2d(dense)
        n_rows, _ = dense.shape
        row_lens = (dense != 0).sum(axis=1)
        if k1 is None:
            k1 = _hybrid_split_k(row_lens)
        k1 = int(max(k1, 0))
        ell_values = np.zeros((max(k1, 1), n_rows), dtype=dense.dtype)
        ell_columns = np.zeros((max(k1, 1), n_rows), dtype=np.int32)
        coo_v, coo_r, coo_c = [], [], []
        for i in range(n_rows):
            cols_i = np.nonzero(dense[i])[0]
            head = cols_i[:k1]
            tail = cols_i[k1:]
            ell_values[: len(head), i] = dense[i, head]
            ell_columns[: len(head), i] = head
            coo_v.extend(dense[i, tail])
            coo_r.extend([i] * len(tail))
            coo_c.extend(tail)
        coo_dtype = dense.dtype
        return cls(
            ell_values=jnp.asarray(ell_values),
            ell_columns=jnp.asarray(ell_columns),
            coo_values=jnp.asarray(np.asarray(coo_v, dtype=coo_dtype)),
            coo_rows=jnp.asarray(np.asarray(coo_r, dtype=np.int32)),
            coo_columns=jnp.asarray(np.asarray(coo_c, dtype=np.int32)),
            shape=dense.shape,
            k1=k1,
        )

    @property
    def nnz(self) -> int:
        return int((np.asarray(self.ell_values) != 0).sum()) + int(self.coo_values.shape[0])

    @property
    def stored_elements(self) -> int:
        return int(np.prod(self.ell_values.shape)) + int(self.coo_values.shape[0])

    def storage_bytes(self) -> int:
        itemsize = jnp.dtype(self.ell_values.dtype).itemsize
        ell = int(np.prod(self.ell_values.shape)) * (itemsize + 4)
        coo = int(self.coo_values.shape[0]) * (itemsize + 8)
        return ell + coo

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.asarray(self.ell_values).dtype)
        vals = np.asarray(self.ell_values)
        cols = np.asarray(self.ell_columns)
        n_rows = self.shape[0]
        for slot in range(vals.shape[0]):
            mask = vals[slot] != 0
            out[np.arange(n_rows)[mask], cols[slot][mask]] += vals[slot][mask]
        np.add.at(out, (np.asarray(self.coo_rows), np.asarray(self.coo_columns)),
                  np.asarray(self.coo_values))
        return out


# ---------------------------------------------------------------------------
# Blocked CSR (BSR)
# ---------------------------------------------------------------------------


@_tree_dataclass
class BlockedCSR:
    """Blocked CSR (paper §3.2, Fig. 2): dense ``bs x bs`` blocks of the matrix
    itself (not of the compressed rows) — the format the paper criticizes for
    low fill efficiency (27% in Fig. 2)."""

    values: Array = _arr()         # (n_blocks, bs, bs)
    block_columns: Array = _arr()  # (n_blocks,)
    block_row_pointers: Array = _arr()  # (n_block_rows + 1,)
    block_row_ids: Array = _arr()  # derived, for the jnp oracle
    shape: Tuple[int, int] = _static()
    block_size: int = _static()

    name: ClassVar[str] = "blocked_csr"

    @classmethod
    def from_dense(cls, dense: np.ndarray, block_size: int = 4) -> "BlockedCSR":
        dense = _as_2d(dense)
        n_rows, n_cols = dense.shape
        bs = block_size
        pr = (-n_rows) % bs
        pc = (-n_cols) % bs
        padded = np.pad(dense, ((0, pr), (0, pc)))
        nbr, nbc = padded.shape[0] // bs, padded.shape[1] // bs
        blocks = padded.reshape(nbr, bs, nbc, bs).transpose(0, 2, 1, 3)
        nz_block = (blocks != 0).any(axis=(2, 3))
        brows, bcols = np.nonzero(nz_block)
        values = blocks[brows, bcols]
        ptr = np.zeros(nbr + 1, dtype=np.int32)
        np.add.at(ptr, brows + 1, 1)
        ptr = np.cumsum(ptr).astype(np.int32)
        return cls(
            values=jnp.asarray(values),
            block_columns=jnp.asarray(bcols.astype(np.int32)),
            block_row_pointers=jnp.asarray(ptr),
            block_row_ids=jnp.asarray(brows.astype(np.int32)),
            shape=dense.shape,
            block_size=bs,
        )

    @property
    def nnz(self) -> int:
        return int((np.asarray(self.values) != 0).sum())

    @property
    def stored_elements(self) -> int:
        return int(np.prod(self.values.shape))

    def storage_bytes(self) -> int:
        itemsize = jnp.dtype(self.values.dtype).itemsize
        nb = int(self.values.shape[0])
        return self.stored_elements * itemsize + nb * 4 + (len(self.block_row_pointers)) * 4

    def to_dense(self) -> np.ndarray:
        bs = self.block_size
        nbr = len(np.asarray(self.block_row_pointers)) - 1
        nbc = (self.shape[1] + bs - 1) // bs
        out = np.zeros((nbr * bs, nbc * bs), dtype=np.asarray(self.values).dtype)
        vals = np.asarray(self.values)
        brows = np.asarray(self.block_row_ids)
        bcols = np.asarray(self.block_columns)
        for b in range(vals.shape[0]):
            r0, c0 = brows[b] * bs, bcols[b] * bs
            out[r0:r0 + bs, c0:c0 + bs] += vals[b]
        return out[: self.shape[0], : self.shape[1]]


# ---------------------------------------------------------------------------
# Row-grouped CSR — the paper's format — and Sliced ELLPACK
# ---------------------------------------------------------------------------


def _rgcsr_arrays(dense: np.ndarray, group_size: int, slot_pad: int):
    """Build slot-major grouped arrays. Returns a dict of numpy arrays.

    Layout: group ``g`` covers rows ``[g*G, min((g+1)*G, N))``; its data is a
    dense ``(K_g, G)`` tile flattened into ``values``/``columns`` starting at
    ``group_pointers[g]``, where element ``(slot, r)`` sits at
    ``group_pointers[g] + slot*G + r``.  ``K_g`` = max row length in the group,
    rounded up to ``slot_pad`` (TPU sublane packing; paper pads to the max
    row length only — the extra pad is accounted as artificial zeros too).
    The last group is padded to a full ``G`` rows (lanes must be full).
    """
    dense = _as_2d(dense)
    n_rows = dense.shape[0]
    g_size = int(group_size)
    n_groups = max(1, -(-n_rows // g_size))
    row_lens = (dense != 0).sum(axis=1).astype(np.int32)

    group_ptr = np.zeros(n_groups + 1, dtype=np.int64)
    slots_per_group = np.zeros(n_groups, dtype=np.int32)
    for g in range(n_groups):
        lo, hi = g * g_size, min((g + 1) * g_size, n_rows)
        k_g = int(row_lens[lo:hi].max()) if hi > lo else 0
        if slot_pad > 1:
            k_g = -(-max(k_g, 1) // slot_pad) * slot_pad
        else:
            k_g = max(k_g, 1)
        slots_per_group[g] = k_g
        group_ptr[g + 1] = group_ptr[g] + k_g * g_size

    total = int(group_ptr[-1])
    values = np.zeros(total, dtype=dense.dtype)
    columns = np.zeros(total, dtype=np.int32)
    row_of_element = np.zeros(total, dtype=np.int32)  # derived (oracle only)
    for g in range(n_groups):
        lo, hi = g * g_size, min((g + 1) * g_size, n_rows)
        base = int(group_ptr[g])
        k_g = int(slots_per_group[g])
        # default the padding's row-ids to the group's first row; values are 0
        row_of_element[base: base + k_g * g_size] = lo if hi > lo else 0
        for r in range(lo, hi):
            cols_r = np.nonzero(dense[r])[0]
            lane = r - lo
            idx = base + np.arange(len(cols_r)) * g_size + lane
            values[idx] = dense[r, cols_r]
            columns[idx] = cols_r
            pad_idx = base + np.arange(len(cols_r), k_g) * g_size + lane
            row_of_element[base + np.arange(k_g) * g_size + lane] = r
            columns[pad_idx] = 0  # ghost index (paper: "ghost index")
    return dict(
        values=values,
        columns=columns,
        group_pointers=group_ptr.astype(np.int32),
        row_lengths=row_lens,
        slots_per_group=slots_per_group,
        row_of_element=row_of_element,
        n_groups=n_groups,
    )


@_tree_dataclass
class RgCSR:
    """Row-grouped CSR — the paper's contribution (§3.4, Fig. 5).

    ``values``/``columns``: flat slot-major grouped storage.
    ``group_pointers``:     offset of each group (paper's groupPointers).
    ``row_lengths``:        true nnz per row (paper's rowLengths — the delta
                            vs sliced ELLPACK: lets the kernel skip padding).
    ``slots_per_group``:    K_g per group (derivable from group_pointers; kept
                            for the chunk table used by the Pallas kernel).
    ``row_of_element``:     derived row index per stored element — used only by
                            the vectorized jnp oracle, excluded from storage
                            accounting (a CUDA thread derives it from its id).
    """

    values: Array = _arr()
    columns: Array = _arr()
    group_pointers: Array = _arr()
    row_lengths: Array = _arr()
    slots_per_group: Array = _arr()
    row_of_element: Array = _arr()
    shape: Tuple[int, int] = _static()
    group_size: int = _static()
    slot_pad: int = _static()

    name: ClassVar[str] = "rgcsr"

    @classmethod
    def from_dense(cls, dense: np.ndarray, group_size: int = TPU_LANES,
                   slot_pad: int = TPU_SUBLANES) -> "RgCSR":
        dense = _as_2d(dense)
        arrs = _rgcsr_arrays(dense, group_size, slot_pad)
        return cls(
            values=jnp.asarray(arrs["values"]),
            columns=jnp.asarray(arrs["columns"]),
            group_pointers=jnp.asarray(arrs["group_pointers"]),
            row_lengths=jnp.asarray(arrs["row_lengths"]),
            slots_per_group=jnp.asarray(arrs["slots_per_group"]),
            row_of_element=jnp.asarray(arrs["row_of_element"]),
            shape=dense.shape,
            group_size=int(group_size),
            slot_pad=int(slot_pad),
        )

    @property
    def n_groups(self) -> int:
        return int(self.slots_per_group.shape[0])

    @property
    def nnz(self) -> int:
        return int(np.asarray(self.row_lengths).sum())

    @property
    def stored_elements(self) -> int:
        return int(self.values.shape[0])

    def fill_ratio(self) -> float:
        """Paper's "artificial zeros" metric: pad/nnz as a percentage.
        100% = as many artificial zeros as true nonzeros."""
        nnz = self.nnz
        if nnz == 0:
            return 0.0
        return 100.0 * (self.stored_elements - nnz) / nnz

    def storage_bytes(self) -> int:
        itemsize = jnp.dtype(self.values.dtype).itemsize
        n_rows = self.shape[0]
        return (self.stored_elements * (itemsize + 4)
                + (self.n_groups + 1) * 4 + n_rows * 4)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.asarray(self.values).dtype)
        vals = np.asarray(self.values)
        cols = np.asarray(self.columns)
        rows = np.asarray(self.row_of_element)
        mask = vals != 0
        np.add.at(out, (rows[mask], cols[mask]), vals[mask])
        return out

    def to_csr_arrays(self):
        """Host CSR triplet ``(values, columns, row_ptr)`` recovered from the
        grouped slot-major storage — no densification.

        Used by the adaptive planner (kernels/ops, ordering='adaptive') to
        regroup rows by length.  Extraction is *positional*: row ``r`` owns
        slots ``[0, row_lengths[r])`` of its lane, i.e. flat indices
        ``group_pointers[r // G] + slot·G + (r % G)``.  Selecting by stored
        value (``!= 0``) would misalign every subsequent row if a true
        element happens to equal 0.0 (e.g. a trained value crossing zero),
        so positions — not values — define membership.
        """
        vals = np.asarray(self.values)
        cols = np.asarray(self.columns)
        g = self.group_size
        row_lens = np.asarray(self.row_lengths).astype(np.int64)
        gp = np.asarray(self.group_pointers).astype(np.int64)
        row_ptr = np.concatenate([[0], np.cumsum(row_lens)])
        total = int(row_ptr[-1])
        rows = np.repeat(np.arange(len(row_lens), dtype=np.int64), row_lens)
        slot = np.arange(total, dtype=np.int64) - np.repeat(
            row_ptr[:-1], row_lens)
        flat = gp[rows // g] + slot * g + (rows % g)
        return vals[flat], cols[flat], row_ptr


@_tree_dataclass
class SlicedEllpack:
    """Sliced ELLPACK [Monakov et al. 2010] (paper §3.4): same grouped
    slot-major layout as RgCSR but WITHOUT ``row_lengths`` — every row in a
    group performs K_g multiply-adds including the padding (the paper's
    "meaningless arithmetic").  Storage equals RgCSR minus the rowLengths
    array; compute is modeled accordingly in :mod:`repro.core.analyze`."""

    values: Array = _arr()
    columns: Array = _arr()
    group_pointers: Array = _arr()
    slots_per_group: Array = _arr()
    row_of_element: Array = _arr()
    shape: Tuple[int, int] = _static()
    group_size: int = _static()
    slot_pad: int = _static()

    name: ClassVar[str] = "sliced_ellpack"

    @classmethod
    def from_dense(cls, dense: np.ndarray, group_size: int = TPU_LANES,
                   slot_pad: int = TPU_SUBLANES) -> "SlicedEllpack":
        arrs = _rgcsr_arrays(_as_2d(dense), group_size, slot_pad)
        return cls(
            values=jnp.asarray(arrs["values"]),
            columns=jnp.asarray(arrs["columns"]),
            group_pointers=jnp.asarray(arrs["group_pointers"]),
            slots_per_group=jnp.asarray(arrs["slots_per_group"]),
            row_of_element=jnp.asarray(arrs["row_of_element"]),
            shape=_as_2d(dense).shape,
            group_size=int(group_size),
            slot_pad=int(slot_pad),
        )

    @property
    def nnz(self) -> int:
        return int((np.asarray(self.values) != 0).sum())

    @property
    def stored_elements(self) -> int:
        return int(self.values.shape[0])

    def storage_bytes(self) -> int:
        itemsize = jnp.dtype(self.values.dtype).itemsize
        n_groups = int(self.slots_per_group.shape[0])
        return self.stored_elements * (itemsize + 4) + (n_groups + 1) * 4

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.asarray(self.values).dtype)
        vals = np.asarray(self.values)
        cols = np.asarray(self.columns)
        rows = np.asarray(self.row_of_element)
        mask = vals != 0
        np.add.at(out, (rows[mask], cols[mask]), vals[mask])
        return out


# ---------------------------------------------------------------------------
# Row-sharded RgCSR — one RgCSR per device shard (multi-device SpMV)
# ---------------------------------------------------------------------------


@_tree_dataclass
class ShardedRgCSR:
    """RgCSR partitioned by rows over a 1-D mesh axis (DESIGN.md §11).

    The canonical distributed-SpMV decomposition (Kreutzer et al.,
    arXiv:1112.5588): shard ``d`` owns the contiguous row block
    ``[d·rows_per_shard, (d+1)·rows_per_shard)`` and stores it as its own
    :class:`RgCSR` — so block/adaptive grouping, slot padding and the step
    table all apply *per shard*, and per-device stored slots and grid steps
    shrink ~1/D.  Columns keep their **global** indices here; the local /
    remote split (columns owned by this device vs. columns whose x-entries
    must be communicated) is computed at plan time
    (:func:`repro.kernels.ops.make_sharded_plan`) because it depends on the
    execution mode.

    Every shard is built over exactly ``rows_per_shard`` rows (the trailing
    shard is padded with empty rows), so all shards have the *same* group
    count — the uniformity `shard_map` needs for SPMD execution.
    """

    shards: Tuple[RgCSR, ...] = _arr()   # pytree children (one per device)
    shape: Tuple[int, int] = _static()
    n_shards: int = _static()
    rows_per_shard: int = _static()
    group_size: int = _static()
    slot_pad: int = _static()

    name: ClassVar[str] = "sharded_rgcsr"

    @staticmethod
    def shard_layout(n_rows: int, n_cols: int,
                     n_shards: int) -> Tuple[int, int]:
        """``(rows_per_shard, cols_per_shard)`` ceil-div layout.

        The single source of the shard geometry — plan construction
        (``ops.make_sharded_plan``) and per-shard tuning
        (``autotune.shard_row_blocks``) derive their blocks from this, so
        a layout change here cannot silently desynchronize them.
        """
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        return (max(1, -(-n_rows // n_shards)),
                max(1, -(-n_cols // n_shards)))

    @classmethod
    def from_dense(cls, dense: np.ndarray, n_shards: int,
                   group_size: int = TPU_LANES,
                   slot_pad: int = TPU_SUBLANES) -> "ShardedRgCSR":
        dense = _as_2d(dense)
        n_rows, n_cols = dense.shape
        rps, _ = cls.shard_layout(n_rows, n_cols, n_shards)
        shards = []
        for d in range(n_shards):
            lo, hi = d * rps, min((d + 1) * rps, n_rows)
            block = np.zeros((rps, n_cols), dtype=dense.dtype)
            if hi > lo:
                block[: hi - lo] = dense[lo:hi]
            shards.append(RgCSR.from_dense(block, group_size=group_size,
                                           slot_pad=slot_pad))
        return cls(shards=tuple(shards), shape=dense.shape,
                   n_shards=int(n_shards), rows_per_shard=rps,
                   group_size=int(group_size), slot_pad=int(slot_pad))

    @property
    def nnz(self) -> int:
        return sum(s.nnz for s in self.shards)

    @property
    def stored_elements(self) -> int:
        return sum(s.stored_elements for s in self.shards)

    def storage_bytes(self) -> int:
        return sum(s.storage_bytes() for s in self.shards)

    def shard_rows(self, d: int) -> Tuple[int, int]:
        """(lo, hi) global row range truly owned by shard ``d`` (unpadded)."""
        lo = d * self.rows_per_shard
        return lo, min(lo + self.rows_per_shard, self.shape[0])

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape,
                       dtype=np.asarray(self.shards[0].values).dtype)
        for d, s in enumerate(self.shards):
            lo, hi = self.shard_rows(d)
            if hi > lo:
                out[lo:hi] = s.to_dense()[: hi - lo]
        return out


FORMATS = {
    "csr": CSR,
    "coo": COO,
    "ellpack": ELLPACK,
    "hybrid": HybridEllCoo,
    "blocked_csr": BlockedCSR,
    "sliced_ellpack": SlicedEllpack,
    "rgcsr": RgCSR,
}


def from_dense(dense: np.ndarray, fmt: str = "rgcsr", **kwargs):
    """Build any of the paper's formats from a dense matrix."""
    try:
        cls = FORMATS[fmt]
    except KeyError:
        raise ValueError(f"unknown format {fmt!r}; options: {sorted(FORMATS)}")
    return cls.from_dense(dense, **kwargs)
