"""Row orderings (paper §4.4.2, Table 7).

The paper evaluates three orderings of the row index:

* ``without``    — natural order.
* ``descending`` — rows sorted by decreasing nonzero count.  Optimal for
  suppressing RgCSR artificial zeros (rows in a group have similar lengths)
  but may shuffle the nonzero pattern (worse x-locality).
* ``amd``        — approximate minimum degree.  We substitute **RCM**
  (reverse Cuthill–McKee, via scipy) — the same role in the experiment: a
  bandwidth/profile-reducing symmetric permutation that improves x-reuse at
  the cost of more artificial zeros than descending.  The substitution is
  recorded in DESIGN.md §9 and labeled in every benchmark table.

All orderings are host-side (numpy/scipy) — format construction time, exactly
as in the paper.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "descending_ordering",
    "descending_from_lengths",
    "split_spill_rows",
    "rcm_ordering",
    "random_ordering",
    "permute_rows",
    "permute_symmetric",
    "ORDERINGS",
]


def descending_ordering(dense: np.ndarray) -> np.ndarray:
    """Permutation sorting rows by decreasing nonzero count (stable)."""
    row_lens = (np.asarray(dense) != 0).sum(axis=1)
    return np.argsort(-row_lens, kind="stable")


def descending_from_lengths(row_lens: np.ndarray) -> np.ndarray:
    """Descending-length permutation straight from a row-length vector.

    The adaptive RgCSR planner (kernels/ops.make_plan, ordering='adaptive')
    already holds exact per-row nonzero counts, so it permutes without
    touching the dense matrix.  Stable: equal-length rows keep their
    original relative order, which keeps the permutation deterministic and
    x-locality as good as descending allows.
    """
    return np.argsort(-np.asarray(row_lens), kind="stable")


def split_spill_rows(row_lens: np.ndarray, threshold: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """(grouped_rows, spilled_rows) split at ``threshold`` nonzeros.

    Rows longer than ``threshold`` are pathological for any grouped padded
    format (one long row inflates its whole group's slot count, paper
    Table 6); the adaptive planner routes them to a COO tail instead
    (Bell–Garland Hybrid spill).  ``threshold <= 0`` disables spilling.
    """
    row_lens = np.asarray(row_lens)
    if threshold <= 0:
        return np.arange(len(row_lens)), np.empty(0, dtype=np.int64)
    spilled = np.nonzero(row_lens > threshold)[0]
    grouped = np.nonzero(row_lens <= threshold)[0]
    return grouped, spilled


def rcm_ordering(dense: np.ndarray) -> np.ndarray:
    """Reverse Cuthill–McKee on the symmetrized pattern (AMD stand-in)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    a = sp.csr_matrix(np.asarray(dense) != 0)
    sym = ((a + a.T) > 0).astype(np.int8)
    perm = reverse_cuthill_mckee(sym.tocsr(), symmetric_mode=True)
    return np.asarray(perm, dtype=np.int64)


def random_ordering(dense: np.ndarray, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(np.asarray(dense).shape[0])


def permute_rows(dense: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Row permutation P·A.  SpMV result comes back permuted: y' = P·(A x)."""
    return np.asarray(dense)[perm]


def permute_symmetric(dense: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Symmetric permutation P·A·Pᵀ (AMD/RCM style); x must be permuted too."""
    d = np.asarray(dense)
    return d[np.ix_(perm, perm)]


ORDERINGS = {
    "without": lambda d: np.arange(np.asarray(d).shape[0]),
    "descending": descending_ordering,
    "rcm": rcm_ordering,
}
