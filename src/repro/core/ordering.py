"""Row orderings (paper §4.4.2, Table 7).

The paper evaluates three orderings of the row index:

* ``without``    — natural order.
* ``descending`` — rows sorted by decreasing nonzero count.  Optimal for
  suppressing RgCSR artificial zeros (rows in a group have similar lengths)
  but may shuffle the nonzero pattern (worse x-locality).
* ``amd``        — approximate minimum degree.  We substitute **RCM**
  (reverse Cuthill–McKee, via scipy) — the same role in the experiment: a
  bandwidth/profile-reducing symmetric permutation that improves x-reuse at
  the cost of more artificial zeros than descending.  The substitution is
  recorded in DESIGN.md §7 and labeled in every benchmark table.

All orderings are host-side (numpy/scipy) — format construction time, exactly
as in the paper.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "descending_ordering",
    "rcm_ordering",
    "random_ordering",
    "permute_rows",
    "permute_symmetric",
    "ORDERINGS",
]


def descending_ordering(dense: np.ndarray) -> np.ndarray:
    """Permutation sorting rows by decreasing nonzero count (stable)."""
    row_lens = (np.asarray(dense) != 0).sum(axis=1)
    return np.argsort(-row_lens, kind="stable")


def rcm_ordering(dense: np.ndarray) -> np.ndarray:
    """Reverse Cuthill–McKee on the symmetrized pattern (AMD stand-in)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    a = sp.csr_matrix(np.asarray(dense) != 0)
    sym = ((a + a.T) > 0).astype(np.int8)
    perm = reverse_cuthill_mckee(sym.tocsr(), symmetric_mode=True)
    return np.asarray(perm, dtype=np.int64)


def random_ordering(dense: np.ndarray, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(np.asarray(dense).shape[0])


def permute_rows(dense: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Row permutation P·A.  SpMV result comes back permuted: y' = P·(A x)."""
    return np.asarray(dense)[perm]


def permute_symmetric(dense: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Symmetric permutation P·A·Pᵀ (AMD/RCM style); x must be permuted too."""
    d = np.asarray(dense)
    return d[np.ix_(perm, perm)]


ORDERINGS = {
    "without": lambda d: np.arange(np.asarray(d).shape[0]),
    "descending": descending_ordering,
    "rcm": rcm_ordering,
}
