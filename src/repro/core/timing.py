"""Single timing harness shared by the autotuner and the benchmark tables.

One implementation so measured autotune winners stay comparable with the
benchmark CSV figures (same warmup/block/median protocol).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

__all__ = ["time_us"]


def time_us(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time of fn(*args) in µs (jit-warmed, device-blocked)."""
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))
