"""Single timing harness shared by the autotuner and the benchmark tables.

One implementation so measured autotune winners stay comparable with the
benchmark CSV figures (same warmup/block/median protocol).  Two clocks:

* :func:`time_us` — host wall-clock (perf_counter around a blocked call),
  always available, noisy on busy hosts.
* :func:`profiled_time_us_group` — device time from a ``jax.profiler``
  trace session: one session covers a whole group of callables (a trace
  session costs ~1s of setup, far too slow per candidate), each wrapped
  in a named ``TraceAnnotation`` window per repeat; device-event
  durations inside each window are summed and the median over repeats is
  the callable's time.  Returns ``None`` whenever anything about the
  profiler path is unavailable or unparseable, so callers fall back to
  :func:`time_us` — the provenance (``profiler`` vs ``wallclock``) is
  recorded by the autotuner in ``TuneResult.timing_source``.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import tempfile
import time
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np

__all__ = ["time_us", "profiler_available", "profiled_time_us_group"]


def time_us(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time of fn(*args) in µs (jit-warmed, device-blocked).

    Every warmup iteration blocks before the next starts — otherwise
    async-dispatched warmup work can still be in flight when the first
    measured repeat begins, and its completion bleeds into that repeat's
    wall time.  ``warmup=0`` is a valid no-warmup call (the old code
    would have blocked on ``None``).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


# ---------------------------------------------------------------------------
# jax.profiler-backed device timing
# ---------------------------------------------------------------------------

_PROFILER_OK: Optional[bool] = None


def profiler_available() -> bool:
    """Whether jax.profiler trace sessions work in this runtime (probed
    once, memoized).  False on runtimes without profiler support or when
    trace capture raises."""
    global _PROFILER_OK
    if _PROFILER_OK is None:
        try:
            with tempfile.TemporaryDirectory() as d:
                with jax.profiler.trace(d):
                    jax.block_until_ready(jax.numpy.zeros(8) + 1)
                _PROFILER_OK = _find_trace_file(d) is not None
        except Exception:
            _PROFILER_OK = False
    return _PROFILER_OK


def _find_trace_file(trace_dir: str) -> Optional[str]:
    hits = glob.glob(os.path.join(
        trace_dir, "plugins", "profile", "*", "*.trace.json.gz"))
    return hits[0] if hits else None


def _load_trace_events(path: str) -> List[dict]:
    with gzip.open(path, "rt") as f:
        return json.load(f).get("traceEvents", [])


def _device_pids(events: Sequence[dict]) -> set:
    """pids whose process hosts device execution events.  TPU/GPU lanes
    carry "/device:" in the process name; the CPU backend runs compiled
    computations under ``TfrtCpuExecutable`` events, so any pid owning
    one of those counts too."""
    pids = set()
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            name = str((ev.get("args") or {}).get("name", ""))
            if "/device:" in name.lower() or "/device:" in name:
                pids.add(ev.get("pid"))
    for ev in events:
        if "TfrtCpuExecutable" in str(ev.get("name", "")):
            pids.add(ev.get("pid"))
    return pids


def profiled_time_us_group(fns: Sequence[Callable], *, repeats: int = 3,
                           warmup: int = 1) -> Optional[List[float]]:
    """Device time in µs for each callable, from one shared trace session.

    Each ``fns[i]`` is a zero-arg callable returning a value to block on.
    Warmup runs happen before the trace starts (compilation must not be
    measured).  Inside the session, repeat ``r`` of callable ``i`` runs
    under ``TraceAnnotation("tune:i:r")``; afterwards the device events
    whose timestamps fall inside each annotation window are summed and
    the per-callable median over repeats is returned.  Any failure →
    ``None`` (caller falls back to wall-clock)."""
    if not fns or not profiler_available():
        return None
    try:
        for fn in fns:
            for _ in range(max(1, warmup)):
                jax.block_until_ready(fn())
        with tempfile.TemporaryDirectory() as d:
            with jax.profiler.trace(d):
                for i, fn in enumerate(fns):
                    for r in range(repeats):
                        with jax.profiler.TraceAnnotation(f"tune:{i}:{r}"):
                            jax.block_until_ready(fn())
            path = _find_trace_file(d)
            if path is None:
                return None
            events = _load_trace_events(path)
    except Exception:
        return None

    windows = {}
    for ev in events:
        name = str(ev.get("name", ""))
        if name.startswith("tune:") and ev.get("ph") == "X":
            try:
                _, i, r = name.split(":")
                key = (int(i), int(r))
            except ValueError:
                continue
            t0 = float(ev.get("ts", 0.0))
            t1 = t0 + float(ev.get("dur", 0.0))
            lo, hi = windows.get(key, (t0, t1))
            windows[key] = (min(lo, t0), max(hi, t1))
    if not windows:
        return None

    dev_pids = _device_pids(events)
    if not dev_pids:
        return None
    device_events = [
        (float(ev.get("ts", 0.0)), float(ev.get("dur", 0.0)))
        for ev in events
        if ev.get("ph") == "X" and ev.get("pid") in dev_pids
        and not str(ev.get("name", "")).startswith("tune:")]

    results: List[float] = []
    for i in range(len(fns)):
        per_repeat = []
        for r in range(repeats):
            win = windows.get((i, r))
            if win is None:
                continue
            lo, hi = win
            dev = sum(dur for ts, dur in device_events
                      if lo <= ts and ts + dur <= hi)
            if dev > 0:
                per_repeat.append(dev)
        if not per_repeat:
            return None
        results.append(float(np.median(per_repeat)))
    return results
