"""Format analytics: fill, byte models, modeled throughput (paper Table 1).

The paper's peak-performance model (§3.4): per nonzero, an SpMV step reads one
int32 column index + one value + one x element; with a perfectly effective
cache for x, the x read is free.  GFLOPS = 2·nnz / (bytes / bandwidth).

We generalize to any format via ``storage_bytes()`` (which includes the
format's pointer/padding overhead — exactly what the paper identifies as the
thing formats must minimize) and provide both the paper's GTX280 numbers and
the TPU v5e target constants used in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import numpy as np

__all__ = [
    "HardwareModel",
    "GTX280",
    "TPU_V5E",
    "row_stats",
    "format_report",
    "modeled_gflops",
    "peak_model_gflops",
]


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    mem_bandwidth_gbs: float     # HBM / global-memory bandwidth
    peak_flops_tf32: float       # peak dense single-precision TFLOPS
    x_cache_bytes: int           # texture cache (GPU) / VMEM (TPU) for x

# The paper's card (§2, §4.1).
GTX280 = HardwareModel(name="gtx280", mem_bandwidth_gbs=141.0,
                       peak_flops_tf32=1.0, x_cache_bytes=16 * 1024)
# Our target chip (system-prompt constants: 197 TF bf16, 819 GB/s HBM).
TPU_V5E = HardwareModel(name="tpu_v5e", mem_bandwidth_gbs=819.0,
                        peak_flops_tf32=197.0, x_cache_bytes=16 * 2 ** 20)


def row_stats(dense: np.ndarray) -> Dict[str, float]:
    """max/mean/min nonzeros per row — the paper's Table 6 characterization."""
    row_lens = (np.asarray(dense) != 0).sum(axis=1)
    return {
        "rows": int(dense.shape[0]),
        "nnz": int(row_lens.sum()),
        "row_nnz_max": int(row_lens.max()) if len(row_lens) else 0,
        "row_nnz_mean": float(row_lens.mean()) if len(row_lens) else 0.0,
        "row_nnz_min": int(row_lens.min()) if len(row_lens) else 0,
        "row_nnz_std": float(row_lens.std()) if len(row_lens) else 0.0,
        "density_pct": 100.0 * row_lens.sum() / max(1, dense.shape[0] * dense.shape[1]),
    }


def modeled_gflops(matrix: Any, hw: HardwareModel = TPU_V5E,
                   x_cached: bool = True, dtype_bytes: int = 4,
                   n_cols: int | None = None) -> float:
    """Bandwidth-roofline GFLOPS for one SpMV with this stored format.

    bytes = format storage traffic (+ x traffic if not cached) + y writeback.
    flops = 2·nnz.  This is the paper's §3.4 estimate generalized: for common
    CSR with one value+one index per nonzero it reduces to m/12 (sp,
    uncached → plus 8B x read = 12B per nonzero with 4B index... the paper
    counts 12B = 4B idx + 4B val + 4B x for sp) and m/8 cached.
    """
    nnz = matrix.nnz
    if nnz == 0:
        return 0.0
    n_cols = n_cols if n_cols is not None else matrix.shape[1]
    traffic = matrix.storage_bytes()
    if x_cached:
        traffic += n_cols * dtype_bytes          # x streamed exactly once
    else:
        traffic += matrix.stored_elements * dtype_bytes  # one x read per stored element
    traffic += matrix.shape[0] * dtype_bytes     # y writeback
    seconds = traffic / (hw.mem_bandwidth_gbs * 1e9)
    return 2.0 * nnz / seconds / 1e9


def peak_model_gflops(hw: HardwareModel, dtype_bytes: int, x_cached: bool) -> float:
    """The paper's Table 1 closed form: m/(idx+val[+x]) GFLOPS."""
    per_elem = 4 + dtype_bytes + (0 if x_cached else dtype_bytes)
    return 2.0 * hw.mem_bandwidth_gbs / per_elem


def format_report(matrix: Any, hw: HardwareModel = TPU_V5E,
                  dtype_bytes: int = 4) -> Dict[str, float]:
    nnz = matrix.nnz
    stored = matrix.stored_elements
    fill = 100.0 * (stored - nnz) / max(1, nnz)
    return {
        "format": type(matrix).name,
        "nnz": nnz,
        "stored_elements": stored,
        "artificial_zeros_pct": fill,
        "storage_bytes": matrix.storage_bytes(),
        "gflops_cached": modeled_gflops(matrix, hw, True, dtype_bytes),
        "gflops_uncached": modeled_gflops(matrix, hw, False, dtype_bytes),
    }
