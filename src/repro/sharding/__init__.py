"""Distribution: logical-axis partitioner (DP/FSDP/TP/EP/SP)."""
from repro.sharding.partitioner import (  # noqa: F401
    Partitioner,
    ShardingRules,
    SERVE_RULES,
    TRAIN_RULES,
    mesh_signature,
    resolve_spmv_shard_axis,
)
