"""Logical-axis partitioner: rules → PartitionSpec/NamedSharding trees.

t5x-style: every parameter dim carries a logical axis name (set in the layer
specs); a rules table maps names to mesh axes per *shape kind*:

* ``train``   — FSDP + TP: ``embed → data`` (ZeRO-style parameter sharding
  over the data axis, all-gathered per layer inside the scan), heads/mlp/
  vocab/experts → ``model``; batch over ``(pod, data)``; gradients reduce
  over ``(pod, data)`` automatically (GSPMD).
* ``prefill/decode/long_decode`` — serving: TP only for dense params (no
  per-layer all-gathers on the latency path), MoE experts spread over the
  *whole* mesh (``(data, model)`` EP — the deepseek-EP layout), KV caches
  sharded over batch/heads, or over sequence when batch=1 (``long_500k``).

Every rule is divisibility-checked against the actual dim; on failure the
next candidate applies (finally: replicated).  That single mechanism absorbs
the awkward cases (49,155-row vocabs, 8-kv-head caches on 16-way TP, batch=1
decodes) without per-arch special-casing — and the fused ``*_heads_x_dim``
parameter layout keeps TP divisible even for 40-head models on 16 devices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models.spec import P

__all__ = ["Partitioner", "ShardingRules", "TRAIN_RULES", "SERVE_RULES",
           "resolve_spmv_shard_axis", "mesh_signature"]

_is_p = lambda x: isinstance(x, P)


def _candidates(x) -> Tuple:
    """Normalize a rule entry to a tuple of candidates (each axis-spec|None)."""
    if x is None:
        return (None,)
    if isinstance(x, list):
        return tuple(x) + (None,)
    return (x, None)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """params: logical-axis name → mesh-axis | tuple-of-axes | list of
    candidates (tried in order).  batch: axes for the batch dim."""
    params: Dict[str, Any]
    batch: Tuple[str, ...] = ("pod", "data")
    act_embed: Optional[str] = None       # residual-stream sharding constraint


TRAIN_RULES = ShardingRules(params={
    "vocab": "model",
    "embed": "data",                      # FSDP
    "q_heads_x_dim": "model",
    "kv_heads_x_dim": "model",
    "mlp": "model",
    "mlp2": None,
    "experts": "model",
    "mla_latent": None,
    "ssm_heads": None,
    "conv_ch": "model",
    "norm": None,
    "layers": None,
    "frontend": None,
    "embed2": None,
    "sparse_rows": "model",
})

SERVE_RULES = ShardingRules(params={
    "vocab": "model",
    "embed": None,                        # no FSDP on the latency path
    "q_heads_x_dim": "model",
    "kv_heads_x_dim": "model",
    "mlp": "model",
    "mlp2": None,
    "experts": [("data", "model"), "model"],   # whole-mesh EP, fallback TP
    "mla_latent": None,
    "ssm_heads": None,
    "conv_ch": "model",
    "norm": None,
    "layers": None,
    "frontend": None,
    "embed2": None,
    "sparse_rows": "model",
})


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([_axis_size(mesh, a) for a in axis]))
    return mesh.shape[axis]


def _filter_axis(mesh: Mesh, axis):
    """Drop mesh axes that don't exist (e.g. 'pod' on the single-pod mesh)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        return kept if kept else None
    return axis if axis in mesh.axis_names else None


def mesh_signature(mesh: Mesh) -> tuple:
    """Value identity of a mesh: axis names, per-axis sizes, device ids.

    Mesh-dependent caches (the sharded SpMV executable memo, warm-plan
    bookkeeping) key on this instead of ``id(mesh)`` alone so a resized or
    rebuilt mesh — same Python id after GC, different topology — can never
    alias a stale entry (DESIGN.md §12).
    """
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in np.asarray(mesh.devices).flat))


def resolve_spmv_shard_axis(mesh: Mesh, shape_kind: str = "decode") -> str:
    """The mesh axis for row-sharded SpMV, or raise with guidance.

    Single source of the lookup-or-raise shared by ``core.spmv`` dispatch
    and ``Engine.warm_spmv_plans`` (DESIGN.md §11 routing).
    """
    axis = Partitioner(mesh, shape_kind).spmv_shard_axis()
    if axis is None:
        raise ValueError(
            f"no mesh axis resolves the 'sparse_rows' rule on mesh axes "
            f"{mesh.axis_names}; pass mesh_axis= explicitly")
    return axis


class Partitioner:
    def __init__(self, mesh: Mesh, shape_kind: str = "train",
                 rules: Optional[ShardingRules] = None):
        self.mesh = mesh
        self.shape_kind = shape_kind
        if rules is None:
            rules = TRAIN_RULES if shape_kind == "train" else SERVE_RULES
        self.rules = rules

    # ------------------------------------------------------------ primitives
    def _dim_spec(self, dim: int, name: Optional[str], used: set):
        for cand in _candidates(self.rules.params.get(name)):
            cand = _filter_axis(self.mesh, cand)
            if cand is None:
                return None
            axes = cand if isinstance(cand, tuple) else (cand,)
            if any(a in used for a in axes):
                continue
            if dim % _axis_size(self.mesh, cand) == 0:
                used.update(axes)
                return cand
        return None

    def _leaf_spec(self, p: P) -> PartitionSpec:
        used: set = set()
        return PartitionSpec(*[self._dim_spec(d, n, used)
                               for d, n in zip(p.shape, p.axes)])

    def _named(self, spec: PartitionSpec) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------------ sparse spmv
    def spmv_shard_axis(self) -> Optional[str]:
        """Mesh axis the ``sparse_rows`` rule resolves to on this mesh.

        This is the routing hook for the row-sharded SpMV path
        (DESIGN.md §11): ``ShardedRgCSR`` splits rows over exactly one mesh
        axis, and both rule tables already map ``sparse_rows → model``.
        Returns the first rule candidate that is a single axis present on
        the mesh (row counts are padded per shard, so no divisibility check
        applies), or ``None`` when every candidate filters away.
        """
        for cand in _candidates(self.rules.params.get("sparse_rows")):
            cand = _filter_axis(self.mesh, cand)
            if cand is None:
                continue
            if isinstance(cand, tuple):   # row shards need a single 1-D axis
                cand = cand[0] if len(cand) == 1 else None
                if cand is None:
                    continue
            return cand
        return None

    def spmv_shard_count(self) -> int:
        """Device count of the resolved SpMV row-shard axis (1 = unsharded)."""
        axis = self.spmv_shard_axis()
        return 1 if axis is None else int(self.mesh.shape[axis])

    # ---------------------------------------------------------------- params
    def param_specs(self, spec_tree):
        return jax.tree_util.tree_map(self._leaf_spec, spec_tree, is_leaf=_is_p)

    def param_shardings(self, spec_tree):
        return jax.tree_util.tree_map(
            lambda p: self._named(self._leaf_spec(p)), spec_tree, is_leaf=_is_p)

    # ------------------------------------------------------------- optimizer
    def opt_shardings(self, spec_tree, opt_name: str,
                      factored_min_dim: int = 2):
        """Sharding tree matching optimizer.init(params)' structure."""
        rep = self._named(PartitionSpec())

        if opt_name == "adamw":
            import jax.numpy as jnp

            def moment(p: P):
                # integer buffers (frozen RgCSR structure) carry scalar
                # placeholder moments — replicated
                if p.dtype is not None and not jnp.issubdtype(
                        p.dtype, jnp.floating):
                    return rep
                return self._named(self._leaf_spec(p))

            moments = jax.tree_util.tree_map(moment, spec_tree, is_leaf=_is_p)
            return {"step": rep, "m": moments, "v": moments}

        def stats(p: P):
            if len(p.shape) >= factored_min_dim:
                used_r: set = set()
                vr = PartitionSpec(*[self._dim_spec(d, n, used_r) for d, n in
                                     zip(p.shape[:-1], p.axes[:-1])])
                used_c: set = set()
                vc_dims = list(zip(p.shape[:-2], p.axes[:-2])) \
                    + [(p.shape[-1], p.axes[-1])]
                vc = PartitionSpec(*[self._dim_spec(d, n, used_c)
                                     for d, n in vc_dims])
                return {"vr": self._named(vr), "vc": self._named(vc)}
            return {"v": rep}

        return {"step": rep,
                "stats": jax.tree_util.tree_map(stats, spec_tree, is_leaf=_is_p)}

    # ----------------------------------------------------------------- batch
    def _batch_dim(self, b: int):
        axes = _filter_axis(self.mesh, tuple(self.rules.batch))
        if axes and b % _axis_size(self.mesh, axes) == 0:
            return axes
        return None

    def batch_shardings(self, batch_tree):
        def leaf(x):
            b = x.shape[0] if getattr(x, "ndim", 0) else 1
            spec = [self._batch_dim(b)] + [None] * (max(0, x.ndim - 1))
            return self._named(PartitionSpec(*spec))
        return jax.tree_util.tree_map(leaf, batch_tree)

    # ----------------------------------------------------------------- cache
    def cache_shardings(self, cache_tree):
        """KV/state cache shardings by leaf name (path-aware)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
        out = []
        for path, leaf in flat:
            keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
            name = keys[-1] if keys else None
            stacked = "body" in keys          # leading (layers,) dim
            out.append(self._named(self._cache_leaf_spec(name, leaf, stacked)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def _cache_leaf_spec(self, name, leaf, stacked: bool) -> PartitionSpec:
        nd = leaf.ndim - (1 if stacked else 0)
        prefix = [None] if stacked else []
        if name in ("index", "block_table") or nd == 0:
            return PartitionSpec(*([None] * leaf.ndim))
        used: set = set()

        def dim(d, cands):
            for c in cands:
                c = _filter_axis(self.mesh, c)
                if c is None:
                    continue
                axes = c if isinstance(c, tuple) else (c,)
                if any(a in used for a in axes):
                    continue
                if d % _axis_size(self.mesh, c) == 0:
                    used.update(axes)
                    return c
            return None

        shape = leaf.shape[1:] if stacked else leaf.shape
        batch_c = [tuple(self.rules.batch), "data"]
        long_seq = self.shape_kind == "long_decode"
        if name in ("k", "v", "k_scale", "v_scale", "ck", "cv"):
            # (B, S, H, Dh)
            spec = [dim(shape[0], batch_c),
                    dim(shape[1], ["data"] if long_seq else []),
                    dim(shape[2], ["model"]),
                    dim(shape[3], ["model"])]
        elif name in ("ckv", "krope"):
            # (B, S, R)
            spec = [dim(shape[0], batch_c),
                    dim(shape[1], ["data"] if long_seq else []),
                    dim(shape[2], ["model"])]
        elif name == "ssm":
            # (B, H, P, N)
            spec = [dim(shape[0], batch_c), dim(shape[1], ["model"]),
                    None, None]
        elif name == "conv":
            # (B, W-1, C)
            spec = [dim(shape[0], batch_c), None, dim(shape[2], ["model"])]
        elif name == "h":
            # (B, D)
            spec = [dim(shape[0], batch_c), dim(shape[1], ["model"])]
        else:
            spec = [dim(shape[0], batch_c)] + [None] * (nd - 1)
        return PartitionSpec(*(prefix + spec))

    # ---------------------------------------------------------------- output
    def logits_sharding(self, batch: int):
        return self._named(PartitionSpec(self._batch_dim(batch), None, None))

    def replicated(self):
        return self._named(PartitionSpec())
