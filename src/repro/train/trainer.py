"""Trainer: pjit'd step + data + checkpoints + watchdog + restart loop.

Composes the substrate: launch/steps.py (jit'd train step with microbatch
accumulation), train/data.py (deterministic stream), train/checkpoint.py
(atomic async checkpoints), train/fault.py (watchdog + restartable loop).
Works on a single CPU device (tests/examples) and on a production mesh
(launch/train.py) with the same code path — the partitioner simply returns
replicated shardings when no mesh is given.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.launch.steps import make_train_step
from repro.models import LanguageModel
from repro.train.checkpoint import CheckpointManager, latest_step
from repro.train.data import DataConfig, make_batch
from repro.train.fault import FaultConfig, FaultInjector, RestartableLoop, \
    Watchdog
from repro.train.optimizer import OptimizerConfig

log = logging.getLogger("repro.trainer")

__all__ = ["TrainConfig", "Trainer"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    seed: int = 0
    opt: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    fault: FaultConfig = dataclasses.field(default_factory=FaultConfig)


class Trainer:
    def __init__(self, model_cfg, train_cfg: TrainConfig, *, mesh=None,
                 partitioner=None, fault_injector: Optional[FaultInjector]
                 = None):
        self.cfg = train_cfg
        self.model = LanguageModel(model_cfg)
        self.mesh = mesh
        self.fault_injector = fault_injector
        self.data_cfg = DataConfig(
            vocab=model_cfg.vocab,
            seq_len=model_cfg.frontend_tokens + 32
            if model_cfg.family == "vlm" else 0,  # replaced below
            global_batch=0,
            family=model_cfg.family,
            d_frontend=model_cfg.d_frontend,
            frontend_tokens=model_cfg.frontend_tokens,
            seed=train_cfg.seed,
        )
        step_fn, opt_init = make_train_step(self.model, train_cfg.opt,
                                            train_cfg.microbatches)
        self.opt_init = opt_init
        if mesh is not None and partitioner is not None:
            spec_tree = self.model.spec()
            p_sh = partitioner.param_shardings(spec_tree)
            o_sh = partitioner.opt_shardings(spec_tree, train_cfg.opt.name)
            self._p_sh, self._o_sh = p_sh, o_sh
            self.train_step = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                                      out_shardings=(p_sh, o_sh, None),
                                      donate_argnums=(0, 1))
        else:
            self._p_sh = self._o_sh = None
            self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir,
                                      keep=train_cfg.ckpt_keep) \
            if train_cfg.ckpt_dir else None
        self.watchdog = Watchdog(train_cfg.fault)
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------------ API
    def init_state(self, seq_len: int, global_batch: int):
        self.data_cfg = dataclasses.replace(
            self.data_cfg, seq_len=seq_len, global_batch=global_batch)
        params = self.model.init(jax.random.PRNGKey(self.cfg.seed))
        opt_state = self.opt_init(params)
        return params, opt_state

    def _batch(self, step: int):
        return make_batch(self.data_cfg, step)

    def run(self, state, start_step: int = 0,
            n_steps: Optional[int] = None):
        """Train with watchdog + checkpointing + restart-on-failure."""
        n_steps = n_steps if n_steps is not None else self.cfg.steps
        loop = RestartableLoop(self.cfg.fault)

        def step_fn(state, step):
            if self.fault_injector:
                self.fault_injector.check(step)
            t0 = time.time()
            params, opt_state = state
            batch = self._batch(step)
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            self.watchdog.observe(step, dt)
            metrics.update(step=step, step_time_s=dt)
            self.history.append(metrics)
            if step % self.cfg.log_every == 0:
                log.info("step %d: loss=%.4f (%.2fs)", step,
                         metrics["loss"], dt)
            if self.ckpt and step and step % self.cfg.ckpt_every == 0:
                self.ckpt.save(step, {"params": params,
                                      "opt_state": opt_state},
                               extra={"data_step": step + 1})
            return params, opt_state

        def restore_fn():
            if not self.ckpt or latest_step(self.cfg.ckpt_dir) is None:
                # no checkpoint yet: restart from scratch (deterministic init)
                params = self.model.init(jax.random.PRNGKey(self.cfg.seed))
                return (params, self.opt_init(params)), start_step
            # structure-only template (live arrays may have been donated)
            params_abs = self.model.abstract_params()
            tree_like = {"params": params_abs,
                         "opt_state": jax.eval_shape(self.opt_init,
                                                     params_abs)}
            restored, manifest = self.ckpt.restore_latest(tree_like)
            log.info("restored checkpoint step %d", manifest["step"])
            return ((restored["params"], restored["opt_state"]),
                    manifest["step"] + 1)

        state, step = loop.run(state, start_step, n_steps, step_fn,
                               restore_fn)
        if self.ckpt:
            self.ckpt.save(step - 1, {"params": state[0],
                                      "opt_state": state[1]})
            self.ckpt.wait()
        return state, step
