"""Training substrate: optimizer, data, checkpointing, fault tolerance.

NOTE: submodules are imported lazily — ``trainer`` imports
``repro.launch.steps`` which imports ``repro.train.optimizer``; an eager
package import here would create a cycle.
"""
from repro.train.optimizer import OptimizerConfig, make_optimizer  # noqa: F401


def __getattr__(name):
    if name in ("TrainConfig", "Trainer"):
        from repro.train import trainer
        return getattr(trainer, name)
    raise AttributeError(name)
