"""Optimizers (AdamW, Adafactor) + LR schedules + global-norm clipping.

Written from scratch (no optax in this environment) with the production
requirements in mind:

* **AdamW** — fp32 moments, decoupled weight decay with a mask (no decay on
  norms/biases/1-D params), bias correction.
* **Adafactor** — factored second moment (row/col RMS) for ≥2-D params:
  the memory-viable choice for the 671B MoE cells (EXPERIMENTS.md §Dry-run
  memory table) — O(n+m) statistics instead of O(n·m), as used by T5/PaLM.
* schedules: linear warmup → cosine/linear/constant decay.

State layout mirrors the param tree (same sharding applies leaf-for-leaf),
so the partitioner shards optimizer state for free — this is what makes the
ZeRO-style "optimizer sharded like params" behaviour fall out of GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "make_optimizer", "warmup_cosine",
           "warmup_linear", "constant", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"                # adamw | adafactor
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    schedule: str = "cosine"           # cosine | linear | constant
    # adafactor
    decay_rate: float = 0.8
    factored_min_dim: int = 2


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def warmup_cosine(cfg: OptimizerConfig):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, cfg.warmup_steps)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
                        0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)
    return fn


def warmup_linear(cfg: OptimizerConfig):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, cfg.warmup_steps)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
                        0.0, 1.0)
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 1.0 - frac)
    return fn


def constant(cfg: OptimizerConfig):
    return lambda step: jnp.full((), cfg.lr, jnp.float32)


def _schedule(cfg: OptimizerConfig):
    return {"cosine": warmup_cosine, "linear": warmup_linear,
            "constant": constant}[cfg.schedule](cfg)


# ---------------------------------------------------------------------------
# utilities
# ---------------------------------------------------------------------------


def _differentiable(x) -> bool:
    """True for real float grads; False for int buffers / float0 tangents
    (e.g. the frozen RgCSR structure tables in SparseLinear)."""
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


def global_norm(tree) -> jax.Array:
    leaves = [x for x in jax.tree_util.tree_leaves(tree) if _differentiable(x)]
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype)
        if _differentiable(g) else g, tree), norm


def _decay_mask(params):
    """True = apply weight decay (2-D+ floating-point params only)."""
    return jax.tree_util.tree_map(
        lambda p: p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating), params)


def _is_float(p):
    return jnp.issubdtype(p.dtype, jnp.floating)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _adamw(cfg: OptimizerConfig):
    sched = _schedule(cfg)

    def init(params):
        zeros = lambda p: (jnp.zeros(p.shape, jnp.float32) if _is_float(p)
                           else jnp.zeros((), jnp.float32))
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr = sched(step)
        b1, b2 = cfg.betas
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        mask = _decay_mask(params)

        def upd(g, m, v, p, decay):
            if not _is_float(p):
                return p, m, v
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh, vh = m / c1, v / c2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.weight_decay:
                delta = delta + jnp.where(decay, cfg.weight_decay, 0.0) \
                    * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     params, mask)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step, "m": new_m, "v": new_v}

    return init, update


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment)
# ---------------------------------------------------------------------------


def _adafactor(cfg: OptimizerConfig):
    sched = _schedule(cfg)

    def _factored(p):
        return _is_float(p) and p.ndim >= cfg.factored_min_dim

    def init(params):
        def stats(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            if _is_float(p):
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            return {"v": jnp.zeros((), jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "stats": jax.tree_util.tree_map(stats, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = sched(step)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay_rate)
        mask = _decay_mask(params)

        def upd(g, st, p, decay):
            if not _is_float(p):
                return p, st
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + 1e-30
            if "vr" in st:
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                v_est = (vr[..., None] * vc[..., None, :]) / denom[..., None]
                delta = g * jax.lax.rsqrt(v_est + 1e-30)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                delta = g * jax.lax.rsqrt(v + 1e-30)
                new_st = {"v": v}
            # update clipping (Adafactor's RMS-1 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(delta)) + 1e-30)
            delta = delta / jnp.maximum(1.0, rms)
            if cfg.weight_decay:
                delta = delta + jnp.where(decay, cfg.weight_decay, 0.0) \
                    * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_st

        # grads/params are array-leaf trees; stats has dict leaves one level
        # deeper — flatten stats up to the grads structure to align them.
        g_leaves, gdef = jax.tree_util.tree_flatten(grads)
        s_leaves = gdef.flatten_up_to(state["stats"])
        p_leaves = gdef.flatten_up_to(params)
        m_leaves = gdef.flatten_up_to(mask)
        pairs = [upd(g, s, p, m) for g, s, p, m in
                 zip(g_leaves, s_leaves, p_leaves, m_leaves)]
        new_params = jax.tree_util.tree_unflatten(gdef, [t[0] for t in pairs])
        new_stats = jax.tree_util.tree_unflatten(gdef, [t[1] for t in pairs])
        return new_params, {"step": step, "stats": new_stats}

    return init, update


def make_optimizer(cfg: OptimizerConfig):
    """Returns (init_fn, update_fn).

    ``update_fn(grads, state, params) -> (new_params, new_state)``; gradient
    clipping is applied by the caller (trainer) so the norm can be logged.
    """
    if cfg.name == "adamw":
        return _adamw(cfg)
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
