"""Fault tolerance: step watchdog, failure classification, restart policy,
straggler mitigation.

What runs here vs. what is documented-only on CPU:

* **Implemented + tested** — the restart loop (exception → restore latest
  checkpoint → seek the data stream → resume), the step-time watchdog
  (EWMA straggler detector), bounded retry with backoff, and fault
  injection hooks used by tests/test_fault.py.  The watchdog and injector
  are shared with the serving engine (DESIGN.md §6.4): ``Engine.serve``
  runs a :class:`Watchdog` over decode-step times (stragglers land in
  ``paging_stats``) and threads a :class:`FaultInjector` through its
  per-request prefill/decode paths for fault-isolation tests.
* **Documented policy (needs a real cluster)** — hot-spare pod promotion
  and ICI-link-failure remapping: on a 1000+-node deployment the watchdog's
  `on_straggler` callback is wired to the cluster scheduler to drain/replace
  the slow host; here it logs and (optionally) triggers an elastic re-shard
  through checkpoint.restore_sharded onto the surviving mesh — which IS
  exercised by tests (256→128-device re-layout under the dry-run device
  count).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

__all__ = ["FaultConfig", "Watchdog", "RestartableLoop", "FaultInjector",
           "ProcessKilled"]

log = logging.getLogger("repro.fault")


class ProcessKilled(RuntimeError):
    """A ``("process", k)`` fault site fired: the whole serving process is
    presumed lost — every replica, every session, every in-memory queue.

    Deliberately NOT a replica-tier fault: the router re-raises it instead
    of migrating (there is no surviving replica to migrate to).  The crash
    drill (DESIGN.md §7.6) catches it at the top level, rebuilds the fleet
    from params, and restores the latest snapshot."""


@dataclasses.dataclass
class FaultConfig:
    max_restarts: int = 3
    backoff_s: float = 0.1
    straggler_ewma_alpha: float = 0.1
    straggler_factor: float = 2.0      # step > factor × EWMA → straggler
    min_samples: int = 5


class Watchdog:
    """EWMA step-time tracker; flags stragglers (slow steps/hosts).

    A flagged step's ``dt`` is **clamped to the flagging threshold**
    (``straggler_factor × EWMA``) before it feeds the EWMA: folding the
    raw outlier in used to inflate the baseline so fast that a sustained
    slowdown stopped being flagged after a single alert.  With the clamp
    the baseline still adapts — geometrically, one clamped update at a
    time — so a host that is *permanently* slower eventually becomes the
    new normal (bounded alert stream), but a step-function slowdown is
    flagged for several consecutive steps first, long enough for a
    router/scheduler health policy to act on it.
    """

    def __init__(self, cfg: FaultConfig,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.cfg = cfg
        self.ewma: Optional[float] = None
        self.n = 0
        self.events = []
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if flagged as straggler."""
        flagged = False
        if self.ewma is not None and self.n >= self.cfg.min_samples \
                and dt > self.cfg.straggler_factor * self.ewma:
            flagged = True
            self.events.append((step, dt, self.ewma))
            log.warning("straggler: step %d took %.3fs (EWMA %.3fs)",
                        step, dt, self.ewma)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        a = self.cfg.straggler_ewma_alpha
        # clamp flagged outliers at the threshold so one straggler can't
        # poison the baseline (see class docstring)
        d = min(dt, self.cfg.straggler_factor * self.ewma) if flagged else dt
        self.ewma = d if self.ewma is None else (1 - a) * self.ewma + a * d
        self.n += 1
        return flagged


class FaultInjector:
    """Test hook: raise at chosen steps (simulates node/request failure).

    ``fail_at_steps`` entries are either bare ints (site-agnostic — the
    train loop's ``check(step)`` matches them) or ``(site, step)`` tuples
    for site-qualified injection: the serving engine threads
    ``check(k, site="prefill")`` / ``check(k, site="decode")`` through its
    per-request paths, so a fault can target "the 3rd prefill this serve
    call" or "a request committing its 2nd generated token" without
    touching the engine.  Each entry fires exactly once (then it is
    discarded), so injection is deterministic regardless of how many
    requests reach the same step count; fired entries are recorded in
    ``self.fired`` for assertions.

    Two sites have non-raising / non-default semantics (DESIGN.md §7.6):

    * ``("process", k)`` raises :class:`ProcessKilled` (never ``exc``) —
      whole-process loss; checked with ``exact=True`` so bare ints can't
      accidentally escalate a request fault to a process death;
    * ``("page", idx)`` / ``("page_nan", idx)`` entries don't raise at
      all: the engine drains them via :meth:`take` at chunk-commit
      boundaries and *corrupts KV page* ``idx`` in place — silent
      device-memory corruption, detected later by the integrity layer.
    """

    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.armed = True
        self.fired = []

    def check(self, step: int, site: Optional[str] = None,
              exact: bool = False):
        """Raise if an armed entry matches.  ``exact=True`` matches ONLY
        the ``(site, step)`` tuple — bare site-agnostic ints are ignored
        (used for the ``"process"`` site, where a stray bare int must not
        escalate to a whole-process death)."""
        if not self.armed:
            return
        if exact:
            keys = ((site, step),)
        else:
            keys = (step,) if site is None else ((site, step), step)
        for key in keys:
            if key in self.fail_at:
                self.fail_at.discard(key)
                self.fired.append((site, step))
                exc = ProcessKilled if site == "process" else self.exc
                raise exc(f"injected fault at {site or 'step'} {step}")

    def next_armed(self, site: Optional[str], start: int,
                   stop: int, exact: bool = False) -> Optional[int]:
        """Smallest armed step in ``[start, stop)`` that ``check(step,
        site=site)`` would fire on (site-qualified tuples and bare
        site-agnostic ints both count, unless ``exact``), or ``None``.
        The serving engine's fused decode loop uses this to split a chunk
        exactly at an injected replica/process fault, so chunked serving
        fires faults at the same decode-step index the stepwise cadence
        did."""
        if not self.armed:
            return None
        hits = [s for s in range(start, stop)
                if (site, s) in self.fail_at
                or (not exact and s in self.fail_at)]
        return min(hits) if hits else None

    def take(self, site: str) -> Optional[int]:
        """Pop and return the smallest armed index for ``site`` WITHOUT
        raising, or ``None``.  This is the corruption-site drain: the
        engine calls ``take("page")`` at each chunk-commit boundary and
        scribbles over the returned page — the fault is the *corruption*,
        not an exception, so detection must come from the integrity
        layer."""
        if not self.armed:
            return None
        hits = sorted(k[1] for k in self.fail_at
                      if isinstance(k, tuple) and k[0] == site)
        if not hits:
            return None
        idx = hits[0]
        self.fail_at.discard((site, idx))
        self.fired.append((site, idx))
        return idx


class RestartableLoop:
    """Run a step function with restart-from-checkpoint on failure.

    ``run(state, start_step, n_steps, step_fn, restore_fn)`` where
    ``step_fn(state, step) -> state`` and ``restore_fn() -> (state, step)``
    reloads the latest checkpoint.  Deterministic data (train/data.py) makes
    the recovery exact: the replayed steps see identical batches.

    ``sleep=`` / ``clock=`` are injectable (matching ``Engine.clock`` /
    ``Router.clock``): the restart backoff sleeps through ``sleep`` and
    each restart is stamped with ``clock()`` into ``restart_log`` as
    ``(failed_step, backoff_s, t)`` — so tests assert the exact backoff
    schedule on a fake timer instead of burning real wall-clock.
    """

    def __init__(self, cfg: FaultConfig, sleep: Optional[Callable] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        self.restarts = 0
        # resolved lazily so monkeypatching repro.train.fault.time still
        # works for callers that construct the loop first
        self._sleep = sleep
        self._clock = clock
        self.restart_log = []

    def run(self, state, start_step: int, n_steps: int, step_fn,
            restore_fn):
        sleep = self._sleep if self._sleep is not None else time.sleep
        clock = self._clock if self._clock is not None else time.time
        step = start_step
        end = start_step + n_steps
        while step < end:
            try:
                state = step_fn(state, step)
                step += 1
            except Exception as e:  # noqa: BLE001 — any step failure
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    log.error("restart budget exhausted (%d)", self.restarts)
                    raise
                log.warning("step %d failed (%r); restoring (restart %d/%d)",
                            step, e, self.restarts, self.cfg.max_restarts)
                backoff = self.cfg.backoff_s * self.restarts
                self.restart_log.append((step, backoff, clock()))
                sleep(backoff)
                state, step = restore_fn()
        return state, step
