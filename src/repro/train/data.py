"""Deterministic synthetic LM data pipeline, per-host sharded.

Production properties this reproduces:

* **Determinism / replayability** — every batch is a pure function of
  ``(seed, step, host)``: restart-from-checkpoint replays the exact stream
  with no data-loader state to save (the fault-tolerance path in
  train/fault.py relies on this).
* **Per-host sharding** — each host generates only its shard of the global
  batch (``host_id``/``n_hosts``), matching multi-host jax.Array creation.
* **Structured tokens** — Zipf-distributed unigrams mixed with short
  Markov-ish repeats so the loss actually decreases (pure-uniform tokens
  would pin CE at log V and mask training bugs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "make_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.2
    repeat_prob: float = 0.3           # P(copy a recent token) — learnable
    family: str = "dense"              # vlm/audio need frontend stubs
    d_frontend: int = 0
    frontend_tokens: int = 0


def _token_block(rng: np.random.Generator, cfg: DataConfig, b: int,
                 s: int) -> np.ndarray:
    base = rng.zipf(cfg.zipf_alpha, size=(b, s)).astype(np.int64)
    tokens = (base - 1) % cfg.vocab
    # inject copy-structure: with prob p, token t = token t-k (k in 1..8)
    copy_mask = rng.uniform(size=(b, s)) < cfg.repeat_prob
    lags = rng.integers(1, 9, size=(b, s))
    idx = np.maximum(np.arange(s)[None, :] - lags, 0)
    copied = np.take_along_axis(tokens, idx, axis=1)
    tokens = np.where(copy_mask, copied, tokens)
    return tokens.astype(np.int32)


def make_batch(cfg: DataConfig, step: int, *, host_id: int = 0,
               n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """The batch for ``step`` (this host's shard)."""
    assert cfg.global_batch % n_hosts == 0
    b = cfg.global_batch // n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_id]))
    s = cfg.seq_len
    out: Dict[str, np.ndarray] = {}
    if cfg.family == "vlm":
        ft = cfg.frontend_tokens
        text = _token_block(rng, cfg, b, s - ft + 1)
        out["patch_embeds"] = rng.standard_normal(
            (b, ft, cfg.d_frontend)).astype(np.float32)
        out["tokens"] = text[:, :-1]
        out["labels"] = text[:, 1:]
    elif cfg.family == "audio":
        text = _token_block(rng, cfg, b, s + 1)
        out["frames"] = rng.standard_normal(
            (b, s, cfg.d_frontend)).astype(np.float32)
        out["tokens"] = text[:, :-1]
        out["labels"] = text[:, 1:]
    else:
        text = _token_block(rng, cfg, b, s + 1)
        out["tokens"] = text[:, :-1]
        out["labels"] = text[:, 1:]
    return out


class SyntheticLM:
    """Iterator facade with explicit step addressing (seekable)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1,
                 start_step: int = 0):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = make_batch(self.cfg, self.step, host_id=self.host_id,
                           n_hosts=self.n_hosts)
        self.step += 1
        return batch

    def seek(self, step: int) -> "SyntheticLM":
        self.step = step
        return self
