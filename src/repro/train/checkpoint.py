"""Checkpointing: atomic save/restore + async writer + elastic re-shard.

Layout per step::

    <dir>/step_000123/
        manifest.json       # step, config digest, mesh shape, tree structure
        arrays.npz          # flattened leaves (host numpy)
    <dir>/LATEST            # atomically-updated pointer file

Production properties:

* **Atomicity** — written to ``step_N.tmp`` then ``os.rename``d; a crash
  mid-write never corrupts the restore point (``LATEST`` only advances
  after the rename).
* **Async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and writes on a worker thread, overlapping I/O with the next train steps.
* **Elastic re-shard** — restore returns host arrays + the manifest's mesh
  shape; ``restore_sharded`` re-lays them out onto *any* new mesh via
  ``jax.device_put`` with freshly resolved shardings, so a job can restart
  on a different pod count (EXPERIMENTS.md §Dry-run / fault drill).
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "restore_sharded",
           "latest_step", "CheckpointManager", "save_snapshot",
           "restore_snapshot", "latest_snapshot", "SnapshotManager"]


def _flatten_with_keys(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None):
    """Synchronous atomic checkpoint write."""
    keys, leaves, _ = _flatten_with_keys(tree)
    host = [np.asarray(x) for x in leaves]
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": a for i, a in enumerate(host)})
    manifest = {
        "step": step,
        "keys": keys,
        "dtypes": [str(a.dtype) for a in host],
        "shapes": [list(a.shape) for a in host],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _update_latest(ckpt_dir, step)
    return final


def _update_latest(ckpt_dir: str, step: int):
    ptr = os.path.join(ckpt_dir, "LATEST")
    tmp = ptr + ".tmp"
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, ptr)


_EXECUTOR = concurrent.futures.ThreadPoolExecutor(max_workers=1)


def save_async(ckpt_dir: str, step: int, tree, *, extra=None):
    """Snapshot to host now, write on a worker thread. Returns a Future."""
    keys, leaves, _ = _flatten_with_keys(tree)
    host = [np.asarray(x) for x in leaves]  # device→host sync point

    def _write():
        fake_tree = None  # we already flattened
        final = os.path.join(ckpt_dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(host)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": keys,
                       "dtypes": [str(a.dtype) for a in host],
                       "shapes": [list(a.shape) for a in host],
                       "extra": extra or {}}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _update_latest(ckpt_dir, step)
        return final

    return _EXECUTOR.submit(_write)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None
            ) -> Tuple[Any, Dict]:
    """Restore to host numpy arrays in the structure of ``tree_like``."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays.npz"))
    host = [data[f"a{i}"] for i in range(len(manifest["keys"]))]
    keys, _, treedef = _flatten_with_keys(tree_like)
    if keys != manifest["keys"]:
        raise ValueError(
            "checkpoint tree mismatch: "
            f"{set(keys) ^ set(manifest['keys'])} (config change?)")
    tree = jax.tree_util.tree_unflatten(treedef, host)
    return tree, manifest


def restore_sharded(ckpt_dir: str, tree_like, shardings,
                    step: Optional[int] = None):
    """Restore + lay out on a (possibly different) mesh: elastic restart."""
    tree, manifest = restore(ckpt_dir, tree_like, step)
    flat_t, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = treedef.flatten_up_to(shardings)
    placed = [jax.device_put(a, s) for a, s in zip(flat_t, flat_s)]
    return jax.tree_util.tree_unflatten(treedef, placed), manifest


class CheckpointManager:
    """Rolling checkpoints with retention + async hand-off."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_write: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[concurrent.futures.Future] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree, extra=None):
        self.wait()
        self._gc()  # prune BEFORE submitting: the new write must not race GC
        if self.async_write:
            fut = save_async(self.dir, step, tree, extra=extra)
            fut.add_done_callback(lambda _: self._gc())
            self._pending = fut
        else:
            save(self.dir, step, tree, extra=extra)
            self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None
            # The done-callback's _gc runs on the executor thread and is not
            # ordered with respect to result() returning — prune here too so
            # retention is guaranteed once wait() returns.
            self._gc()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def restore_latest(self, tree_like, shardings=None):
        self.wait()
        if shardings is None:
            return restore(self.dir, tree_like)
        return restore_sharded(self.dir, tree_like, shardings)


# ---------------------------------------------------------------------------
# serving snapshots (DESIGN.md §7.6): small JSON state dicts — session /
# router snapshot(), not parameter trees — written with the same atomic
# tmp + os.replace discipline and LATEST pointer as the step checkpoints
# ---------------------------------------------------------------------------


def save_snapshot(snap_dir: str, seq: int, state: Dict) -> str:
    """Atomic write of one serving snapshot (``snap_<seq>.json``): the
    payload lands in a ``.tmp`` first and ``os.replace`` publishes it, so
    a crash mid-write never corrupts a restore point; the ``LATEST``
    pointer only advances after the publish."""
    os.makedirs(snap_dir, exist_ok=True)
    final = os.path.join(snap_dir, f"snap_{seq:09d}.json")
    tmp = final + ".tmp"
    with open(tmp, "w") as f:
        json.dump(state, f)
    os.replace(tmp, final)
    _update_latest(snap_dir, seq)
    return final


def latest_snapshot(snap_dir: str) -> Optional[int]:
    """Sequence number of the newest published snapshot, or None."""
    return latest_step(snap_dir)


def restore_snapshot(snap_dir: str, seq: Optional[int] = None) -> Dict:
    """Load snapshot ``seq`` (default: the LATEST pointer's)."""
    if seq is None:
        seq = latest_snapshot(snap_dir)
        if seq is None:
            raise FileNotFoundError(f"no snapshot under {snap_dir}")
    with open(os.path.join(snap_dir, f"snap_{seq:09d}.json")) as f:
        return json.load(f)


class SnapshotManager:
    """Rolling serving snapshots with retention (the serving analogue of
    :class:`CheckpointManager` — synchronous, since the payload is a few
    KB of host JSON, not device arrays).  ``save(state)`` auto-increments
    the sequence; ``restore_latest()`` returns ``(state, seq)``."""

    def __init__(self, snap_dir: str, keep: int = 3):
        self.dir = snap_dir
        self.keep = keep
        os.makedirs(snap_dir, exist_ok=True)

    @property
    def next_seq(self) -> int:
        latest = latest_snapshot(self.dir)
        return 0 if latest is None else latest + 1

    def save(self, state: Dict, seq: Optional[int] = None) -> str:
        path = save_snapshot(self.dir, self.next_seq if seq is None
                             else seq, state)
        self._gc()
        return path

    def restore_latest(self) -> Tuple[Dict, int]:
        seq = latest_snapshot(self.dir)
        if seq is None:
            raise FileNotFoundError(f"no snapshot under {self.dir}")
        return restore_snapshot(self.dir, seq), seq

    def _gc(self):
        seqs = sorted(
            int(f[5:-5]) for f in os.listdir(self.dir)
            if f.startswith("snap_") and f.endswith(".json"))
        for s in seqs[: -self.keep]:
            try:
                os.remove(os.path.join(self.dir, f"snap_{s:09d}.json"))
            except OSError:
                pass
