"""Serving engine: batched prefill + decode with KV caches.

Production structure on the latency path:

* jit'd ``prefill`` (prompt → logits + caches) and ``decode`` (one token,
  donated cache) — the same functions the decode dry-run cells lower, so
  serving perf analysis and the roofline table talk about identical HLO.
* **Continuous mixed-length batching**: a fixed decode batch of
  ``n_slots`` with a **per-slot KV position index**, so requests of any
  prompt length share one live batch and a finished slot immediately pulls
  the next queued request — no cache resets, no drain barriers.
* **Paged KV cache** (``kv_layout="paged"``, the default — DESIGN.md §6,
  ``serve/paging.py``): K/V live in a shared page pool addressed through
  per-slot block tables; pages are allocated lazily as slots grow and
  freed on completion, so resident KV memory tracks *actual* sequence
  lengths.  ``kv_layout="dense"`` keeps the per-slot ``(n_slots, S_max)``
  slabs (still per-slot-indexed, so mixed lengths work there too) — the
  layout ``generate()`` and training-eval equivalence use.
* **Graceful overload** (DESIGN.md §6.4): admission reserves prompt pages
  only (``admission_policy="prompt"``) and decode-boundary pool
  exhaustion **recompute-preempts** the latest-admitted slot instead of
  blocking; oversized requests are rejected per-request, mid-request
  faults fail only the affected request, and per-request deadlines shed
  expired work — each terminal outcome lands in ``Request.status``
  (``worst_case`` admission + ``strict=True`` restore the PR 5
  defer/fail-stop behavior).  A ``train/fault.py`` Watchdog flags
  straggler decode steps into ``paging_stats``.
* Sampling: greedy / temperature / top-k, fp32 logits.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LanguageModel
from repro.serve import paging

__all__ = ["ServeConfig", "Engine", "Request"]


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    n_slots: int = 4                    # decode batch size
    temperature: float = 0.0            # 0 → greedy
    top_k: int = 0
    eos_id: int = -1                    # -1 → run to max_new_tokens
    seed: int = 0
    # --- KV-cache layout (DESIGN.md §6) ---
    kv_layout: str = "paged"            # paged | dense
    page_size: int = 16                 # tokens per KV page
    n_pages: int = 0                    # 0 → auto: dense capacity + null page
    # --- overload behavior (DESIGN.md §6.4) ---
    # prompt     → admit on the resident tokens' pages only and
    #              recompute-preempt a victim at decode-boundary exhaustion
    # worst_case → reserve each request's worst case at admission and
    #              defer admissions when the pool can't cover it (PR 5)
    admission_policy: str = "prompt"
    # strict=True restores fail-stop serving: oversized requests and
    # mid-request exceptions raise out of serve() (the pre-overload-layer
    # behavior) instead of failing only the affected request.
    strict: bool = False
    # default completion deadline (seconds from serve() entry) applied to
    # requests that don't carry their own ``deadline_s``; 0 → no deadline.
    deadline_s: float = 0.0


@dataclasses.dataclass
class Request:
    """One serving request.

    Terminal state (set by ``serve``): ``done`` flips True exactly once,
    and ``status`` says how the request ended —

    * ``"ok"``            — completed normally;
    * ``"preempted_<n>"`` — completed normally after ``n`` recompute
      preemptions (still a success — ``ok_like`` covers both);
    * ``"rejected"``      — refused at admission (budget overflows
      ``max_seq``, or its worst-case page count exceeds the whole pool);
    * ``"failed"``        — a mid-request exception (prefill/decode fault)
      killed this request; the rest of the batch kept serving;
    * ``"timed_out"``     — its ``deadline_s`` passed (queued or
      mid-decode); partial output is kept in ``out``.

    ``error`` carries the reason for the three failure statuses.
    ``deadline_s`` is a completion deadline in seconds measured from the
    ``serve()`` call's entry (it bounds queue wait + processing; ``None``
    falls back to ``ServeConfig.deadline_s``).

    Timing fields (all seconds, set by ``serve``):

    * ``queue_s``   — time from ``serve()`` entry until this request was
      first slotted (head-of-line wait).
    * ``prefill_s`` — its own (first) prefill forward duration.
    * ``latency_s`` — end-to-end latency measured from *this request's own
      processing start* (first slotting) to its completion — NOT from the
      start of the whole serve call, which would bill earlier requests'
      work to late-slotted ones.
    """
    tokens: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    out: Optional[List[int]] = None
    done: bool = False
    deadline_s: Optional[float] = None
    status: str = "ok"
    error: Optional[str] = None
    preemptions: int = 0
    latency_s: float = 0.0
    queue_s: float = 0.0
    prefill_s: float = 0.0

    @property
    def ok_like(self) -> bool:
        """Completed with full output (possibly after preemptions)."""
        return self.done and (self.status == "ok"
                              or self.status.startswith("preempted"))


class Engine:
    def __init__(self, model_cfg, serve_cfg: ServeConfig, params=None,
                 fault_cfg=None, fault_injector=None):
        from repro.train.fault import FaultConfig
        self.cfg = serve_cfg
        # fault/overload knobs (DESIGN.md §6.4): the watchdog config drives
        # straggler flagging of decode steps; an engine-level injector (or
        # one passed to serve()) exercises per-request fault isolation.
        self.fault_cfg = fault_cfg if fault_cfg is not None else FaultConfig()
        self.fault_injector = fault_injector
        # injectable clock: every serve() timestamp (deadlines, latency,
        # watchdog) flows through this, so tests drive deadlines with a
        # fake timer instead of wall-clock sleeps.
        self.clock = time.time
        self.model = LanguageModel(model_cfg)
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(serve_cfg.seed))
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.cfg.max_seq),
            static_argnums=())
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        # paging observability from the most recent serve() call
        self.paging_stats: Optional[Dict] = None
        # Sparse (RgCSR) weights: pre-stage kernel plan containers at model
        # load for eager per-layer paths (DESIGN.md §3.2).  The jit'd
        # prefill/decode below assemble their plans at trace time, so the
        # latency path pays no per-call host plan work either way; warming
        # is a no-op for layer-stacked param trees (plans_warmed == 0).
        self.plans_warmed = 0
        self.spmv_plans_warmed = 0
        self.sharded_spmv_plans_warmed = 0
        # append-only observability log: one small host dict per warmed
        # (matrix, mesh) — deliberately never pruned, unlike _warm_sharded
        # below, which holds device arrays and must release superseded plans
        self.sharded_spmv_shard_stats: List[Dict] = []
        # strong refs keep the sharded-plan cache entries alive; keyed on
        # (mesh signature, x_mode, exact matrix content) so re-warming the
        # same matrix on the same mesh REPLACES its entry — the superseded
        # plan's device arrays are released to the weakref-evicted caches
        # instead of accumulating for the engine's lifetime.  The key must
        # be the exact content (not the tuner's log2-bucketed signature):
        # two distinct matrices sharing a bucket must both stay warmed.
        self._warm_sharded: Dict[tuple, tuple] = {}
        if model_cfg.sparsity.enabled and model_cfg.sparsity.impl_is_kernel():
            from repro.kernels import ops as kops
            # warm at the model's compute dtype — the dtype the eager apply
            # path will request (a float32 default would never be hit under
            # the bfloat16 default config)
            self.plans_warmed = kops.warm_plans_from_params(
                self.params, dtype=jnp.dtype(model_cfg.dtype))

    def warm_spmv_plans(self, matrices, *, repeats: int = 1, mesh=None,
                        mesh_axis: Optional[str] = None,
                        x_mode: str = "replicated",
                        per_shard_tune: bool = True):
        """Pre-tune and stage SpMV plans for auxiliary sparse matrices.

        Serving deployments that also answer SpMV traffic (iterative
        solvers, graph scoring) hand their matrices here at startup: each
        one runs the joint autotune search — ``(chunks_per_step,
        group_size, ordering, spill_threshold)``, DESIGN.md §5 — and the
        winning plan (block or adaptive, whichever measured faster) lands
        in the process-wide ``PLAN_CACHE`` before the first request.

        Contract: the warmed entries are keyed to the tuner's own RgCSR
        containers (retained per matrix signature), so the request path
        hits them by fetching through ``autotune.tuned_plan(dense)`` —
        a signature-memo hit, no re-timing, no plan rebuild.  A caller
        that instead runs ``core.spmv`` on its *own* RgCSR object gets a
        fresh plan under that object's identity and must thread the
        returned config's ``(ordering, spill_threshold, chunks_per_step)``
        itself.  Returns the winning
        :class:`repro.kernels.autotune.TuneConfig` per matrix, in order.

        With ``mesh`` set, each matrix is additionally row-sharded over the
        resolved mesh axis (``mesh_axis`` or the partitioner's
        ``sparse_rows`` rule) and, with ``per_shard_tune`` (the default),
        **each shard is tuned independently** (DESIGN.md §11,
        ``autotune.autotune_spmv_per_shard``): the heavy shard of a skewed
        matrix gets spill/adaptive while light shards keep plain block
        cps>1, all at the global winner's ``group_size`` so the stacked
        plan stays uniform.  The stacked shard_map plan is built at those
        per-shard winners and staged in the sharded plan cache — keyed on
        the shard/device count, so re-warming on a resized mesh builds a
        fresh plan instead of reusing a stale stacked one.  Per-matrix
        shard stats (slots, steps, remote columns, exchange volume per the
        §11 sparse-collective schedule, per-shard winner configs) land in
        ``sharded_spmv_shard_stats``.  The sharded matrices are retained
        on the engine so the cache entries survive warmup.
        """
        from repro.kernels import autotune
        winners = []
        if mesh is not None and mesh_axis is None:
            from repro.sharding import resolve_spmv_shard_axis
            mesh_axis = resolve_spmv_shard_axis(mesh)
        for dense in matrices:
            dense = np.asarray(dense)
            _, result = autotune.tuned_plan(dense, repeats=repeats)
            winners.append(result.config)
            if mesh is not None:
                from repro.core.formats import ShardedRgCSR
                from repro.kernels import ops as kops
                from repro.sharding import mesh_signature
                cfg = result.config
                n_shards = int(mesh.shape[mesh_axis])
                shard_cfgs = None
                if per_shard_tune:
                    shard_results = autotune.autotune_spmv_per_shard(
                        dense, n_shards, group_size=cfg.group_size,
                        repeats=repeats, x_mode=x_mode)
                    shard_cfgs = autotune.harmonize_shard_winners(
                        shard_results)
                sm = ShardedRgCSR.from_dense(
                    dense, n_shards=n_shards, group_size=cfg.group_size)
                splan = kops.get_sharded_plan(
                    sm, chunks_per_step=cfg.chunks_per_step,
                    ordering=cfg.ordering,
                    spill_threshold=cfg.spill_threshold, x_mode=x_mode,
                    shard_configs=shard_cfgs)
                content = hashlib.sha1(
                    np.ascontiguousarray(dense).tobytes()).hexdigest()
                self._warm_sharded[(mesh_signature(mesh), x_mode,
                                    dense.shape, str(dense.dtype),
                                    content)] = (sm, splan)
                self.sharded_spmv_plans_warmed += 1
                self.sharded_spmv_shard_stats.append({
                    "n_shards": splan.n_shards,
                    "mesh": mesh_signature(mesh),
                    "x_mode": splan.x_mode,
                    "stored_slots": list(splan.shard_stored_slots),
                    "num_steps": list(splan.shard_num_steps),
                    "remote_cols": list(splan.shard_remote_cols),
                    "exchange_recv_cols": list(
                        splan.shard_exchange_recv_cols),
                    "exchange_send_cols": list(
                        splan.shard_exchange_send_cols),
                    "exchange_bytes": list(splan.shard_exchange_bytes),
                    "kernel_chunks_per_step": splan.chunks_per_step,
                    "shard_winners": [list(c) for c in splan.shard_configs],
                })
        self.spmv_plans_warmed += len(winners)
        return winners

    def plan_cache_stats(self):
        """Plan-cache counters: the matrix PlanCache (core spmv dispatch)
        and the SparseLinear param-plan memo (this engine's sparse layers),
        plus how many plans this engine warmed at init."""
        from repro.kernels import ops as kops
        return {"plan_cache": kops.PLAN_CACHE.stats(),
                "param_plans": kops.param_plan_stats(),
                "sharded_plan_cache": kops.sharded_plan_cache_stats(),
                "plans_warmed": self.plans_warmed,
                "spmv_plans_warmed": self.spmv_plans_warmed,
                "sharded_spmv_plans_warmed": self.sharded_spmv_plans_warmed}

    # ---------------------------------------------------------------- sample
    def _sample(self, logits) -> jax.Array:
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        logits = logits / self.cfg.temperature
        # clamp top_k to the vocab: k >= vocab keeps every token (the sort
        # index -k would otherwise read out of range), k <= 0 disables.
        k = min(int(self.cfg.top_k), logits.shape[-1])
        if 0 < k < logits.shape[-1]:
            kth = jnp.sort(logits, axis=-1)[:, -k][:, None]
            logits = jnp.where(logits < kth, -1e30, logits)
        return jax.random.categorical(sub, logits).astype(jnp.int32)

    # ------------------------------------------------------------- one-shot
    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32
                 ) -> np.ndarray:
        """Batch-synchronous generation (all prompts same length).

        Output is always ``(b, max_new_tokens)``; with ``eos_id >= 0``,
        sequences that sample EOS (including at prefill — the first token
        counts) stop consuming decode steps and their remaining positions
        are filled with ``eos_id``.  Once every sequence has finished the
        decode loop exits instead of burning the rest of the budget.
        """
        b = prompts.shape[0]
        eos = self.cfg.eos_id
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, caches = self._prefill(self.params, batch)
        tok = self._sample(logits)[:, None]
        done = np.asarray(tok[:, 0] == eos) if eos >= 0 else np.zeros(b, bool)
        outs = [tok]
        for _ in range(max_new_tokens - 1):
            if eos >= 0 and done.all():
                pad = jnp.full((b, 1), eos, jnp.int32)
                outs.extend([pad] * (max_new_tokens - len(outs)))
                break
            logits, caches = self._decode(self.params, caches, tok)
            nxt = self._sample(logits)
            if eos >= 0:
                nxt = jnp.where(jnp.asarray(done), eos, nxt)
                done |= np.asarray(nxt == eos)
            tok = nxt[:, None]
            outs.append(tok)
        return np.asarray(jnp.concatenate(outs, axis=1))

    # ------------------------------------------------- continuous batching
    def serve(self, requests: List[Request],
              fault_injector=None) -> List[Request]:
        """Continuous mixed-length batching over a request queue.

        Slots share one jit'd decode over the fixed batch; prefill is
        per-request (batch 1) and its cache is committed into the slot —
        page-pool scatter for paged layers, slot-axis splice for rings /
        recurrent state / dense mode (``serve/paging.commit_prefill``).
        Finished slots immediately pull the next queued request — no
        head-of-line blocking on long generations, no drain barriers, no
        cache resets.

        Semantics:

        * prompt lengths may differ freely within one live batch: the
          per-slot position index keeps each slot's attention offsets
          independent, so a request admitted into a half-decoded batch
          neither inherits the batch's write head (the old stale-offset
          drift) nor disturbs the other slots;
        * paged layout, ``admission_policy="prompt"`` (default): admission
          reserves only the pages the request's *resident* tokens need;
          when a decode boundary then finds the pool dry, the
          latest-admitted slot is **recompute-preempted** — its pages are
          freed and the request re-enqueued at the queue head with its
          generated prefix prepended, to be re-prefilled when pages free
          (DESIGN.md §6.4).  Earlier-admitted requests always keep their
          pages (FIFO: the earliest active slot can never be starved), so
          pools sized below aggregate worst case make progress instead of
          blocking.  ``admission_policy="worst_case"`` restores the PR 5
          behavior: worst-case reservations, admission **defers** on
          exhaustion, decode-boundary allocation never fails;
        * per-request fault isolation (unless ``strict=True``): an
          oversized request (budget beyond ``max_seq``, or a worst-case
          page count larger than the whole pool) is **rejected**
          (``status="rejected"``) instead of raising; an exception during
          a request's prefill, or an injected per-request decode fault,
          **fails** that request (``status="failed"``) and frees its
          slot/pages while the rest of the batch keeps serving.  A
          :class:`~repro.train.fault.FaultInjector` (argument, or the
          engine's ``fault_injector``) is consulted at the per-request
          prefill and token-commit sites;
        * deadlines: a request whose ``deadline_s`` (or the config
          default) elapses — measured from serve() entry, so queue wait
          counts — is timed out at the next decode boundary (or while
          still queued), keeping its partial ``out``;
        * a request whose first (prefill-sampled) token is EOS, or whose
          ``max_new_tokens <= 1``, completes immediately without spending
          decode steps, a slot, or pages;
        * per-request timing lands in ``queue_s`` / ``prefill_s`` /
          ``latency_s`` (see :class:`Request`) — ``latency_s`` is measured
          from the request's own processing start, not the serve() call;
        * observability lands in ``self.paging_stats`` after every call:
          pages in use / high-water, fragmentation, deferrals, preemption
          counters (``preemptions``, ``recompute_tokens``, ``evictions``,
          ``pages_evicted``), per-status counts (``completed`` /
          ``rejected`` / ``failed`` / ``timed_out``), and straggler decode
          steps flagged by a :class:`~repro.train.fault.Watchdog` over
          ``self.fault_cfg``.
        """
        from repro.train.fault import Watchdog
        cfg = self.cfg
        n = cfg.n_slots
        paged = cfg.kv_layout == "paged"
        strict = cfg.strict
        clock = self.clock
        injector = fault_injector if fault_injector is not None \
            else self.fault_injector
        geom = alloc = None
        if paged:
            geom = paging.geometry(cfg.max_seq, cfg.page_size, n,
                                   cfg.n_pages)
            alloc = paging.PageAllocator(geom, n,
                                         policy=cfg.admission_policy)
        caches = self.model.init_cache(n, cfg.max_seq, paging=geom)
        queue = deque(requests)
        active: List[Optional[Request]] = [None] * n
        remaining = [0] * n
        pos = [0] * n                       # tokens resident per slot
        admit_seq = [-1] * n                # admission order per slot
        seq_counter = 0
        started: Dict[int, float] = {}      # id(req) → first slotting time
        cur_tok = jnp.zeros((n, 1), jnp.int32)
        t_start = clock()
        watchdog = Watchdog(self.fault_cfg)
        prefill_count = 0                   # prefill site index (injector)
        stats = {"decode_steps": 0, "admission_deferrals": 0,
                 "peak_live_tokens": 0, "frag_at_high_water": 0.0,
                 "requests": len(requests), "completed": 0,
                 "preemptions": 0, "recompute_tokens": 0,
                 "rejected": 0, "failed": 0, "timed_out": 0}

        def deadline_expired(req: Request, now: float) -> bool:
            d = req.deadline_s if req.deadline_s is not None else \
                (cfg.deadline_s if cfg.deadline_s > 0 else None)
            return d is not None and (now - t_start) > d

        def finish_ok(req: Request) -> None:
            req.done = True
            req.status = "ok" if req.preemptions == 0 \
                else f"preempted_{req.preemptions}"
            req.latency_s = clock() - started[id(req)]
            stats["completed"] += 1

        def finish_bad(req: Request, status: str, error: str,
                       slot: Optional[int] = None) -> None:
            """Terminal failure for ONE request: record status/error, free
            its slot and pages, leave everyone else serving."""
            req.done = True
            req.status = status
            req.error = error
            if req.out is None:
                req.out = []
            if id(req) in started:
                req.latency_s = clock() - started[id(req)]
            stats[status] += 1
            if slot is not None:
                active[slot] = None
                if paged:
                    alloc.release(slot)

        def preempt_victim() -> int:
            """Recompute-preempt the latest-admitted (fewest tokens
            generated) active slot: free its pages, re-enqueue the request
            at the queue HEAD with its generated prefix kept in ``out`` —
            re-admission prefills prompt+prefix and resumes sampling where
            it left off.  Returns the victim slot."""
            victim = max((s for s in range(n) if active[s] is not None),
                         key=lambda s: (admit_seq[s], -len(active[s].out)))
            req = active[victim]
            req.preemptions += 1
            req.status = f"preempted_{req.preemptions}"
            stats["preemptions"] += 1
            stats["recompute_tokens"] += pos[victim]
            active[victim] = None
            alloc.release(victim, evicted=True)
            # FIFO: the victim was admitted before anything still queued
            # (later evictions are earlier admissions — appendleft keeps
            # them ordered ahead of this one)
            queue.appendleft(req)
            return victim

        while queue or any(a is not None for a in active):
            # fill free slots; a request finishing at prefill (EOS as its
            # first token, or an exhausted budget) completes without ever
            # occupying the slot, so the next queued request slots in
            deferred = False
            for slot in range(n):
                while active[slot] is None and queue and not deferred:
                    req = queue[0]
                    now = clock()
                    if deadline_expired(req, now):
                        queue.popleft()
                        started.setdefault(id(req), now)
                        req.queue_s = now - t_start
                        finish_bad(req, "timed_out",
                                   "deadline exceeded after "
                                   f"{now - t_start:.3f}s in queue")
                        continue
                    prefix = req.out or []      # preempted: generated so far
                    length = len(req.tokens) + len(prefix)
                    budget = max(req.max_new_tokens, 1) - len(prefix)
                    # max resident tokens: the last decode step has written
                    # length + max_new - 1 of them (the final sampled token
                    # never enters the cache) — preemption never raises it
                    max_resident = len(req.tokens) \
                        + max(req.max_new_tokens, 1) - 1
                    if max_resident > cfg.max_seq:
                        msg = (f"request needs {max_resident} cache "
                               f"positions (prompt {len(req.tokens)} + "
                               f"max_new_tokens {req.max_new_tokens} - 1) "
                               f"but max_seq is {cfg.max_seq}")
                        if strict:
                            raise ValueError(msg)
                        queue.popleft()
                        finish_bad(req, "rejected", msg)
                        continue
                    worst = 0
                    if paged:
                        worst = alloc.pages_for(max_resident)
                        if worst > alloc.usable:
                            msg = (f"request needs up to {worst} pages but "
                                   f"the pool has {alloc.usable}: raise "
                                   f"n_pages or lower max_new_tokens")
                            if strict:
                                raise ValueError(msg)
                            queue.popleft()
                            finish_bad(req, "rejected", msg)
                            continue
                        if not alloc.can_admit(
                                alloc.admission_pages(length, worst)):
                            # FIFO: don't let shorter later requests starve
                            # the head — stop admitting until pages free
                            stats["admission_deferrals"] += 1
                            deferred = True
                            break
                    queue.popleft()
                    t0 = clock()
                    if id(req) not in started:
                        started[id(req)] = t0
                        req.queue_s = t0 - t_start
                    tokens = req.tokens if not prefix else np.concatenate(
                        [np.asarray(req.tokens, np.int32),
                         np.asarray(prefix, np.int32)])
                    site = prefill_count
                    prefill_count += 1
                    try:
                        if injector is not None:
                            injector.check(site, site="prefill")
                        logits, slot_cache = self._prefill(
                            self.params,
                            {"tokens": jnp.asarray(tokens[None, :],
                                                   jnp.int32)})
                        first = int(self._sample(logits)[0])
                    except Exception as e:  # noqa: BLE001 — isolate request
                        if strict:
                            raise
                        finish_bad(req, "failed", repr(e))
                        continue
                    if req.out is None:
                        req.out = []
                    req.out.append(first)
                    if not prefix:
                        req.prefill_s = clock() - t0
                    if first == cfg.eos_id or budget <= 1:
                        finish_ok(req)
                        continue
                    if paged:
                        alloc.admit(slot, length, worst)
                        caches = paging.commit_prefill(
                            caches, slot_cache, slot, length, alloc.table,
                            geom.page_size)
                    else:
                        caches = paging.commit_prefill(
                            caches, slot_cache, slot, length)
                    active[slot] = req
                    admit_seq[slot] = seq_counter
                    seq_counter += 1
                    remaining[slot] = budget - 1
                    pos[slot] = length
                    cur_tok = cur_tok.at[slot, 0].set(first)
            if all(a is None for a in active):
                if queue:
                    continue     # heads were rejected/timed out — refill
                break            # the fill loop drained the queue
            # deadline sweep at the decode boundary: expired slots free
            # their pages before anyone is preempted for space
            now = clock()
            for slot in range(n):
                req = active[slot]
                if req is not None and deadline_expired(req, now):
                    finish_bad(req, "timed_out",
                               "deadline exceeded after "
                               f"{now - t_start:.3f}s with "
                               f"{len(req.out)} tokens", slot=slot)
            if paged:
                # this decode step writes each active slot's token at
                # position pos[slot] — allocate boundary pages up front,
                # earliest-admitted first.  worst_case policy: always
                # succeeds under the reservation invariant.  prompt
                # policy: pool exhaustion preempts the latest-admitted
                # slot (possibly the requester itself) and retries — the
                # earliest active slot can always make progress, since
                # alone it fits by the worst-case-vs-pool admission check.
                changed = False
                order = sorted((s for s in range(n)
                                if active[s] is not None),
                               key=lambda s: admit_seq[s])
                for slot in order:
                    if active[slot] is None:
                        continue             # evicted as a victim below
                    while True:
                        try:
                            changed |= alloc.ensure(slot, pos[slot] + 1)
                            break
                        except paging.PoolExhausted:
                            victim = preempt_victim()
                            changed = True   # victim's table row went null
                            if victim == slot:
                                break        # requester evicted itself
                if changed:
                    caches = paging.sync_block_tables(caches, alloc.table)
            # live-token peak is layout-agnostic (the dense layout used to
            # report 0, skewing the paged-vs-dense residency comparison)
            live = sum(pos[s] + 1 for s in range(n)
                       if active[s] is not None)
            stats["peak_live_tokens"] = max(stats["peak_live_tokens"], live)
            if paged and alloc.pages_in_use >= alloc.high_water:
                stats["frag_at_high_water"] = 1.0 - live / max(
                    alloc.pages_in_use * geom.page_size, 1)
            if all(a is None for a in active):
                continue         # deadline sweep / self-eviction emptied
            step_t0 = clock()
            logits, caches = self._decode(self.params, caches, cur_tok)
            watchdog.observe(stats["decode_steps"], clock() - step_t0)
            stats["decode_steps"] += 1
            nxt = self._sample(logits)
            cur_tok = nxt[:, None]
            for slot in range(n):
                req = active[slot]
                if req is None:
                    continue
                if injector is not None:
                    try:
                        # per-request decode site: "this request committing
                        # its len(out)-th generated token"
                        injector.check(len(req.out), site="decode")
                    except Exception as e:  # noqa: BLE001 — isolate request
                        if strict:
                            raise
                        finish_bad(req, "failed", repr(e), slot=slot)
                        continue
                tok = int(nxt[slot])
                req.out.append(tok)
                pos[slot] += 1
                remaining[slot] -= 1
                if remaining[slot] <= 0 or tok == cfg.eos_id:
                    finish_ok(req)
                    active[slot] = None
                    if paged:
                        alloc.release(slot)
        stats["straggler_decode_steps"] = len(watchdog.events)
        if paged:
            stats.update(alloc.stats())
            stats["kv_layout"] = "paged"
            # dense-equivalent residency: what (n_slots, S_max) slabs pin
            stats["dense_equiv_tokens"] = n * cfg.max_seq
            stats["paged_peak_tokens"] = stats["page_high_water"] \
                * geom.page_size
        else:
            stats["kv_layout"] = "dense"
        self.paging_stats = stats
        return requests
