"""Serving engine: batched prefill + decode with KV caches.

Production structure on the latency path:

* jit'd ``prefill`` (prompt → logits + caches) and ``decode`` (one token,
  donated cache) — the same functions the decode dry-run cells lower, so
  serving perf analysis and the roofline table talk about identical HLO.
* **Continuous mixed-length batching**: a fixed decode batch of
  ``n_slots`` with a **per-slot KV position index**, so requests of any
  prompt length share one live batch and a finished slot immediately pulls
  the next queued request — no cache resets, no drain barriers.
* **Paged KV cache** (``kv_layout="paged"``, the default — DESIGN.md §6,
  ``serve/paging.py``): K/V live in a shared page pool addressed through
  per-slot block tables; pages are allocated lazily as slots grow and
  freed on completion, so resident KV memory tracks *actual* sequence
  lengths.  Admission defers when the pool can't cover a request's
  worst-case reservation.  ``kv_layout="dense"`` keeps the per-slot
  ``(n_slots, S_max)`` slabs (still per-slot-indexed, so mixed lengths
  work there too) — the layout ``generate()`` and training-eval
  equivalence use.
* Sampling: greedy / temperature / top-k, fp32 logits.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LanguageModel
from repro.serve import paging

__all__ = ["ServeConfig", "Engine", "Request"]


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    n_slots: int = 4                    # decode batch size
    temperature: float = 0.0            # 0 → greedy
    top_k: int = 0
    eos_id: int = -1                    # -1 → run to max_new_tokens
    seed: int = 0
    # --- KV-cache layout (DESIGN.md §6) ---
    kv_layout: str = "paged"            # paged | dense
    page_size: int = 16                 # tokens per KV page
    n_pages: int = 0                    # 0 → auto: dense capacity + null page


@dataclasses.dataclass
class Request:
    """One serving request.  Timing fields (all seconds, set by ``serve``):

    * ``queue_s``   — time from ``serve()`` entry until this request was
      slotted (head-of-line wait).
    * ``prefill_s`` — its own prefill forward duration.
    * ``latency_s`` — end-to-end latency measured from *this request's own
      processing start* (slotting) to its completion — NOT from the start
      of the whole serve call, which would bill earlier requests' work to
      late-slotted ones.
    """
    tokens: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    out: Optional[List[int]] = None
    done: bool = False
    latency_s: float = 0.0
    queue_s: float = 0.0
    prefill_s: float = 0.0


class Engine:
    def __init__(self, model_cfg, serve_cfg: ServeConfig, params=None):
        self.cfg = serve_cfg
        self.model = LanguageModel(model_cfg)
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(serve_cfg.seed))
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, c, t),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.cfg.max_seq),
            static_argnums=())
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        # paging observability from the most recent serve() call
        self.paging_stats: Optional[Dict] = None
        # Sparse (RgCSR) weights: pre-stage kernel plan containers at model
        # load for eager per-layer paths (DESIGN.md §3.2).  The jit'd
        # prefill/decode below assemble their plans at trace time, so the
        # latency path pays no per-call host plan work either way; warming
        # is a no-op for layer-stacked param trees (plans_warmed == 0).
        self.plans_warmed = 0
        self.spmv_plans_warmed = 0
        self.sharded_spmv_plans_warmed = 0
        # append-only observability log: one small host dict per warmed
        # (matrix, mesh) — deliberately never pruned, unlike _warm_sharded
        # below, which holds device arrays and must release superseded plans
        self.sharded_spmv_shard_stats: List[Dict] = []
        # strong refs keep the sharded-plan cache entries alive; keyed on
        # (mesh signature, x_mode, exact matrix content) so re-warming the
        # same matrix on the same mesh REPLACES its entry — the superseded
        # plan's device arrays are released to the weakref-evicted caches
        # instead of accumulating for the engine's lifetime.  The key must
        # be the exact content (not the tuner's log2-bucketed signature):
        # two distinct matrices sharing a bucket must both stay warmed.
        self._warm_sharded: Dict[tuple, tuple] = {}
        if model_cfg.sparsity.enabled and model_cfg.sparsity.impl_is_kernel():
            from repro.kernels import ops as kops
            # warm at the model's compute dtype — the dtype the eager apply
            # path will request (a float32 default would never be hit under
            # the bfloat16 default config)
            self.plans_warmed = kops.warm_plans_from_params(
                self.params, dtype=jnp.dtype(model_cfg.dtype))

    def warm_spmv_plans(self, matrices, *, repeats: int = 1, mesh=None,
                        mesh_axis: Optional[str] = None,
                        x_mode: str = "replicated",
                        per_shard_tune: bool = True):
        """Pre-tune and stage SpMV plans for auxiliary sparse matrices.

        Serving deployments that also answer SpMV traffic (iterative
        solvers, graph scoring) hand their matrices here at startup: each
        one runs the joint autotune search — ``(chunks_per_step,
        group_size, ordering, spill_threshold)``, DESIGN.md §5 — and the
        winning plan (block or adaptive, whichever measured faster) lands
        in the process-wide ``PLAN_CACHE`` before the first request.

        Contract: the warmed entries are keyed to the tuner's own RgCSR
        containers (retained per matrix signature), so the request path
        hits them by fetching through ``autotune.tuned_plan(dense)`` —
        a signature-memo hit, no re-timing, no plan rebuild.  A caller
        that instead runs ``core.spmv`` on its *own* RgCSR object gets a
        fresh plan under that object's identity and must thread the
        returned config's ``(ordering, spill_threshold, chunks_per_step)``
        itself.  Returns the winning
        :class:`repro.kernels.autotune.TuneConfig` per matrix, in order.

        With ``mesh`` set, each matrix is additionally row-sharded over the
        resolved mesh axis (``mesh_axis`` or the partitioner's
        ``sparse_rows`` rule) and, with ``per_shard_tune`` (the default),
        **each shard is tuned independently** (DESIGN.md §11,
        ``autotune.autotune_spmv_per_shard``): the heavy shard of a skewed
        matrix gets spill/adaptive while light shards keep plain block
        cps>1, all at the global winner's ``group_size`` so the stacked
        plan stays uniform.  The stacked shard_map plan is built at those
        per-shard winners and staged in the sharded plan cache — keyed on
        the shard/device count, so re-warming on a resized mesh builds a
        fresh plan instead of reusing a stale stacked one.  Per-matrix
        shard stats (slots, steps, remote columns, exchange volume per the
        §11 sparse-collective schedule, per-shard winner configs) land in
        ``sharded_spmv_shard_stats``.  The sharded matrices are retained
        on the engine so the cache entries survive warmup.
        """
        from repro.kernels import autotune
        winners = []
        if mesh is not None and mesh_axis is None:
            from repro.sharding import resolve_spmv_shard_axis
            mesh_axis = resolve_spmv_shard_axis(mesh)
        for dense in matrices:
            dense = np.asarray(dense)
            _, result = autotune.tuned_plan(dense, repeats=repeats)
            winners.append(result.config)
            if mesh is not None:
                from repro.core.formats import ShardedRgCSR
                from repro.kernels import ops as kops
                from repro.sharding import mesh_signature
                cfg = result.config
                n_shards = int(mesh.shape[mesh_axis])
                shard_cfgs = None
                if per_shard_tune:
                    shard_results = autotune.autotune_spmv_per_shard(
                        dense, n_shards, group_size=cfg.group_size,
                        repeats=repeats, x_mode=x_mode)
                    shard_cfgs = autotune.harmonize_shard_winners(
                        shard_results)
                sm = ShardedRgCSR.from_dense(
                    dense, n_shards=n_shards, group_size=cfg.group_size)
                splan = kops.get_sharded_plan(
                    sm, chunks_per_step=cfg.chunks_per_step,
                    ordering=cfg.ordering,
                    spill_threshold=cfg.spill_threshold, x_mode=x_mode,
                    shard_configs=shard_cfgs)
                content = hashlib.sha1(
                    np.ascontiguousarray(dense).tobytes()).hexdigest()
                self._warm_sharded[(mesh_signature(mesh), x_mode,
                                    dense.shape, str(dense.dtype),
                                    content)] = (sm, splan)
                self.sharded_spmv_plans_warmed += 1
                self.sharded_spmv_shard_stats.append({
                    "n_shards": splan.n_shards,
                    "mesh": mesh_signature(mesh),
                    "x_mode": splan.x_mode,
                    "stored_slots": list(splan.shard_stored_slots),
                    "num_steps": list(splan.shard_num_steps),
                    "remote_cols": list(splan.shard_remote_cols),
                    "exchange_recv_cols": list(
                        splan.shard_exchange_recv_cols),
                    "exchange_send_cols": list(
                        splan.shard_exchange_send_cols),
                    "exchange_bytes": list(splan.shard_exchange_bytes),
                    "kernel_chunks_per_step": splan.chunks_per_step,
                    "shard_winners": [list(c) for c in splan.shard_configs],
                })
        self.spmv_plans_warmed += len(winners)
        return winners

    def plan_cache_stats(self):
        """Plan-cache counters: the matrix PlanCache (core spmv dispatch)
        and the SparseLinear param-plan memo (this engine's sparse layers),
        plus how many plans this engine warmed at init."""
        from repro.kernels import ops as kops
        return {"plan_cache": kops.PLAN_CACHE.stats(),
                "param_plans": kops.param_plan_stats(),
                "sharded_plan_cache": kops.sharded_plan_cache_stats(),
                "plans_warmed": self.plans_warmed,
                "spmv_plans_warmed": self.spmv_plans_warmed,
                "sharded_spmv_plans_warmed": self.sharded_spmv_plans_warmed}

    # ---------------------------------------------------------------- sample
    def _sample(self, logits) -> jax.Array:
        logits = logits[:, -1, :].astype(jnp.float32)
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        logits = logits / self.cfg.temperature
        # clamp top_k to the vocab: k >= vocab keeps every token (the sort
        # index -k would otherwise read out of range), k <= 0 disables.
        k = min(int(self.cfg.top_k), logits.shape[-1])
        if 0 < k < logits.shape[-1]:
            kth = jnp.sort(logits, axis=-1)[:, -k][:, None]
            logits = jnp.where(logits < kth, -1e30, logits)
        return jax.random.categorical(sub, logits).astype(jnp.int32)

    # ------------------------------------------------------------- one-shot
    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32
                 ) -> np.ndarray:
        """Batch-synchronous generation (all prompts same length).

        Output is always ``(b, max_new_tokens)``; with ``eos_id >= 0``,
        sequences that sample EOS (including at prefill — the first token
        counts) stop consuming decode steps and their remaining positions
        are filled with ``eos_id``.  Once every sequence has finished the
        decode loop exits instead of burning the rest of the budget.
        """
        b = prompts.shape[0]
        eos = self.cfg.eos_id
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, caches = self._prefill(self.params, batch)
        tok = self._sample(logits)[:, None]
        done = np.asarray(tok[:, 0] == eos) if eos >= 0 else np.zeros(b, bool)
        outs = [tok]
        for _ in range(max_new_tokens - 1):
            if eos >= 0 and done.all():
                pad = jnp.full((b, 1), eos, jnp.int32)
                outs.extend([pad] * (max_new_tokens - len(outs)))
                break
            logits, caches = self._decode(self.params, caches, tok)
            nxt = self._sample(logits)
            if eos >= 0:
                nxt = jnp.where(jnp.asarray(done), eos, nxt)
                done |= np.asarray(nxt == eos)
            tok = nxt[:, None]
            outs.append(tok)
        return np.asarray(jnp.concatenate(outs, axis=1))

    # ------------------------------------------------- continuous batching
    def serve(self, requests: List[Request]) -> List[Request]:
        """Continuous mixed-length batching over a request queue.

        Slots share one jit'd decode over the fixed batch; prefill is
        per-request (batch 1) and its cache is committed into the slot —
        page-pool scatter for paged layers, slot-axis splice for rings /
        recurrent state / dense mode (``serve/paging.commit_prefill``).
        Finished slots immediately pull the next queued request — no
        head-of-line blocking on long generations, no drain barriers, no
        cache resets.

        Semantics:

        * prompt lengths may differ freely within one live batch: the
          per-slot position index keeps each slot's attention offsets
          independent, so a request admitted into a half-decoded batch
          neither inherits the batch's write head (the old stale-offset
          drift) nor disturbs the other slots;
        * paged layout: admission reserves the request's worst-case page
          count (``ceil((len + max_new - 1) / page_size)``) — when the
          pool can't cover it, admission **defers** (FIFO — later requests
          wait too) until a completion frees pages.  Decode-boundary page
          allocations always succeed under that reservation invariant;
        * a request whose first (prefill-sampled) token is EOS, or whose
          ``max_new_tokens <= 1``, completes immediately without spending
          decode steps, a slot, or pages;
        * per-request timing lands in ``queue_s`` / ``prefill_s`` /
          ``latency_s`` (see :class:`Request`) — ``latency_s`` is measured
          from the request's own processing start, not the serve() call;
        * paging observability lands in ``self.paging_stats`` (pages in
          use / high-water, fragmentation, deferrals) after every call.
        """
        cfg = self.cfg
        n = cfg.n_slots
        paged = cfg.kv_layout == "paged"
        geom = alloc = None
        if paged:
            geom = paging.geometry(cfg.max_seq, cfg.page_size, n,
                                   cfg.n_pages)
            alloc = paging.PageAllocator(geom, n)
        caches = self.model.init_cache(n, cfg.max_seq, paging=geom)
        queue = deque(requests)
        active: List[Optional[Request]] = [None] * n
        remaining = [0] * n
        pos = [0] * n                       # tokens resident per slot
        slot_t0 = [0.0] * n                 # processing start per slot
        cur_tok = jnp.zeros((n, 1), jnp.int32)
        t_start = time.time()
        stats = {"decode_steps": 0, "admission_deferrals": 0,
                 "peak_live_tokens": 0, "frag_at_high_water": 0.0,
                 "requests": len(requests)}

        while queue or any(a is not None for a in active):
            # fill free slots; a request finishing at prefill (EOS as its
            # first token, or a 1-token budget) completes without ever
            # occupying the slot, so the next queued request slots in
            deferred = False
            for slot in range(n):
                while active[slot] is None and queue and not deferred:
                    req = queue[0]
                    length = len(req.tokens)
                    # max resident tokens: the last decode step has written
                    # length + max_new - 1 of them (the final sampled token
                    # never enters the cache)
                    max_resident = length + max(req.max_new_tokens, 1) - 1
                    if max_resident > cfg.max_seq:
                        raise ValueError(
                            f"request needs {max_resident} cache positions "
                            f"(prompt {length} + max_new_tokens "
                            f"{req.max_new_tokens} - 1) but max_seq is "
                            f"{cfg.max_seq}")
                    worst = 0
                    if paged:
                        worst = alloc.pages_for(max_resident)
                        if worst > alloc.usable:
                            raise ValueError(
                                f"request needs up to {worst} pages but the "
                                f"pool has {alloc.usable}: raise n_pages or "
                                f"lower max_new_tokens")
                        if not alloc.can_admit(worst):
                            # FIFO: don't let shorter later requests starve
                            # the head — stop admitting until pages free
                            stats["admission_deferrals"] += 1
                            deferred = True
                            break
                    queue.popleft()
                    t0 = time.time()
                    req.queue_s = t0 - t_start
                    logits, slot_cache = self._prefill(
                        self.params,
                        {"tokens": jnp.asarray(req.tokens[None, :],
                                               jnp.int32)})
                    first = int(self._sample(logits)[0])
                    req.out = [first]
                    req.prefill_s = time.time() - t0
                    if first == cfg.eos_id or req.max_new_tokens <= 1:
                        req.done = True
                        req.latency_s = time.time() - t0
                        continue
                    if paged:
                        alloc.admit(slot, length, worst)
                        caches = paging.commit_prefill(
                            caches, slot_cache, slot, length, alloc.table,
                            geom.page_size)
                    else:
                        caches = paging.commit_prefill(
                            caches, slot_cache, slot, length)
                    slot_t0[slot] = t0
                    active[slot] = req
                    remaining[slot] = req.max_new_tokens - 1
                    pos[slot] = length
                    cur_tok = cur_tok.at[slot, 0].set(first)
            if all(a is None for a in active):
                break        # queue is empty too (the fill loop drained it)
            if paged:
                # this decode step writes each active slot's token at
                # position pos[slot] — allocate boundary pages up front
                # (always succeeds: reservations bound physical use)
                changed = False
                for slot in range(n):
                    if active[slot] is not None:
                        changed |= alloc.ensure(slot, pos[slot] + 1)
                if changed:
                    caches = paging.sync_block_tables(caches, alloc.table)
                live = sum(pos[s] + 1 for s in range(n)
                           if active[s] is not None)
                stats["peak_live_tokens"] = max(stats["peak_live_tokens"],
                                                live)
                if alloc.pages_in_use >= alloc.high_water:
                    stats["frag_at_high_water"] = 1.0 - live / max(
                        alloc.pages_in_use * geom.page_size, 1)
            logits, caches = self._decode(self.params, caches, cur_tok)
            stats["decode_steps"] += 1
            nxt = self._sample(logits)
            cur_tok = nxt[:, None]
            for slot in range(n):
                req = active[slot]
                if req is None:
                    continue
                tok = int(nxt[slot])
                req.out.append(tok)
                pos[slot] += 1
                remaining[slot] -= 1
                if remaining[slot] <= 0 or tok == cfg.eos_id:
                    req.done = True
                    req.latency_s = time.time() - slot_t0[slot]
                    active[slot] = None
                    if paged:
                        alloc.release(slot)
        if paged:
            stats.update(alloc.stats())
            stats["kv_layout"] = "paged"
            # dense-equivalent residency: what (n_slots, S_max) slabs pin
            stats["dense_equiv_tokens"] = n * cfg.max_seq
            stats["paged_peak_tokens"] = stats["page_high_water"] \
                * geom.page_size
        else:
            stats["kv_layout"] = "dense"
        self.paging_stats = stats
        return requests
