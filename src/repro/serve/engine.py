"""Serving engine: batched prefill + decode with KV caches.

Production structure on the latency path:

* jit'd ``prefill`` (prompt → logits + caches) and ``decode`` (one token,
  donated cache) — the same functions the decode dry-run cells lower, so
  serving perf analysis and the roofline table talk about identical HLO.
* **Continuous mixed-length batching**: a fixed decode batch of
  ``n_slots`` with a **per-slot KV position index**, so requests of any
  prompt length share one live batch and a finished slot immediately pulls
  the next queued request — no cache resets, no drain barriers.
* **Paged KV cache** (``kv_layout="paged"``, the default — DESIGN.md §6,
  ``serve/paging.py``): K/V live in a shared page pool addressed through
  per-slot block tables; pages are allocated lazily as slots grow and
  freed on completion, so resident KV memory tracks *actual* sequence
  lengths.  ``kv_layout="dense"`` keeps the per-slot ``(n_slots, S_max)``
  slabs (still per-slot-indexed, so mixed lengths work there too) — the
  layout ``generate()`` and training-eval equivalence use.
* **Graceful overload** (DESIGN.md §6.4): admission reserves prompt pages
  only (``admission_policy="prompt"``) and decode-boundary pool
  exhaustion **recompute-preempts** the latest-admitted slot instead of
  blocking; oversized requests are rejected per-request, mid-request
  faults fail only the affected request, and per-request deadlines shed
  expired work — each terminal outcome lands in ``Request.status``
  (``worst_case`` admission + ``strict=True`` restore the PR 5
  defer/fail-stop behavior).  A ``train/fault.py`` Watchdog flags
  straggler decode steps into ``paging_stats``.
* Sampling: greedy / temperature / top-k, fp32 logits.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LanguageModel
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import device_loop, paging

__all__ = ["ServeConfig", "Engine", "EngineSession", "Request",
           "request_to_state", "request_from_state"]


def request_to_state(req: "Request", now: float) -> Dict:
    """JSON-serializable crash-consistent state of one undone request
    (DESIGN.md §7.6).  KV tensors are NOT captured — the generated
    prefix in ``out`` is enough for the recompute path to resume the
    stream exactly.  The arrival timestamp is stored as an *age* so the
    restoring process can rebase it onto its own clock (deadlines keep
    running across the restart)."""
    return {
        "tokens": np.asarray(req.tokens, np.int32).tolist(),
        "max_new_tokens": int(req.max_new_tokens),
        "out": None if req.out is None else [int(t) for t in req.out],
        "preemptions": int(req.preemptions),
        "retries": int(req.retries),
        "deadline_s": req.deadline_s,
        "age_s": 0.0 if req.arrival_t is None
        else float(now - req.arrival_t),
        "queue_s": float(req.queue_s),
        "prefill_s": float(req.prefill_s),
    }


def request_from_state(state: Dict, now: float) -> "Request":
    """Inverse of :func:`request_to_state`: rebuild a live
    :class:`Request` in the restoring process, arrival rebased to
    ``now - age_s``."""
    req = Request(tokens=np.asarray(state["tokens"], np.int32),
                  max_new_tokens=state["max_new_tokens"])
    req.out = None if state.get("out") is None else list(state["out"])
    req.preemptions = state.get("preemptions", 0)
    req.retries = state.get("retries", 0)
    req.deadline_s = state.get("deadline_s")
    req.arrival_t = now - state.get("age_s", 0.0)
    req.queue_s = state.get("queue_s", 0.0)
    req.prefill_s = state.get("prefill_s", 0.0)
    if req.preemptions:
        req.status = f"preempted_{req.preemptions}"
    return req


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 1024
    n_slots: int = 4                    # decode batch size
    temperature: float = 0.0            # 0 → greedy
    top_k: int = 0
    eos_id: int = -1                    # -1 → run to max_new_tokens
    seed: int = 0
    # --- KV-cache layout (DESIGN.md §6) ---
    kv_layout: str = "paged"            # paged | dense
    page_size: int = 16                 # tokens per KV page
    n_pages: int = 0                    # 0 → auto: dense capacity + null page
    # --- fused decode loop (DESIGN.md §7.1) ---
    # max decode steps per fused on-device dispatch; 1 restores the
    # stepwise one-dispatch-per-token cadence (host sync every step)
    decode_chunk: int = 8
    # --- overload behavior (DESIGN.md §6.4) ---
    # prompt     → admit on the resident tokens' pages only and
    #              recompute-preempt a victim at decode-boundary exhaustion
    # worst_case → reserve each request's worst case at admission and
    #              defer admissions when the pool can't cover it (PR 5)
    admission_policy: str = "prompt"
    # strict=True restores fail-stop serving: oversized requests and
    # mid-request exceptions raise out of serve() (the pre-overload-layer
    # behavior) instead of failing only the affected request.
    strict: bool = False
    # default completion deadline (seconds from serve() entry) applied to
    # requests that don't carry their own ``deadline_s``; 0 → no deadline.
    deadline_s: float = 0.0
    # --- KV-page integrity (DESIGN.md §7.6) ---
    # kv_integrity=True arms two independent detectors for silent
    # device-memory corruption in the long-lived page pools: per-page
    # crc32 checksums recorded at chunk-commit boundaries and verified
    # before every dispatch (corruption at rest), and a NaN/Inf logit
    # screen in the commit loop (corruption that strikes inside the
    # dispatch window).  Detection quarantines the page and
    # recompute-preempts exactly the requests that touched it.
    kv_integrity: bool = False


@dataclasses.dataclass
class Request:
    """One serving request.

    Terminal state (set by ``serve``/the router): ``done`` flips True
    exactly once, and ``status`` says how the request ended —

    * ``"ok"``            — completed normally;
    * ``"preempted_<n>"`` — completed normally after ``n`` recompute
      preemptions (still a success — ``ok_like`` covers both);
    * ``"rejected"``      — refused at admission (budget overflows
      ``max_seq``, or its worst-case page count exceeds the whole pool);
    * ``"failed"``        — a mid-request exception (prefill/decode fault)
      killed this request, or a router-migrated request exhausted its
      retry budget; the rest of the batch kept serving;
    * ``"timed_out"``     — its ``deadline_s`` passed (queued or
      mid-decode); partial output is kept in ``out``;
    * ``"shed"``          — refused at the router's door: the bounded
      router queue was full (backpressure, DESIGN.md §7) — the request
      never reached an engine.

    ``error`` carries the reason for the failure statuses.
    ``deadline_s`` is a completion deadline in seconds measured from the
    request's **arrival** — the moment it was submitted to a session or
    router (``arrival_t``; batch-submitted ``serve()`` requests arrive at
    call entry, keeping the original semantics).  It bounds queue wait +
    processing and keeps running across router migrations; ``None`` falls
    back to ``ServeConfig.deadline_s``.

    ``retries`` counts router migrations of this request off faulted
    replicas (bounded by the router's ``FaultConfig.max_restarts``).

    Timing fields (all seconds, set by ``serve``):

    * ``queue_s``   — time from arrival until this request was first
      slotted (head-of-line wait).
    * ``prefill_s`` — its own (first) prefill forward duration.
    * ``latency_s`` — end-to-end latency measured from *this request's own
      processing start* (first slotting; re-measured from re-slotting
      after a router migration) to its completion — NOT from the start of
      the whole serve call, which would bill earlier requests' work to
      late-slotted ones.
    """
    tokens: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    out: Optional[List[int]] = None
    done: bool = False
    deadline_s: Optional[float] = None
    status: str = "ok"
    error: Optional[str] = None
    preemptions: int = 0
    retries: int = 0
    arrival_t: Optional[float] = None
    latency_s: float = 0.0
    queue_s: float = 0.0
    prefill_s: float = 0.0

    @property
    def ok_like(self) -> bool:
        """Completed with full output (possibly after preemptions)."""
        return self.done and (self.status == "ok"
                              or self.status.startswith("preempted"))


class Engine:
    def __init__(self, model_cfg, serve_cfg: ServeConfig, params=None,
                 fault_cfg=None, fault_injector=None):
        from repro.train.fault import FaultConfig
        self.cfg = serve_cfg
        # fault/overload knobs (DESIGN.md §6.4): the watchdog config drives
        # straggler flagging of decode steps; an engine-level injector (or
        # one passed to serve()) exercises per-request fault isolation.
        self.fault_cfg = fault_cfg if fault_cfg is not None else FaultConfig()
        self.fault_injector = fault_injector
        # injectable clock: every serve() timestamp (deadlines, latency,
        # watchdog) flows through this, so tests drive deadlines with a
        # fake timer instead of wall-clock sleeps.
        self.clock = time.time
        # observability (DESIGN.md §13): attach a repro.obs.trace.Tracer
        # (and a per-replica label) BEFORE start_session() and every
        # session event lands on this replica's track; None keeps the
        # no-op fast path.  The router attaches these for its fleet.
        self.tracer = None
        self.trace_label = "replica0"
        self.model = LanguageModel(model_cfg)
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(serve_cfg.seed))
        # one decode-step definition (device_loop.make_decode_step) feeds
        # both the per-step jit (generate() and the stepwise oracle) and
        # the fused lax.while_loop chunk runner EngineSession dispatches
        self._decode = jax.jit(device_loop.make_decode_step(self.model),
                               donate_argnums=(1,))
        self._fused_decode = device_loop.build_fused_decode(
            self.model, serve_cfg, on_dispatch=self._on_fused_dispatch)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.cfg.max_seq),
            static_argnums=())
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        # stats from the most recent serve() call — a plain-dict render
        # of the session's metrics registry (EngineSession.stats_snapshot;
        # DESIGN.md §13.1), kept under the historical name
        self.paging_stats: Optional[Dict] = None
        # Sparse (RgCSR) weights: pre-stage kernel plan containers at model
        # load for eager per-layer paths (DESIGN.md §3.2).  The jit'd
        # prefill/decode below assemble their plans at trace time, so the
        # latency path pays no per-call host plan work either way; warming
        # is a no-op for layer-stacked param trees (plans_warmed == 0).
        self.plans_warmed = 0
        self.spmv_plans_warmed = 0
        self.sharded_spmv_plans_warmed = 0
        # append-only observability log: one small host dict per warmed
        # (matrix, mesh) — deliberately never pruned, unlike _warm_sharded
        # below, which holds device arrays and must release superseded plans
        self.sharded_spmv_shard_stats: List[Dict] = []
        # strong refs keep the sharded-plan cache entries alive; keyed on
        # (mesh signature, x_mode, exact matrix content) so re-warming the
        # same matrix on the same mesh REPLACES its entry — the superseded
        # plan's device arrays are released to the weakref-evicted caches
        # instead of accumulating for the engine's lifetime.  The key must
        # be the exact content (not the tuner's log2-bucketed signature):
        # two distinct matrices sharing a bucket must both stay warmed.
        self._warm_sharded: Dict[tuple, tuple] = {}
        if model_cfg.sparsity.enabled and model_cfg.sparsity.impl_is_kernel():
            from repro.kernels import ops as kops
            # warm at the model's compute dtype — the dtype the eager apply
            # path will request (a float32 default would never be hit under
            # the bfloat16 default config)
            self.plans_warmed = kops.warm_plans_from_params(
                self.params, dtype=jnp.dtype(model_cfg.dtype))

    def warm_spmv_plans(self, matrices, *, repeats: int = 1, mesh=None,
                        mesh_axis: Optional[str] = None,
                        x_mode: str = "replicated",
                        per_shard_tune: bool = True):
        """Pre-tune and stage SpMV plans for auxiliary sparse matrices.

        Serving deployments that also answer SpMV traffic (iterative
        solvers, graph scoring) hand their matrices here at startup: each
        one runs the joint autotune search — ``(chunks_per_step,
        group_size, ordering, spill_threshold)``, DESIGN.md §5 — and the
        winning plan (block or adaptive, whichever measured faster) lands
        in the process-wide ``PLAN_CACHE`` before the first request.

        Contract: the warmed entries are keyed to the tuner's own RgCSR
        containers (retained per matrix signature), so the request path
        hits them by fetching through ``autotune.tuned_plan(dense)`` —
        a signature-memo hit, no re-timing, no plan rebuild.  A caller
        that instead runs ``core.spmv`` on its *own* RgCSR object gets a
        fresh plan under that object's identity and must thread the
        returned config's ``(ordering, spill_threshold, chunks_per_step)``
        itself.  Returns the winning
        :class:`repro.kernels.autotune.TuneConfig` per matrix, in order.

        With ``mesh`` set, each matrix is additionally row-sharded over the
        resolved mesh axis (``mesh_axis`` or the partitioner's
        ``sparse_rows`` rule) and, with ``per_shard_tune`` (the default),
        **each shard is tuned independently** (DESIGN.md §12,
        ``autotune.autotune_spmv_per_shard``): the heavy shard of a skewed
        matrix gets spill/adaptive while light shards keep plain block
        cps>1, all at the global winner's ``group_size`` so the stacked
        plan stays uniform.  The stacked shard_map plan is built at those
        per-shard winners and staged in the sharded plan cache — keyed on
        the shard/device count, so re-warming on a resized mesh builds a
        fresh plan instead of reusing a stale stacked one.  Per-matrix
        shard stats (slots, steps, remote columns, exchange volume per the
        §12 sparse-collective schedule, per-shard winner configs) land in
        ``sharded_spmv_shard_stats``.  The sharded matrices are retained
        on the engine so the cache entries survive warmup.
        """
        from repro.kernels import autotune
        winners = []
        if mesh is not None and mesh_axis is None:
            from repro.sharding import resolve_spmv_shard_axis
            mesh_axis = resolve_spmv_shard_axis(mesh)
        for dense in matrices:
            dense = np.asarray(dense)
            _, result = autotune.tuned_plan(dense, repeats=repeats)
            winners.append(result.config)
            if mesh is not None:
                from repro.core.formats import ShardedRgCSR
                from repro.kernels import ops as kops
                from repro.sharding import mesh_signature
                cfg = result.config
                n_shards = int(mesh.shape[mesh_axis])
                shard_cfgs = None
                if per_shard_tune:
                    shard_results = autotune.autotune_spmv_per_shard(
                        dense, n_shards, group_size=cfg.group_size,
                        repeats=repeats, x_mode=x_mode)
                    shard_cfgs = autotune.harmonize_shard_winners(
                        shard_results)
                sm = ShardedRgCSR.from_dense(
                    dense, n_shards=n_shards, group_size=cfg.group_size)
                splan = kops.get_sharded_plan(
                    sm, chunks_per_step=cfg.chunks_per_step,
                    ordering=cfg.ordering,
                    spill_threshold=cfg.spill_threshold, x_mode=x_mode,
                    shard_configs=shard_cfgs)
                content = hashlib.sha1(
                    np.ascontiguousarray(dense).tobytes()).hexdigest()
                self._warm_sharded[(mesh_signature(mesh), x_mode,
                                    dense.shape, str(dense.dtype),
                                    content)] = (sm, splan)
                self.sharded_spmv_plans_warmed += 1
                self.sharded_spmv_shard_stats.append({
                    "n_shards": splan.n_shards,
                    "mesh": mesh_signature(mesh),
                    "x_mode": splan.x_mode,
                    "stored_slots": list(splan.shard_stored_slots),
                    "num_steps": list(splan.shard_num_steps),
                    "remote_cols": list(splan.shard_remote_cols),
                    "exchange_recv_cols": list(
                        splan.shard_exchange_recv_cols),
                    "exchange_send_cols": list(
                        splan.shard_exchange_send_cols),
                    "exchange_bytes": list(splan.shard_exchange_bytes),
                    "kernel_chunks_per_step": splan.chunks_per_step,
                    "shard_winners": [list(c) for c in splan.shard_configs],
                })
        self.spmv_plans_warmed += len(winners)
        return winners

    def plan_cache_stats(self):
        """Plan-cache counters: the matrix PlanCache (core spmv dispatch)
        and the SparseLinear param-plan memo (this engine's sparse layers),
        plus how many plans this engine warmed at init."""
        from repro.kernels import ops as kops
        return {"plan_cache": kops.PLAN_CACHE.stats(),
                "param_plans": kops.param_plan_stats(),
                "sharded_plan_cache": kops.sharded_plan_cache_stats(),
                "plans_warmed": self.plans_warmed,
                "spmv_plans_warmed": self.spmv_plans_warmed,
                "sharded_spmv_plans_warmed": self.sharded_spmv_plans_warmed}

    # ---------------------------------------------------------------- sample
    def _sample(self, logits) -> jax.Array:
        """Host-side sampling: split the engine key once per step and
        defer to the pure sampler the fused device loop also uses."""
        if self.cfg.temperature <= 0.0:
            return device_loop.sample_tokens(logits, None, 0.0, 0)
        self._key, sub = jax.random.split(self._key)
        return device_loop.sample_tokens(logits, sub, self.cfg.temperature,
                                         self.cfg.top_k)

    def _on_fused_dispatch(self, out) -> None:
        """Trace hook run INSIDE the fused-decode callable (see
        ``device_loop.build_fused_decode``) — test/bench harnesses wrap
        ``engine._fused_decode`` from the outside, so an emission there
        would be lost under their wrappers.  Late-bound: attaching a
        tracer after engine construction takes effect immediately."""
        tr = self.tracer
        if tr is not None and tr.enabled:
            tr.instant("fused_dispatch", (self.trace_label, "device"),
                       steps=int(out[1]))

    # ------------------------------------------------------------- one-shot
    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32
                 ) -> np.ndarray:
        """Batch-synchronous generation (all prompts same length).

        Output is always ``(b, max_new_tokens)``; with ``eos_id >= 0``,
        sequences that sample EOS (including at prefill — the first token
        counts) stop consuming decode steps and their remaining positions
        are filled with ``eos_id``.  Once every sequence has finished the
        decode loop exits instead of burning the rest of the budget.
        """
        b = prompts.shape[0]
        eos = self.cfg.eos_id
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, caches = self._prefill(self.params, batch)
        tok = self._sample(logits)[:, None]
        done = np.asarray(tok[:, 0] == eos) if eos >= 0 else np.zeros(b, bool)
        outs = [tok]
        for _ in range(max_new_tokens - 1):
            if eos >= 0 and done.all():
                pad = jnp.full((b, 1), eos, jnp.int32)
                outs.extend([pad] * (max_new_tokens - len(outs)))
                break
            logits, caches = self._decode(self.params, caches, tok)
            nxt = self._sample(logits)
            if eos >= 0:
                nxt = jnp.where(jnp.asarray(done), eos, nxt)
                done |= np.asarray(nxt == eos)
            tok = nxt[:, None]
            outs.append(tok)
        return np.asarray(jnp.concatenate(outs, axis=1))

    # ------------------------------------------------- continuous batching
    def start_session(self, requests: Optional[List[Request]] = None,
                      fault_injector=None) -> "EngineSession":
        """Open a reentrant serving session (DESIGN.md §7).

        The returned :class:`EngineSession` owns the decode batch, page
        allocator, and request queue, and hands control back to the host
        between decode steps: ``submit()`` enqueues requests at any time,
        ``step(k)`` runs up to ``k`` decode steps (admissions, deadline
        sweeps, and completions happen at the step boundaries), and
        ``drain()`` runs to quiescence.  ``serve()`` below is the thin
        blocking wrapper; a :class:`~repro.serve.router.Router` interleaves
        many sessions — one per replica — through this interface.  The
        "run K steps, then sync host state" cadence is also the shape the
        ROADMAP's on-device ``lax.while_loop`` decode body slots into: the
        host side of this session is already written against it.
        """
        injector = fault_injector if fault_injector is not None \
            else self.fault_injector
        return EngineSession(self, requests or [], injector)

    def restore_session(self, snap, fault_injector=None):
        """Crash-recovery convenience: fresh session + load a
        :meth:`EngineSession.snapshot`.  Returns ``(session, requests)``
        where ``requests`` are the re-enqueued handles in queue order —
        ``session.drain()`` completes them token-identically to the
        streams the dead process was producing."""
        session = self.start_session([], fault_injector)
        return session, session.restore(snap)

    def serve(self, requests: List[Request],
              fault_injector=None) -> List[Request]:
        """Continuous mixed-length batching over a request queue.

        Thin blocking wrapper over :meth:`start_session` +
        :meth:`EngineSession.drain`.  Slots share one jit'd decode over
        the fixed batch; prefill is per-request (batch 1) and its cache is
        committed into the slot — page-pool scatter for paged layers,
        slot-axis splice for rings / recurrent state / dense mode
        (``serve/paging.commit_prefill``).  Finished slots immediately
        pull the next queued request — no head-of-line blocking on long
        generations, no drain barriers, no cache resets.

        Semantics:

        * prompt lengths may differ freely within one live batch: the
          per-slot position index keeps each slot's attention offsets
          independent, so a request admitted into a half-decoded batch
          neither inherits the batch's write head (the old stale-offset
          drift) nor disturbs the other slots;
        * paged layout, ``admission_policy="prompt"`` (default): admission
          reserves only the pages the request's *resident* tokens need;
          when a decode boundary then finds the pool dry, the
          latest-admitted slot is **recompute-preempted** — its pages are
          freed and the request re-enqueued at the queue head with its
          generated prefix prepended, to be re-prefilled when pages free
          (DESIGN.md §6.4).  Earlier-admitted requests always keep their
          pages (FIFO: the earliest active slot can never be starved), so
          pools sized below aggregate worst case make progress instead of
          blocking.  ``admission_policy="worst_case"`` restores the PR 5
          behavior: worst-case reservations, admission **defers** on
          exhaustion, decode-boundary allocation never fails;
        * per-request fault isolation (unless ``strict=True``): an
          oversized request (budget beyond ``max_seq``, or a worst-case
          page count larger than the whole pool) is **rejected**
          (``status="rejected"``) instead of raising; an exception during
          a request's prefill, or an injected per-request decode fault,
          **fails** that request (``status="failed"``) and frees its
          slot/pages while the rest of the batch keeps serving.  A
          :class:`~repro.train.fault.FaultInjector` (argument, or the
          engine's ``fault_injector``) is consulted at the per-request
          prefill and token-commit sites.  The injector's ``"replica"``
          site is the exception: it models a whole-engine fault (node
          loss) and raises out of ``step()``/``serve()`` regardless of
          ``strict`` — the router catches it and migrates the session's
          in-flight requests to surviving replicas (DESIGN.md §7);
        * deadlines: a request whose ``deadline_s`` (or the config
          default) elapses — measured from its **arrival**
          (``Request.arrival_t``; for batch-submitted calls like this one,
          serve() entry), so queue wait counts — is timed out at the next
          decode boundary (or while still queued), keeping its partial
          ``out``;
        * a request whose first (prefill-sampled) token is EOS, or whose
          ``max_new_tokens <= 1``, completes immediately without spending
          decode steps, a slot, or pages;
        * per-request timing lands in ``queue_s`` / ``prefill_s`` /
          ``latency_s`` (see :class:`Request`) — ``latency_s`` is measured
          from the request's own processing start, not the serve() call;
        * observability lands in ``self.paging_stats`` after every call —
          a plain-dict view rendered from the session's typed metrics
          registry (:meth:`EngineSession.stats_snapshot`, DESIGN.md §13):
          pages in use / high-water, fragmentation, deferrals, preemption
          counters (``preemptions``, ``recompute_tokens``, ``evictions``,
          ``pages_evicted``), per-status counts (``completed`` /
          ``rejected`` / ``failed`` / ``timed_out``), straggler decode
          steps flagged by a :class:`~repro.train.fault.Watchdog` over
          ``self.fault_cfg``, plus ``request_timing`` histogram states
          and ``latency_percentiles`` (p50/p95/p99 of queue_s /
          prefill_s / latency_s).  Attach a
          :class:`repro.obs.trace.Tracer` to ``self.tracer`` before the
          call for the matching per-request span timeline.
        """
        session = self.start_session(requests, fault_injector)
        session.drain()
        self.paging_stats = session.stats_snapshot()
        return requests


class EngineSession:
    """Reentrant serving stepper over one :class:`Engine` (DESIGN.md §7).

    Holds everything ``Engine.serve`` used to keep as loop locals — the
    decode batch, page allocator, request queue, per-slot bookkeeping, and
    stats — so the host can run ``step(k)`` decode steps, regain control,
    and interleave other work (other replicas, admissions, I/O) between
    bursts.  All the §6 serving semantics (recompute preemption,
    per-request fault isolation, deadlines, prefill-EOS fast path) live
    here unchanged; ``Engine.serve`` is a ``drain()`` around this class.

    Faults split into two tiers:

    * **request tier** — prefill/decode-site injections and real
      exceptions in a request's prefill fail only that request
      (``strict=False``), exactly as before;
    * **replica tier** — an injected ``("replica", k)`` fault (checked
      once per decode step, ``k`` = this session's decode-step count) or
      any exception escaping the decode dispatch itself raises out of
      ``step()``: the whole session is presumed lost.  The router
      harvests ``inflight()`` (generated prefixes intact in ``out``) and
      re-prefills them on surviving replicas — the same prompt+prefix
      recompute path preemption uses, so migrated streams stay
      oracle-identical.
    """

    def __init__(self, engine: Engine, requests: List[Request],
                 injector=None):
        from repro.train.fault import Watchdog
        self.engine = engine
        cfg = engine.cfg
        self.cfg = cfg
        self.n = cfg.n_slots
        self.paged = cfg.kv_layout == "paged"
        self.strict = cfg.strict
        self.clock = engine.clock
        self.injector = injector
        self.geom = self.alloc = None
        if self.paged:
            self.geom = paging.geometry(cfg.max_seq, cfg.page_size, self.n,
                                        cfg.n_pages)
            self.alloc = paging.PageAllocator(self.geom, self.n,
                                              policy=cfg.admission_policy,
                                              strict=cfg.strict)
        self.kv_integrity = cfg.kv_integrity and self.paged
        self.caches = engine.model.init_cache(self.n, cfg.max_seq,
                                              paging=self.geom)
        self.queue: deque = deque()
        self.active: List[Optional[Request]] = [None] * self.n
        self.remaining = [0] * self.n
        self.pos = [0] * self.n             # tokens resident per slot
        self.admit_seq = [-1] * self.n      # admission order per slot
        self.seq_counter = 0
        self.started: Dict[int, float] = {}  # id(req) → first slotting time
        self.cur_tok = jnp.zeros((self.n, 1), jnp.int32)
        self.t_start = self.clock()
        self.watchdog = Watchdog(engine.fault_cfg)
        self.prefill_count = 0              # prefill site index (injector)
        # observability (DESIGN.md §13): ``stats`` keeps its historical
        # dict interface but is a view over a typed metrics registry;
        # request timing feeds histograms so percentiles survive replica
        # merging and host-state snapshots.  The tracer comes from the
        # engine (NOOP when tracing is off); spans land on this replica's
        # track — one ``slot<k>`` lane per slot plus a ``session`` lane.
        self.trace = engine.tracer if engine.tracer is not None \
            else obs_trace.NOOP
        self.label = engine.trace_label
        self.track = (self.label, "session")
        self.metrics = obs_metrics.MetricsRegistry()
        self.stats = self.metrics.view(
            counters=("decode_steps", "decode_dispatches",
                      "admission_deferrals"),
            gauges=("peak_live_tokens", "frag_at_high_water"))
        for key in ("requests", "completed", "preemptions",
                    "recompute_tokens", "rejected", "failed", "timed_out",
                    "restores", "restore_recompute_tokens",
                    "nonfinite_logits"):
            self.stats[key] = 0
        self.stats["frag_at_high_water"] = 0.0
        self.hists = {name: self.metrics.histogram(name)
                      for name in ("queue_s", "prefill_s", "latency_s")}
        if self.alloc is not None and self.trace.enabled:
            self.alloc.tracer = self.trace
            self.alloc.trace_track = self.track
        for req in requests:
            self.submit(req)

    # ------------------------------------------------------------ queries
    @property
    def idle(self) -> bool:
        """No queued and no resident work."""
        return not self.queue and all(a is None for a in self.active)

    @property
    def num_queued(self) -> int:
        return len(self.queue)

    @property
    def num_active(self) -> int:
        return sum(a is not None for a in self.active)

    @property
    def has_free_slot(self) -> bool:
        return any(a is None for a in self.active)

    @property
    def free_pages(self) -> int:
        """Routing signal: free pages in this session's pool (dense
        sessions report free slots — the analogous capacity unit)."""
        if self.alloc is not None:
            return self.alloc.free_pages
        return sum(a is None for a in self.active)

    def inflight(self) -> List[Request]:
        """Undone requests this session owns, FIFO: resident slots in
        admission order, then the queue.  This is what a router migrates
        when the replica dies — each request's generated prefix is in
        ``out``, so re-admission elsewhere resumes it exactly."""
        resident = sorted((s for s in range(self.n)
                           if self.active[s] is not None),
                          key=lambda s: self.admit_seq[s])
        return [self.active[s] for s in resident] + \
            [r for r in self.queue if not r.done]

    # ---------------------------------------------------------- lifecycle
    def submit(self, req: Request, front: bool = False) -> None:
        """Enqueue a request (``front=True``: ahead of the line — used for
        preemption re-entry and router migrations).  Stamps ``arrival_t``
        on first submission; a migrated request keeps its original arrival
        so its deadline keeps running across replicas."""
        if req.arrival_t is None:
            req.arrival_t = self.clock()
        self.stats["requests"] += 1
        # idempotent per request: a router-migrated request keeps its
        # one open lifeline instead of starting a second one
        self.trace.request_begin(req, self.track, prompt=len(req.tokens))
        if front:
            self.queue.appendleft(req)
        else:
            self.queue.append(req)

    def _deadline_expired(self, req: Request, now: float) -> bool:
        d = req.deadline_s if req.deadline_s is not None else \
            (self.cfg.deadline_s if self.cfg.deadline_s > 0 else None)
        return d is not None and (now - req.arrival_t) > d

    def _finish_ok(self, req: Request) -> None:
        req.done = True
        req.status = "ok" if req.preemptions == 0 \
            else f"preempted_{req.preemptions}"
        req.latency_s = self.clock() - self.started[id(req)]
        self.stats["completed"] += 1
        self.hists["latency_s"].observe(req.latency_s)
        self.trace.request_end(req, self.track, status=req.status,
                               tokens=len(req.out or ()))

    def _finish_bad(self, req: Request, status: str, error: str,
                    slot: Optional[int] = None) -> None:
        """Terminal failure for ONE request: record status/error, free
        its slot and pages, leave everyone else serving."""
        req.done = True
        req.status = status
        req.error = error
        if req.out is None:
            req.out = []
        if id(req) in self.started:
            req.latency_s = self.clock() - self.started[id(req)]
            self.hists["latency_s"].observe(req.latency_s)
        self.stats[status] += 1
        if status == "timed_out":
            self.trace.instant("deadline_expired", self.track,
                               queued=slot is None)
        self.trace.request_end(req, self.track, status=status)
        if slot is not None:
            self.trace.end("request", (self.label, f"slot{slot}"),
                           status=status)
            self.active[slot] = None
            if self.paged:
                self.alloc.release(slot)

    def _preempt_slot(self, slot: int) -> None:
        """Recompute-preempt one specific slot: free its pages (corrupt
        ones land in quarantine at release), re-enqueue the request at
        the queue HEAD with its generated prefix kept in ``out`` —
        re-admission prefills prompt+prefix and resumes sampling where
        it left off."""
        req = self.active[slot]
        req.preemptions += 1
        req.status = f"preempted_{req.preemptions}"
        self.stats["preemptions"] += 1
        self.stats["recompute_tokens"] += self.pos[slot]
        self.trace.end("request", (self.label, f"slot{slot}"),
                       status=req.status)
        self.trace.instant("preempt", (self.label, f"slot{slot}"),
                           slot=slot, recompute_tokens=self.pos[slot])
        self.active[slot] = None
        if self.paged:
            self.alloc.release(slot, evicted=True)
        self.queue.appendleft(req)

    def _preempt_victim(self) -> int:
        """Recompute-preempt the latest-admitted (fewest tokens
        generated) active slot (see :meth:`_preempt_slot`).  Returns the
        victim slot.  FIFO: the victim was admitted before anything
        still queued (later evictions are earlier admissions —
        appendleft keeps them ordered ahead of this one)."""
        victim = max((s for s in range(self.n)
                      if self.active[s] is not None),
                     key=lambda s: (self.admit_seq[s],
                                    -len(self.active[s].out)))
        self._preempt_slot(victim)
        return victim

    # ---------------------------------------------------- page integrity
    def _record_checksums(self) -> None:
        """Chunk-commit boundary: fingerprint every live page's committed
        contents into the allocator's checksum table (DESIGN.md §7.6).
        A slot with ``pos`` resident tokens has committed exactly the
        first ``pos`` rows of its page chain; lookahead pages with no
        committed rows carry no record (nothing to protect yet)."""
        alloc, ps = self.alloc, self.geom.page_size
        committed: Dict[int, int] = {}
        for slot in range(self.n):
            if self.active[slot] is None:
                continue
            for j, page in enumerate(alloc.slot_pages[slot]):
                ntok = min(ps, self.pos[slot] - j * ps)
                if ntok > 0:
                    committed[page] = ntok
        for page in list(alloc.checksums):
            if page not in committed:
                del alloc.checksums[page]
        for page, crc in paging.page_fingerprints(self.caches,
                                                  committed).items():
            alloc.record_checksum(page, committed[page], crc)

    def _verify_integrity(self) -> None:
        """Pre-dispatch verify: recompute every recorded page's crc over
        its recorded committed length and compare.  A mismatch means the
        page mutated between commit boundaries with no token having been
        sampled from it yet (the verify runs before the next dispatch),
        so recovery is surgical and oracle-exact: quarantine the page,
        recompute-preempt exactly the slots whose block tables reference
        it (their ``out`` prefixes predate the corruption), null the
        affected table rows on device, and leave every other slot
        untouched."""
        alloc = self.alloc
        if not alloc.checksums:
            return
        recorded = dict(alloc.checksums)
        crcs = paging.page_fingerprints(
            self.caches, {p: lc[0] for p, lc in recorded.items()})
        bad = [p for p, crc in crcs.items() if crc != recorded[p][1]]
        if not bad:
            return
        victims = set()
        for page in bad:
            owner = alloc.owner_of(page)
            alloc.quarantine(page)
            if owner is not None and self.active[owner] is not None:
                victims.add(owner)
        # preempt in reverse admission order so appendleft leaves the
        # earliest-admitted victim at the queue head (FIFO preserved)
        for slot in sorted(victims, key=lambda s: self.admit_seq[s],
                           reverse=True):
            self._preempt_slot(slot)
        self.caches = paging.sync_block_tables(self.caches, alloc.table)

    def _quarantine_slot_pages(self, slot: int) -> None:
        """A slot's logits went non-finite mid-dispatch: localize the
        poison in its page chain and quarantine it (the preempting
        release then withholds those pages from the free list).  NaN
        leaks through the attention mask from any position of a touched
        page — including uncommitted tail positions the checksums don't
        cover — so localization scans the pages for non-finite values
        directly, falls back to checksum mismatches, and as a last
        resort quarantines the whole chain (losing a few clean pages
        beats re-admitting onto a poisoned one)."""
        alloc = self.alloc
        chain = list(alloc.slot_pages[slot])
        bad = paging.pages_nonfinite(self.caches, chain)
        if not bad:
            recorded = {p: alloc.checksums[p][0] for p in chain
                        if p in alloc.checksums}
            bad = {p for p, crc in paging.page_fingerprints(
                self.caches, recorded).items()
                if crc != alloc.checksums[p][1]}
        if not bad:
            bad = set(chain)
        for page in bad:
            alloc.quarantine(page)

    def _admit(self) -> None:
        """Fill free slots from the queue; a request finishing at prefill
        (EOS as its first token, or an exhausted budget) completes without
        ever occupying the slot, so the next queued request slots in."""
        cfg, alloc = self.cfg, self.alloc
        deferred = False
        for slot in range(self.n):
            while self.active[slot] is None and self.queue and not deferred:
                req = self.queue[0]
                now = self.clock()
                if self._deadline_expired(req, now):
                    self.queue.popleft()
                    self.started.setdefault(id(req), now)
                    req.queue_s = now - req.arrival_t
                    self.hists["queue_s"].observe(req.queue_s)
                    self._finish_bad(req, "timed_out",
                                     "deadline exceeded after "
                                     f"{now - req.arrival_t:.3f}s in queue")
                    continue
                prefix = req.out or []      # preempted: generated so far
                length = len(req.tokens) + len(prefix)
                budget = max(req.max_new_tokens, 1) - len(prefix)
                # max resident tokens: the last decode step has written
                # length + max_new - 1 of them (the final sampled token
                # never enters the cache) — preemption never raises it
                max_resident = len(req.tokens) \
                    + max(req.max_new_tokens, 1) - 1
                if max_resident > cfg.max_seq:
                    msg = (f"request needs {max_resident} cache "
                           f"positions (prompt {len(req.tokens)} + "
                           f"max_new_tokens {req.max_new_tokens} - 1) "
                           f"but max_seq is {cfg.max_seq}")
                    if self.strict:
                        raise ValueError(msg)
                    self.queue.popleft()
                    self._finish_bad(req, "rejected", msg)
                    continue
                worst = 0
                if self.paged:
                    worst = alloc.pages_for(max_resident)
                    if worst > alloc.usable:
                        msg = (f"request needs up to {worst} pages but "
                               f"the pool has {alloc.usable}: raise "
                               f"n_pages or lower max_new_tokens")
                        if self.strict:
                            raise ValueError(msg)
                        self.queue.popleft()
                        self._finish_bad(req, "rejected", msg)
                        continue
                    if not alloc.can_admit(
                            alloc.admission_pages(length, worst)):
                        # FIFO: don't let shorter later requests starve
                        # the head — stop admitting until pages free
                        self.stats["admission_deferrals"] += 1
                        deferred = True
                        break
                self.queue.popleft()
                t0 = self.clock()
                if id(req) not in self.started:
                    self.started[id(req)] = t0
                    req.queue_s = t0 - req.arrival_t
                    self.hists["queue_s"].observe(req.queue_s)
                lane = (self.label, f"slot{slot}")
                self.trace.begin("request", lane,
                                 prompt=len(req.tokens),
                                 prefix=len(prefix))
                tokens = req.tokens if not prefix else np.concatenate(
                    [np.asarray(req.tokens, np.int32),
                     np.asarray(prefix, np.int32)])
                site = self.prefill_count
                self.prefill_count += 1
                self.trace.begin("prefill", lane, tokens=len(tokens))
                try:
                    if self.injector is not None:
                        self.injector.check(site, site="prefill")
                    logits, slot_cache = self.engine._prefill(
                        self.engine.params,
                        {"tokens": jnp.asarray(tokens[None, :],
                                               jnp.int32)})
                    first = int(self.engine._sample(logits)[0])
                except Exception as e:  # noqa: BLE001 — isolate request
                    if self.strict:
                        raise
                    self.trace.end("prefill", lane, error=True)
                    self.trace.end("request", lane, status="failed")
                    self._finish_bad(req, "failed", repr(e))
                    continue
                self.trace.end("prefill", lane)
                if req.out is None:
                    req.out = []
                req.out.append(first)
                if not prefix:
                    req.prefill_s = self.clock() - t0
                    self.hists["prefill_s"].observe(req.prefill_s)
                if first == cfg.eos_id or budget <= 1:
                    self.trace.end("request", lane, status="ok")
                    self._finish_ok(req)
                    continue
                if self.paged:
                    alloc.admit(slot, length, worst)
                    self.caches = paging.commit_prefill(
                        self.caches, slot_cache, slot, length, alloc.table,
                        self.geom.page_size)
                else:
                    self.caches = paging.commit_prefill(
                        self.caches, slot_cache, slot, length)
                self.active[slot] = req
                self.admit_seq[slot] = self.seq_counter
                self.seq_counter += 1
                self.remaining[slot] = budget - 1
                self.pos[slot] = length
                self.cur_tok = self.cur_tok.at[slot, 0].set(first)

    def _sweep_deadlines(self) -> None:
        """Decode-boundary deadline sweep: expired slots free their pages
        before anyone is preempted for space."""
        now = self.clock()
        for slot in range(self.n):
            req = self.active[slot]
            if req is not None and self._deadline_expired(req, now):
                self._finish_bad(req, "timed_out",
                                 "deadline exceeded after "
                                 f"{now - req.arrival_t:.3f}s with "
                                 f"{len(req.out)} tokens", slot=slot)

    def _ensure_pages(self, horizon: int = 1) -> int:
        """Grow each active slot's pages for the next fused chunk and
        return the chunk length the pool can actually cover.

        Phase A (mandatory, unchanged §6.4 semantics): the next decode
        step writes each active slot's token at position ``pos[slot]`` —
        allocate that boundary page up front, earliest-admitted first.
        worst_case policy: always succeeds under the reservation
        invariant.  prompt policy: pool exhaustion preempts the
        latest-admitted slot (possibly the requester itself) and retries
        — the earliest active slot can always make progress, since alone
        it fits by the worst-case-vs-pool admission check.

        Phase B (chunk horizon): extend surviving slots to cover
        ``min(horizon, remaining)`` further steps, shrinking ``horizon``
        until the extension fits the FREE pool — extension never
        preempts and never raises, so a fused chunk of the returned
        length cannot exhaust the pool mid-flight.  A slot running ``s``
        steps writes positions ``pos .. pos+s-1`` (its final sampled
        token never enters the cache), and ``pos + remaining`` is the
        admission-checked max residency, so the extension stays within
        each slot's worst-case cap.
        """
        alloc = self.alloc
        changed = False
        order = sorted((s for s in range(self.n)
                        if self.active[s] is not None),
                       key=lambda s: self.admit_seq[s])
        for slot in order:
            if self.active[slot] is None:
                continue                 # evicted as a victim below
            while True:
                try:
                    changed |= alloc.ensure(slot, self.pos[slot] + 1)
                    break
                except paging.PoolExhausted:
                    victim = self._preempt_victim()
                    changed = True       # victim's table row went null
                    if victim == slot:
                        break            # requester evicted itself
        k = max(1, horizon)
        if k > 1:
            live = [s for s in order if self.active[s] is not None]

            def extra(steps: int) -> int:
                return sum(
                    max(0, alloc.pages_for(
                        self.pos[s] + min(steps, self.remaining[s]))
                        - len(alloc.slot_pages[s]))
                    for s in live)

            while k > 1 and extra(k) > alloc.free_pages:
                k -= 1
            for s in live:
                changed |= alloc.ensure(
                    s, self.pos[s] + min(k, self.remaining[s]))
        if changed:
            self.caches = paging.sync_block_tables(self.caches, alloc.table)
        return k

    def _record_live(self) -> None:
        """Live-token peak is layout-agnostic (the dense layout used to
        report 0, skewing the paged-vs-dense residency comparison);
        called once per committed decode row so chunked serving sees the
        same per-step peaks the stepwise cadence did."""
        live = sum(self.pos[s] + 1 for s in range(self.n)
                   if self.active[s] is not None)
        self.stats["peak_live_tokens"] = max(
            self.stats["peak_live_tokens"], live)
        if self.paged and self.alloc.pages_in_use >= self.alloc.high_water:
            self.stats["frag_at_high_water"] = 1.0 - live / max(
                self.alloc.pages_in_use * self.geom.page_size, 1)

    def step(self, max_steps: int = 1) -> int:
        """Run up to ``max_steps`` decode steps; returns how many ran.

        Chunked cadence (DESIGN.md §7.1): each iteration admits from the
        queue, sweeps deadlines, grows/preempts pages out to the chunk
        horizon, then launches ONE fused on-device dispatch
        (``device_loop.build_fused_decode``) that runs up to
        ``decode_chunk`` decode+sample steps before syncing back — the
        returned ``(k, n_slots)`` token block is committed host-side
        row by row with exactly the stepwise per-slot semantics
        (per-request decode fault sites, EOS/budget completion, page
        release).  Admission-only iterations (heads rejected / timed out
        / finished at prefill) don't count against ``max_steps``.

        A replica-tier fault (see class docstring) raises out of this
        method with the session state intact for ``inflight()``
        harvesting; an armed replica fault *inside* the upcoming chunk
        splits the chunk at the fault step, so the tokens before it are
        committed (a partially-committed chunk migrates) and the fault
        fires at exactly the stepwise decode-step index.
        """
        cfg = self.cfg
        ran = 0
        while ran < max_steps and (
                self.queue or any(a is not None for a in self.active)):
            if self.kv_integrity:
                # commit-boundary verify BEFORE admission: corruption
                # detected here frees/quarantines pages and re-enqueues
                # its victims at the head, so recovery re-prefills in
                # this very iteration
                self._verify_integrity()
            self._admit()
            if all(a is None for a in self.active):
                if self.queue:
                    continue     # heads were rejected/timed out — refill
                break            # the fill loop drained the queue
            self._sweep_deadlines()
            chunk = min(max(1, cfg.decode_chunk), max_steps - ran)
            if self.paged:
                chunk = self._ensure_pages(chunk)
            self._record_live()  # chunk-boundary peak (pre-dispatch)
            if all(a is None for a in self.active):
                continue         # deadline sweep / self-eviction emptied
            if self.injector is not None:
                # process-tier fault first (exact-match so bare ints can't
                # escalate): the whole process dies — ProcessKilled raises
                # through the router to the crash drill, which restores
                # the latest snapshot.  Then replica tier: the engine dies
                # mid-decode — deliberately NOT per-request isolated,
                # raises out of step() so the router migrates this
                # session's inflight().  An armed step strictly inside the
                # chunk caps it, so the next iteration fires the fault at
                # the stepwise index with the pre-fault rows committed.
                self.injector.check(self.stats["decode_steps"],
                                    site="process", exact=True)
                self.injector.check(self.stats["decode_steps"],
                                    site="replica")
                lo = self.stats["decode_steps"] + 1
                hi = self.stats["decode_steps"] + chunk
                faults = [f for f in (
                    self.injector.next_armed("replica", lo, hi),
                    self.injector.next_armed("process", lo, hi, exact=True))
                    if f is not None]
                if faults:
                    chunk = min(faults) - self.stats["decode_steps"]
                if self.paged:
                    # corruption striking INSIDE the dispatch window:
                    # injected after the boundary verify, caught by the
                    # commit loop's NaN/Inf screen instead
                    idx = self.injector.take("page_nan")
                    if idx is not None:
                        self.caches = paging.corrupt_page(
                            self.caches, idx, nan=True)
            if self.trace.enabled:
                if self.paged:
                    self.trace.counter("free_pages", self.track,
                                       free=self.alloc.free_pages)
                self.trace.begin("decode_chunk", self.track,
                                 chunk=int(chunk),
                                 active=self.num_active)
            rem_dev = jnp.asarray(
                [self.remaining[s] if self.active[s] is not None else 0
                 for s in range(self.n)], jnp.int32)
            act_dev = jnp.asarray(
                [a is not None for a in self.active], bool)
            step_t0 = self.clock()
            block, steps_ran, tok, key, self.caches, logit_ok = \
                self.engine._fused_decode(
                    self.engine.params, self.caches, self.cur_tok,
                    rem_dev, act_dev, self.engine._key,
                    jnp.asarray(chunk, jnp.int32))
            steps = int(steps_ran)
            self.cur_tok = tok
            self.engine._key = key
            block = np.asarray(jax.device_get(block))
            ok_block = np.asarray(jax.device_get(logit_ok))
            self.stats["decode_dispatches"] += 1
            # normalize wall time by steps actually fused into this
            # dispatch — a k-step chunk must not read as a k× straggler
            if self.watchdog.observe(self.stats["decode_steps"],
                                     (self.clock() - step_t0)
                                     / max(steps, 1)):
                self.trace.instant("straggler_flagged", self.track,
                                   step=self.stats["decode_steps"])
            for i in range(steps):
                if all(a is None for a in self.active):
                    break        # decode faults emptied the batch early
                if i > 0:
                    self._record_live()
                self.stats["decode_steps"] += 1
                ran += 1
                for slot in range(self.n):
                    req = self.active[slot]
                    if req is None:
                        continue
                    if self.injector is not None:
                        try:
                            # per-request decode site: "this request
                            # committing its len(out)-th generated token"
                            self.injector.check(len(req.out), site="decode")
                        except Exception as e:  # noqa: BLE001 — isolate
                            if self.strict:
                                raise
                            self._finish_bad(req, "failed", repr(e),
                                             slot=slot)
                            continue
                    if self.kv_integrity and not ok_block[i, slot]:
                        # poisoned logits: this slot's pages were
                        # corrupted inside the dispatch window.  The
                        # tainted token is never committed, so ``out``
                        # holds only clean tokens — quarantine the bad
                        # page(s) and recompute-preempt just this slot
                        self.stats["nonfinite_logits"] += 1
                        self._quarantine_slot_pages(slot)
                        self._preempt_slot(slot)
                        continue
                    tok_i = int(block[i, slot])
                    req.out.append(tok_i)
                    self.pos[slot] += 1
                    self.remaining[slot] -= 1
                    if self.remaining[slot] <= 0 or tok_i == cfg.eos_id:
                        self._finish_ok(req)
                        self.trace.end("request",
                                       (self.label, f"slot{slot}"),
                                       status=req.status)
                        self.active[slot] = None
                        if self.paged:
                            self.alloc.release(slot)
            if self.kv_integrity:
                self._record_checksums()
            self.trace.end("decode_chunk", self.track, steps=steps)
            if self.injector is not None and self.paged:
                # silent corruption at rest: injected AFTER the boundary
                # fingerprints, so the recorded crc reflects the clean
                # contents and the next iteration's verify flags the page
                idx = self.injector.take("page")
                if idx is not None:
                    self.caches = paging.corrupt_page(self.caches, idx)
        return ran

    def drain(self) -> None:
        """Run to quiescence: every submitted request reaches a terminal
        status.  New ``submit()``s after drain() returns start it again."""
        while not self.idle:
            self.step(max_steps=1 << 30)

    # ------------------------------------------------- snapshot / restore
    def snapshot(self) -> Dict:
        """Crash-consistent session state as a JSON-serializable dict
        (DESIGN.md §7.6).

        Captures the *host* truth only — undone requests in ``inflight()``
        order (prompt tokens, generated prefix, budgets, deadline ages),
        counters, the engine PRNG key, and the allocator's quarantine/
        accounting state.  Raw KV tensors are deliberately NOT serialized:
        :meth:`restore` re-enqueues each request with its prefix in
        ``out``, so re-admission re-prefills prompt+prefix through the
        recompute path and the resumed stream is token-identical to the
        ``generate()`` oracle.  Deadlines are stored as ages
        (``now - arrival_t``) and rebased on the restoring session's
        clock, so a half-spent deadline stays half-spent across the
        restart."""
        now = self.clock()
        reqs = [request_to_state(req, now) for req in self.inflight()]
        snap: Dict = {
            "version": 1,
            "kv_layout": self.cfg.kv_layout,
            "n_slots": self.n,
            "requests": reqs,
            "stats": dict(self.stats),
            # latency/queue/prefill histogram states ride the snapshot so
            # restored percentiles cover the pre-crash population too
            "request_timing": {name: h.state()
                               for name, h in self.hists.items()},
            "prng_key": np.asarray(
                jax.device_get(self.engine._key)).tolist(),
        }
        self.trace.instant("snapshot", self.track, requests=len(reqs))
        if self.paged:
            snap["alloc"] = {
                "quarantined": sorted(self.alloc.quarantined
                                      | self.alloc._pending_quarantine),
                "double_release": self.alloc.double_release,
                "evictions": self.alloc.evictions,
                "pages_evicted": self.alloc.pages_evicted,
                "page_high_water": self.alloc.high_water,
            }
        return snap

    def restore(self, snap: Dict) -> List[Request]:
        """Load a :meth:`snapshot` into this (idle, freshly-built)
        session: counters resume, the PRNG key is reinstated, quarantined
        pages stay out of circulation across the restart, and every
        snapshotted request is re-enqueued FIFO with its generated prefix
        — the next ``step()``/``drain()`` re-prefills and resumes each
        stream exactly where the dead process left it.  Returns the new
        :class:`Request` objects in queue order (the handles the caller
        watches; re-prefilled prompt+prefix tokens are counted in
        ``restore_recompute_tokens``)."""
        if not self.idle:
            raise RuntimeError("restore() needs an idle session — it "
                               "rebuilds the queue from the snapshot")
        if snap.get("kv_layout") != self.cfg.kv_layout:
            raise ValueError(
                f"snapshot was taken under kv_layout="
                f"{snap.get('kv_layout')!r} but this session runs "
                f"{self.cfg.kv_layout!r}")
        now = self.clock()
        self.engine._key = jnp.asarray(
            np.asarray(snap["prng_key"], np.uint32))
        for key, val in snap.get("stats", {}).items():
            if key in self.stats:
                self.stats[key] = val
        for name, state in snap.get("request_timing", {}).items():
            if name in self.hists:
                self.hists[name].load(state)
        self.stats["restores"] += 1
        if self.paged and "alloc" in snap:
            a = snap["alloc"]
            # replay quarantines with the allocator's tracer off: the
            # process that found the corruption already traced these
            # pages, and the restored pages_quarantined counter must
            # keep matching the trace's page_quarantine event count
            saved_tracer = self.alloc.tracer
            self.alloc.tracer = None
            try:
                for page in a.get("quarantined", ()):
                    self.alloc.quarantine(page)
            finally:
                self.alloc.tracer = saved_tracer
            self.alloc.double_release = a.get("double_release", 0)
            self.alloc.evictions = a.get("evictions", 0)
            self.alloc.pages_evicted = a.get("pages_evicted", 0)
            self.alloc.high_water = max(self.alloc.high_water,
                                        a.get("page_high_water", 0))
        restored: List[Request] = []
        for rs in snap.get("requests", []):
            req = request_from_state(rs, now)
            if req.out:
                # the whole prompt+prefix must re-prefill — the KV pages
                # died with the process
                self.stats["restore_recompute_tokens"] += \
                    len(req.tokens) + len(req.out)
            # bypass submit(): the snapshotted stats already counted
            # these requests once
            self.queue.append(req)
            restored.append(req)
        self.trace.instant("restore", self.track,
                           requests=len(restored))
        return restored

    def stats_snapshot(self) -> Dict:
        """Current counters in the ``Engine.paging_stats`` shape; callable
        at any point in the session (the router snapshots mid-flight)."""
        stats = dict(self.stats)
        stats["straggler_decode_steps"] = len(self.watchdog.events)
        stats["request_timing"] = {name: h.state()
                                   for name, h in self.hists.items()}
        stats["latency_percentiles"] = obs_metrics.timing_percentiles(
            stats["request_timing"])
        if self.paged:
            stats.update(self.alloc.stats())
            stats["kv_layout"] = "paged"
            # dense-equivalent residency: what (n_slots, S_max) slabs pin
            stats["dense_equiv_tokens"] = self.n * self.cfg.max_seq
            stats["paged_peak_tokens"] = stats["page_high_water"] \
                * self.geom.page_size
        else:
            stats["kv_layout"] = "dense"
        return stats
