"""Paged KV-cache subsystem: page pool allocator + cache commit/sync ops.

The serving-side analogue of the paper's row-grouping (DESIGN.md §6):
fixed-size pages trade bounded per-slot padding (at most ``page_size - 1``
dead token slots per request, inside its last page) for perfectly regular
addressing, exactly as RgCSR's uniform groups trade per-group padding for
regular strides — and, following the adaptive-format follow-up
(arXiv:1203.5737), residency is sized to *actual* sequence lengths instead
of the worst case: a slot holding a 37-token request owns
``ceil(37 / page_size)`` pages, not ``S_max`` rows.

Split of responsibilities:

* **Device side** (``models/attention.py``): each attention layer's cache is
  a shared page pool ``(n_pages, page_size, ...)`` plus per-slot
  ``block_table`` / ``index`` vectors; ``attend()`` gathers K/V through the
  block table and masks per slot, so slots at different positions decode in
  one batch.
* **Host side** (this module): :class:`PageAllocator` owns the free list
  and the authoritative block table.  Pages are allocated lazily — prompt
  pages at prefill-commit, one page at a time as decode crosses page
  boundaries — under one of two **admission policies** (DESIGN.md §6.4):

  - ``policy="worst_case"`` reserves each request's worst-case page count
    up front, so mid-decode allocation can never fail — pools sized below
    aggregate worst-case *defer* admissions (FIFO) until pages free;
  - ``policy="prompt"`` (the engine's default) reserves only the pages
    the resident tokens actually need, admitting more concurrent
    requests; when decode then crosses a page boundary with the pool dry,
    :meth:`ensure` raises :class:`PoolExhausted` and the engine
    recompute-preempts a victim slot (``release(evicted=True)``) —
    graceful overload instead of head-of-line blocking.

  Page 0 is reserved as the null page: free slots' table rows point at
  it, so their (ignored) decode writes land there instead of corrupting
  reallocated pages.

``commit_prefill`` bridges the two: prefill runs on an ordinary dense
batch-1 cache (the prompt-length-specialized jit the engine already has),
then its K/V slab is scattered into the slot's pages — ring buffers,
recurrent state, and dense-mode caches are spliced at the slot axis by the
same call, so the engine is layout-agnostic.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import PageGeometry
from repro.obs import metrics as obs_metrics

__all__ = ["PageGeometry", "PageAllocator", "PoolExhausted", "geometry",
           "commit_prefill", "sync_block_tables", "page_fingerprints",
           "corrupt_page", "SERVE_MERGE_SPEC"]

# cache keys that live in page pools (everything else is per-slot dense)
_POOL_KEYS = ("k", "v", "k_scale", "v_scale", "ckv", "krope")


def geometry(max_seq: int, page_size: int, n_slots: int,
             n_pages: int = 0) -> PageGeometry:
    """Resolve a :class:`PageGeometry`.  ``n_pages=0`` auto-sizes the pool
    to dense capacity (every slot can reach ``max_seq``) plus the null
    page — admission then never defers; smaller pools trade deferrals for
    memory."""
    pages_per_slot = -(-max_seq // page_size)
    if n_pages <= 0:
        n_pages = 1 + n_slots * pages_per_slot
    return PageGeometry(n_pages=n_pages, page_size=page_size,
                        pages_per_slot=pages_per_slot)


class PoolExhausted(RuntimeError):
    """Raised by :meth:`PageAllocator.ensure` under ``policy="prompt"``
    when a slot must grow but the free list is empty — the engine's
    signal to recompute-preempt a victim slot and retry."""


class PageAllocator:
    """Host-side page bookkeeping for one serve() run.

    Invariants (asserted on every mutation, see :meth:`_check`):

    * ``sum(reserved) <= usable_pages`` — admission control;
    * ``len(free) + pages_in_use == usable_pages`` — pages are never lost
      or double-owned (a double :meth:`release` would otherwise hand the
      same page to two slots);
    * each slot's physical pages never exceed its own worst-case cap.

    ``policy="worst_case"`` reserves the request's whole worst case at
    admission, so :meth:`ensure` can always pop a free page and decode
    never stalls.  ``policy="prompt"`` reserves only what the resident
    tokens need (the reservation tracks the allocation); :meth:`ensure`
    then raises :class:`PoolExhausted` when the pool runs dry and the
    caller must evict a victim (``release(evicted=True)``) before
    retrying.

    **Integrity extensions** (DESIGN.md §7.6): :meth:`quarantine` takes a
    page out of circulation permanently (suspected device-memory
    corruption) — a quarantined page shrinks :attr:`usable` so the
    accounting invariant keeps holding; :meth:`record_checksum` /
    :attr:`checksums` store per-page ``(committed_tokens, crc32)``
    fingerprints recorded by the engine at chunk-commit boundaries.
    ``strict=True`` upgrades the (counted) idempotent double-release
    near-miss into a hard error.
    """

    POLICIES = ("worst_case", "prompt")

    def __init__(self, geom: PageGeometry, n_slots: int,
                 policy: str = "worst_case", strict: bool = False):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}: "
                             f"expected one of {self.POLICIES}")
        self.geom = geom
        self.n_slots = n_slots
        self.policy = policy
        self.strict = strict
        # LIFO free list over pages 1..n_pages-1 (page 0 = null page);
        # popping the lowest id first keeps allocation deterministic
        self.free: List[int] = list(range(geom.n_pages - 1, 0, -1))
        self.table = np.zeros((n_slots, geom.pages_per_slot), np.int32)
        self.slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self.reserved = [0] * n_slots
        self.worst_cap = [geom.pages_per_slot] * n_slots
        self.high_water = 0
        # eviction accounting (preemption observability, DESIGN.md §6.4)
        self.evictions = 0
        self.pages_evicted = 0
        # integrity accounting (DESIGN.md §7.6)
        self.double_release = 0
        self.quarantined: set = set()          # out of circulation for good
        self._pending_quarantine: set = set()  # owned by a slot; withheld
        #                                        from the free list at release
        self.checksums: Dict[int, Tuple[int, int]] = {}
        # observability hook (DESIGN.md §13): the owning session points
        # these at its tracer so quarantines land on the replica's track.
        # None while tracing is off (and during restore-replay, where the
        # quarantines were already traced by the process that found them).
        self.tracer = None
        self.trace_track = None

    # ------------------------------------------------------------- queries
    @property
    def usable(self) -> int:
        """Pages the allocator may hand out: the geometric pool minus
        pages quarantined after corruption (pending ones still sit in a
        slot, so they count as in-use until released)."""
        return self.geom.usable_pages - len(self.quarantined)

    @property
    def pages_in_use(self) -> int:
        return sum(len(p) for p in self.slot_pages)

    @property
    def free_pages(self) -> int:
        """Pages available right now — the router's load-balance signal."""
        return len(self.free)

    def pages_for(self, n_tokens: int) -> int:
        return self.geom.pages_for(n_tokens)

    def admission_pages(self, n_tokens: int, worst_pages: int) -> int:
        """Pages admission will reserve for a request under this policy:
        the full worst case, or just the resident prompt's pages."""
        if self.policy == "prompt":
            return self.pages_for(n_tokens)
        return worst_pages

    def can_admit(self, pages: int) -> bool:
        return sum(self.reserved) + pages <= self.usable

    def _check(self) -> None:
        assert sum(self.reserved) <= self.usable, \
            "admission invariant violated: reservations exceed the pool"
        assert len(self.free) + self.pages_in_use == self.usable, \
            "page accounting violated: free list + in-use != usable " \
            "(double release or leaked page)"
        for s, pages in enumerate(self.slot_pages):
            assert len(pages) <= self.worst_cap[s], \
                f"slot {s} holds more pages than its worst case"

    # ------------------------------------------------------------- updates
    def admit(self, slot: int, n_tokens: int, worst_pages: int) -> bool:
        """Reserve pages for the slot per the admission policy and
        allocate the prompt's pages.  Returns False (nothing changed) when
        the pool can't cover the reservation — the caller defers the
        request."""
        need = self.admission_pages(n_tokens, worst_pages)
        if not self.can_admit(need):
            return False
        self.worst_cap[slot] = worst_pages
        self.reserved[slot] = need
        self.ensure(slot, n_tokens)
        return True

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow the slot's pages to cover ``n_tokens``; True if the block
        table changed (the engine then re-syncs device tables).  Under
        ``policy="prompt"`` the reservation grows with the allocation, and
        :class:`PoolExhausted` is raised if the free list runs dry — the
        partial growth is kept (the slot owns what it got) so the caller
        can evict a victim and retry the same call."""
        need = self.pages_for(n_tokens)
        if self.policy == "prompt":
            assert need <= self.worst_cap[slot], \
                f"slot {slot} grew past its worst-case cap"
        else:
            assert need <= self.reserved[slot], \
                f"slot {slot} grew past its admission reservation"
        changed = False
        pages = self.slot_pages[slot]
        try:
            while len(pages) < need:
                if self.policy == "prompt" and not self.free:
                    raise PoolExhausted(
                        f"slot {slot} needs page {len(pages) + 1}/{need} "
                        f"but the pool is dry")
                page = self.free.pop()
                self.table[slot, len(pages)] = page
                pages.append(page)
                if self.policy == "prompt":
                    self.reserved[slot] = len(pages)
                changed = True
        finally:
            if self.pages_in_use > self.high_water:
                self.high_water = self.pages_in_use
            self._check()
        return changed

    def release(self, slot: int, evicted: bool = False) -> int:
        """Free the slot on completion/eviction: pages return to the pool,
        the table row points back at the null page, the reservation lifts.
        The *cache contents* are untouched — slot reuse needs no reset.

        Idempotent: releasing an already-free slot is a no-op (it must
        not re-extend the free list — that would hand the same page to
        two slots).  Returns the number of pages freed; ``evicted=True``
        additionally counts the free toward the preemption accounting."""
        freed = len(self.slot_pages[slot])
        if freed == 0 and self.reserved[slot] == 0:
            # near-miss: harmless today, but a second release of a live
            # slot would double-own pages — count it so accounting bugs
            # upstream are observable (raise when strict)
            self.double_release += 1
            if self.strict:
                raise RuntimeError(
                    f"double release of already-free slot {slot}")
            return 0
        for page in reversed(self.slot_pages[slot]):
            self.checksums.pop(page, None)
            if page in self._pending_quarantine:
                self._pending_quarantine.discard(page)
                self.quarantined.add(page)
            else:
                self.free.append(page)
        self.slot_pages[slot] = []
        self.table[slot] = 0
        self.reserved[slot] = 0
        self.worst_cap[slot] = self.geom.pages_per_slot
        if evicted:
            self.evictions += 1
            self.pages_evicted += freed
        self._check()
        return freed

    # ---------------------------------------------------------- integrity
    def owner_of(self, page: int) -> Optional[int]:
        """Slot currently holding ``page``, or None (free/quarantined)."""
        for slot, pages in enumerate(self.slot_pages):
            if page in pages:
                return slot
        return None

    def quarantine(self, page: int) -> bool:
        """Take a (suspected-corrupt) page out of circulation for the
        rest of this allocator's life.  A free page leaves the free list
        immediately; a page still owned by a slot is marked pending and
        withheld from the free list when that slot releases.  Returns
        False if the page was already quarantined (idempotent)."""
        if not 0 < page < self.geom.n_pages:
            raise ValueError(f"page {page} outside pool "
                             f"(1..{self.geom.n_pages - 1})")
        if page in self.quarantined or page in self._pending_quarantine:
            return False
        self.checksums.pop(page, None)
        if page in self.free:
            self.free.remove(page)
            self.quarantined.add(page)
        else:
            self._pending_quarantine.add(page)
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.instant("page_quarantine", self.trace_track,
                                page=page)
        self._check()
        return True

    @property
    def pages_quarantined(self) -> int:
        return len(self.quarantined) + len(self._pending_quarantine)

    def record_checksum(self, page: int, n_tokens: int, crc: int) -> None:
        """Record the fingerprint of a page's committed contents (engine
        calls this at chunk-commit boundaries; n_tokens is how many of
        the page's token rows the crc covers)."""
        self.checksums[page] = (int(n_tokens), int(crc))

    def stats(self) -> dict:
        return {
            "n_pages": self.geom.n_pages,
            "page_size": self.geom.page_size,
            "usable_pages": self.usable,
            "pages_in_use": self.pages_in_use,
            "page_high_water": self.high_water,
            "reserved_pages": sum(self.reserved),
            "admission_policy": self.policy,
            "evictions": self.evictions,
            "pages_evicted": self.pages_evicted,
            "double_release": self.double_release,
            "pages_quarantined": self.pages_quarantined,
        }


# ---------------------------------------------------------------------------
# cache tree ops (host-driven, eager — run once per admission / table change)
# ---------------------------------------------------------------------------


def _splice(full, one, slot: int, stacked: bool):
    """Write the batch-1 leaf into the full cache at the slot axis
    (axis 1 under the body stack's leading (layers,) dim)."""
    return jax.lax.dynamic_update_slice_in_dim(
        full, one.astype(full.dtype), slot, axis=1 if stacked else 0)


def _commit_entry(full, one, slot: int, length: int, table_dev,
                  page_ids, offs, stacked: bool):
    if isinstance(full, dict) and "self" in full:   # dec_attn: nested self
        out = dict(full)
        out["self"] = _commit_entry(full["self"], one["self"], slot, length,
                                    table_dev, page_ids, offs, stacked)
        for key in ("ck", "cv"):
            if key in full:
                out[key] = _splice(full[key], one[key], slot, stacked)
        return out
    if isinstance(full, dict) and "block_table" in full:
        # paged entry: scatter the dense prefill slab (1, S_max, ...) into
        # the slot's pages — token t -> (table[slot, t // ps], t % ps)
        out = dict(full)
        for key in _POOL_KEYS:
            if key not in full:
                continue
            pool, slab = full[key], one[key]
            if stacked:
                tok = slab[:, 0, :length].astype(pool.dtype)
                out[key] = pool.at[:, page_ids, offs].set(tok)
            else:
                tok = slab[0, :length].astype(pool.dtype)
                out[key] = pool.at[page_ids, offs].set(tok)
        if stacked:
            out["index"] = full["index"].at[:, slot].set(length)
            out["block_table"] = jnp.broadcast_to(
                table_dev, full["block_table"].shape)
        else:
            out["index"] = full["index"].at[slot].set(length)
            out["block_table"] = table_dev
        return out
    # dense slab / ring / recurrent state: per-slot splice of every leaf
    # (the prefill cache's index leaf carries the prompt length)
    return jax.tree_util.tree_map(
        lambda f, o: _splice(f, o, slot, stacked), full, one)


def commit_prefill(caches, slot_cache, slot: int, length: int,
                   table: Optional[np.ndarray] = None,
                   page_size: Optional[int] = None):
    """Install a batch-1 prefill cache into slot ``slot`` of the live
    decode caches.  Paged entries scatter into pages via ``table`` (the
    allocator's authoritative block table); everything else splices at the
    slot axis.  In dense mode pass ``table=None`` — no paged entries exist
    and the arguments are unused."""
    if table is not None:
        pos = np.arange(length)
        row = np.asarray(table)[slot]
        page_ids = jnp.asarray(row[pos // page_size], jnp.int32)
        offs = jnp.asarray(pos % page_size, jnp.int32)
        table_dev = jnp.asarray(table, jnp.int32)
    else:
        page_ids = offs = table_dev = None
    new = {}
    for part, stacked in (("prefix", False), ("body", True)):
        new[part] = {
            name: _commit_entry(full, slot_cache[part][name], slot, length,
                                table_dev, page_ids, offs, stacked)
            for name, full in caches[part].items()}
    return new


# Authoritative merge schema for session stats (DESIGN.md §13.1).
# Counters sum across replicas; capacity gauges take the fleet-wide
# extreme (with per-replica lists kept so a skewed router policy shows up
# in the bench JSON, not just in the max); pool geometry comes from the
# first replica (replicas share one config); latency histograms merge by
# sample concatenation.  peak_live_tokens rides the page_high_water gate:
# it is reported whenever any replica reports paging high-water figures,
# even for sessions that never recorded a live peak.
SERVE_MERGE_SPEC: Dict[str, obs_metrics.MergeRule] = {
    **{k: obs_metrics.MergeRule("sum") for k in (
        "requests", "completed", "preemptions", "recompute_tokens",
        "rejected", "failed", "timed_out", "decode_steps",
        "decode_dispatches", "admission_deferrals", "evictions",
        "pages_evicted", "double_release", "pages_quarantined",
        "nonfinite_logits", "restores", "restore_recompute_tokens")},
    "straggler_decode_steps": obs_metrics.MergeRule(
        "sum", list_as="straggler_decode_steps_per_replica"),
    **{k: obs_metrics.MergeRule("first") for k in (
        "n_pages", "page_size", "usable_pages", "admission_policy",
        "kv_layout", "dense_equiv_tokens")},
    "page_high_water": obs_metrics.MergeRule(
        "max", list_as="page_high_water_per_replica"),
    "peak_live_tokens": obs_metrics.MergeRule(
        "max", gate="page_high_water"),
    "request_timing": obs_metrics.MergeRule("hist_map"),
}


def merge_replica_stats(per_replica: list) -> dict:
    """Aggregate per-replica session stats into one router-level view —
    a straight application of :data:`SERVE_MERGE_SPEC` through
    :func:`repro.obs.metrics.merge_stats` (which replaced the ad-hoc
    sum/max/first loops this function used to hand-roll)."""
    return obs_metrics.merge_stats(per_replica, SERVE_MERGE_SPEC)


def _paged_entries(caches):
    """Yield ``(entry, stacked)`` for every paged cache entry in the tree
    (mirrors the traversal in :func:`commit_prefill`)."""
    def walk(entry, stacked):
        if isinstance(entry, dict) and "self" in entry:
            yield from walk(entry["self"], stacked)
        elif isinstance(entry, dict) and "block_table" in entry:
            yield entry, stacked

    for part, stacked in (("prefix", False), ("body", True)):
        for entry in caches.get(part, {}).values():
            yield from walk(entry, stacked)


def page_fingerprints(caches, committed: Dict[int, int]) -> Dict[int, int]:
    """crc32 fingerprint of each page's committed contents.

    ``committed`` maps page id -> number of token rows committed into
    that page; the crc covers exactly those rows (a page's tail beyond
    the committed length holds garbage from slot reuse, so it must not
    feed the fingerprint).  The crc chains over every pool leaf of every
    paged entry, so corruption in any layer/head is caught.
    """
    crcs = {page: 0 for page in committed}
    if not crcs:
        return crcs
    for entry, stacked in _paged_entries(caches):
        for key in _POOL_KEYS:
            if key not in entry:
                continue
            pool = np.asarray(jax.device_get(entry[key]))
            for page, ntok in committed.items():
                slab = pool[:, page, :ntok] if stacked else pool[page, :ntok]
                crcs[page] = zlib.crc32(
                    np.ascontiguousarray(slab).tobytes(), crcs[page])
    return crcs


def pages_nonfinite(caches, pages) -> set:
    """Subset of ``pages`` holding any NaN/Inf in a float pool leaf —
    precise localization for the commit-loop logit screen (NaN leaks
    through the attention mask from *any* position of a touched page, so
    detection can't rely on the committed-region checksums alone)."""
    bad: set = set()
    pages = [p for p in pages]
    for entry, stacked in _paged_entries(caches):
        for key in _POOL_KEYS:
            if key not in entry:
                continue
            pool = entry[key]
            if not jnp.issubdtype(pool.dtype, jnp.floating):
                continue
            arr = np.asarray(jax.device_get(pool))
            for page in pages:
                if page in bad:
                    continue
                slab = arr[:, page] if stacked else arr[page]
                if not np.isfinite(slab).all():
                    bad.add(page)
    return bad


def corrupt_page(caches, page: int, nan: bool = False):
    """Scribble over KV page ``page`` in every pool leaf — the
    ``("page", idx)`` fault payload (simulated device-memory corruption).
    ``nan=True`` writes NaN into float pools (poisons logits, caught by
    the engine's commit-time screen); otherwise writes finite garbage
    (silent — caught only by the checksum verify)."""
    def fix(entry, stacked):
        if isinstance(entry, dict) and "self" in entry:
            out = dict(entry)
            out["self"] = fix(entry["self"], stacked)
            return out
        if isinstance(entry, dict) and "block_table" in entry:
            out = dict(entry)
            for key in _POOL_KEYS:
                if key not in entry:
                    continue
                pool = entry[key]
                if jnp.issubdtype(pool.dtype, jnp.floating):
                    val = jnp.nan if nan else 1e4
                else:
                    val = jnp.iinfo(pool.dtype).max
                fill = jnp.asarray(val, pool.dtype)
                out[key] = (pool.at[:, page].set(fill) if stacked
                            else pool.at[page].set(fill))
            return out
        return entry

    return {part: {name: fix(entry, part == "body")
                   for name, entry in caches[part].items()}
            for part in ("prefix", "body")}


def sync_block_tables(caches, table: np.ndarray):
    """Push the allocator's host block table into every layer's
    ``block_table`` leaf (decode-boundary page allocations, slot frees)."""
    t = jnp.asarray(table, jnp.int32)

    def fix(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if keys and keys[-1] == "block_table":
            return jnp.broadcast_to(t, leaf.shape)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, caches)
