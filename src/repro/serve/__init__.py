"""Serving: batched prefill/decode engine with slot-based batching."""
from repro.serve.engine import Engine, Request, ServeConfig  # noqa: F401
