"""Serving: batched prefill/decode engine with continuous mixed-length
batching over a paged KV cache (DESIGN.md §6), fronted by a fault-tolerant
multi-replica router (DESIGN.md §7)."""
from repro.serve import paging  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    Engine, EngineSession, Request, ServeConfig)
from repro.serve.paging import (  # noqa: F401
    PageAllocator, PageGeometry, PoolExhausted)
from repro.serve.router import Replica, Router, RouterConfig  # noqa: F401
