"""Serving: batched prefill/decode engine with continuous mixed-length
batching over a paged KV cache (DESIGN.md §6)."""
from repro.serve import paging  # noqa: F401
from repro.serve.engine import Engine, Request, ServeConfig  # noqa: F401
from repro.serve.paging import (  # noqa: F401
    PageAllocator, PageGeometry, PoolExhausted)
