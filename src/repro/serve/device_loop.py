"""Fused on-device decode loop (DESIGN.md §7.1, device half).

The serving engine used to pay one jitted dispatch — plus a full host
round-trip for sampling and token commit — per generated token.  This
module fuses up to ``decode_chunk`` decode steps into a single jitted
``lax.while_loop`` so the device stays busy while the host only does
coarse bookkeeping (admission, paging, deadline sweeps) once per chunk,
the same amortization move the paper's §3 RgCSR kernel makes by running
many row groups per grid launch.

Three pieces live here because host and device must share them exactly:

* :func:`sample_tokens` — the pure ``(logits, key) → tokens`` sampler
  (greedy / temperature / top-k via ``lax.top_k``).  ``Engine._sample``
  calls it on host with the engine's split key; the fused loop calls it
  in-trace with a key threaded through the carry, so both paths produce
  identical streams for a given key sequence.
* :func:`make_decode_step` — the one decode-step factory.  The engine's
  per-step jit, the fused loop body, and ``launch/steps.py`` all route
  through it, so there is exactly one definition of "one decode step".
* :func:`build_fused_decode` — the jitted chunk runner.

Carry layout (one ``lax.while_loop`` iteration = one decode step)::

    (step, caches, cur_tok, remaining, active, key, block)

    step      ()            int32   steps executed so far
    caches    pytree                KV caches (donated — updated in place)
    cur_tok   (n_slots, 1)  int32   last sampled token per slot
    remaining (n_slots,)    int32   decode budget left per slot
    active    (n_slots,)    bool    slot still generating
    key       (2,)          uint32  PRNG key (split once per step, exactly
                                    like the host sampler)
    block     (k_max, n)    int32   sampled tokens, row i = step i

The predicate is ``step < n_steps AND any(active)`` — the loop early-
exits as soon as every slot has hit EOS or exhausted its budget, so a
chunk never burns device steps on a finished batch.  ``n_steps`` is a
*traced* scalar (the host clamps it to ``k_max``): varying the chunk
length at runtime — drain tails, fault-split chunks — reuses one
compiled executable instead of recompiling per length.

Finished slots keep decoding harmlessly inside a chunk: their block-
table pages are still allocated (the host frees them only when it
commits the chunk), out-of-range paged lookups land on the null page,
and dense out-of-bounds scatters drop under jit — the host commit loop
is the single authority on which rows/slots count.

The caches argument is donated (``donate_argnums``), so each dispatch
updates the KV buffers in place — no per-chunk copy of the pool.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sample_tokens", "make_decode_step", "build_fused_decode"]


def sample_tokens(logits, key, temperature: float, top_k: int) -> jax.Array:
    """Pure ``(logits, key) → tokens`` sampler shared by host and device.

    ``logits`` is ``(b, s, V)`` — the last position is sampled in fp32.
    ``temperature <= 0`` is greedy argmax and consumes no key (callers
    may pass ``key=None``); otherwise top-k filtering uses
    ``jax.lax.top_k`` (O(V log k), vs the old full ``jnp.sort``) with
    ``top_k`` clamped to the vocab: ``k >= vocab`` keeps every token,
    ``k <= 0`` disables filtering.
    """
    logits = logits[:, -1, :].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    k = min(int(top_k), logits.shape[-1])
    if 0 < k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, k)[0][:, -1][:, None]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def make_decode_step(model, shape_kind: str = "decode"):
    """The one decode-step factory: ``(params, caches, tokens) →
    (logits, caches)``.  Engine per-step jit, fused loop body, and the
    launcher dry-run all build their step from here."""
    def decode_step(params, caches, tokens):
        return model.decode_step(params, caches, tokens,
                                 shape_kind=shape_kind)
    return decode_step


def build_fused_decode(model, cfg, on_dispatch=None):
    """Build the jitted fused chunk runner for one engine config.

    ``on_dispatch`` (optional) is called with the full output tuple after
    every dispatch, *inside* the returned callable — the engine's trace
    hook rides here so fused-dispatch marks survive the test/bench
    harnesses that wrap ``engine._fused_decode`` from the outside.

    Returns ``fused(params, caches, cur_tok, remaining, active, key,
    n_steps) → (block, steps_ran, cur_tok, key, caches, logit_ok)`` where
    ``block`` is the static ``(k_max, n_slots)`` token block (rows past
    ``steps_ran`` are zero-padding the host never reads) and ``logit_ok``
    is the matching ``(k_max, n_slots)`` bool block: row i is per-slot
    "every last-position logit at step i was finite" — the host's
    commit-time NaN/Inf screen (DESIGN.md §7.6) reads it to stop
    committing a poisoned stream at the exact step the poison appeared.
    ``logit_ok`` rides at the END of the tuple so existing consumers of
    positions 0–4 keep working.  Sampling parameters (temperature,
    top-k, EOS) are baked in from ``cfg`` — they are per-engine
    constants, and baking them keeps the loop body free of host
    branches.
    """
    eos = int(cfg.eos_id)
    temperature = float(cfg.temperature)
    top_k = int(cfg.top_k)
    k_max = max(1, int(cfg.decode_chunk))
    decode = make_decode_step(model)

    def fused(params, caches, cur_tok, remaining, active, key, n_steps):
        n = cur_tok.shape[0]

        def cond(c):
            step, _, _, _, act, _, _, _ = c
            return (step < n_steps) & jnp.any(act)

        def body(c):
            step, caches, tok, rem, act, key, block, ok = c
            logits, caches = decode(params, caches, tok)
            # per-slot finiteness of the sampled position's logits —
            # NaN/Inf here means the KV pages this slot read are poisoned
            fin = jnp.all(jnp.isfinite(logits[:, -1, :].astype(jnp.float32)),
                          axis=-1)
            if temperature > 0.0:
                # one split per decode step — the exact key-consumption
                # cadence of the host sampler, so device streams match
                # host streams key-for-key
                key, sub = jax.random.split(key)
                nxt = sample_tokens(logits, sub, temperature, top_k)
            else:
                nxt = sample_tokens(logits, None, temperature, top_k)
            block = block.at[step].set(nxt)
            ok = ok.at[step].set(fin)
            rem = jnp.where(act, rem - 1, rem)
            done = rem <= 0
            if eos >= 0:
                done = done | (nxt == eos)
            return (step + 1, caches, nxt[:, None], rem, act & ~done,
                    key, block, ok)

        init = (jnp.zeros((), jnp.int32), caches, cur_tok, remaining,
                active, key, jnp.zeros((k_max, n), jnp.int32),
                jnp.ones((k_max, n), jnp.bool_))
        step, caches, tok, _, _, key, block, ok = jax.lax.while_loop(
            cond, body, init)
        return block, step, tok, key, caches, ok

    jitted = jax.jit(fused, donate_argnums=(1,))
    if on_dispatch is None:
        return jitted

    def fused_with_hook(*args):
        out = jitted(*args)
        on_dispatch(out)
        return out

    return fused_with_hook
