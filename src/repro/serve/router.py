"""Multi-replica serving router: health-checked failover, request
migration, and backpressure (DESIGN.md §7).

One :class:`~repro.serve.engine.Engine` is one decode batch on one mesh —
a replica fault kills every in-flight stream and there is no admission
layer above a single ``serve()`` call.  The :class:`Router` fronts M
engine replicas (shared params, independent KV pools) through the
:class:`~repro.serve.engine.EngineSession` stepper and adds the three
properties a fleet needs:

* **failover + migration** — a replica-tier fault (injected via
  ``FaultInjector`` site ``"replica"``, or any exception escaping
  ``EngineSession.step``) marks the replica dead and migrates its
  in-flight requests to survivors.  Migration *is* recompute preemption
  across replicas: each harvested request carries its generated prefix in
  ``out``, so re-admission elsewhere re-prefills prompt+prefix and the
  resumed stream is token-identical to the single-engine oracle.  Retries
  are bounded per request (``FaultConfig.max_restarts``); exhaustion →
  ``status="failed"``.  Dead replicas restart after a linear backoff
  (``backoff_s × restarts``, the ``RestartableLoop`` schedule) with a
  fresh session; a replica that exhausts its own restart budget stays
  down permanently.
* **health-aware routing** — a per-replica ``Watchdog`` EWMA over
  ``step()`` wall durations marks slow replicas ``degraded``; dispatch
  prefers healthy replicas and, within a health class, the most free
  pages (``PageAllocator.free_pages``).  Admission into a replica is
  deliberately conservative — one request at a time, only into a replica
  with a free slot and an empty session queue — so the router's global
  FIFO queue stays the single ordering authority and no request is
  trapped behind a replica-local backlog when that replica dies.
* **backpressure** — the router queue is bounded (``queue_limit``);
  over-capacity arrivals are refused at the door with ``status="shed"``
  instead of queueing unboundedly.  Migrations bypass the limit (they
  re-enter at the queue head: those requests were already admitted once
  and FIFO-precede everything still waiting).

Draining: ``drain_replica(i)`` stops admitting to a replica, lets its
residents finish, then recycles it with a fresh session (planned
maintenance — the failover path minus the fault).

Everything is driven by the injectable ``clock`` (defaults to
``engine.clock``) — tests run the full fault/migration/backoff machinery
on a fake timer with zero wall-clock asserts.
"""
from __future__ import annotations

import dataclasses
import logging
from collections import deque
from typing import Dict, List, Optional

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import paging
from repro.serve.engine import (Engine, EngineSession, Request,
                                request_from_state, request_to_state)
from repro.train.fault import ProcessKilled

__all__ = ["Router", "RouterConfig", "Replica"]

log = logging.getLogger("repro.router")


@dataclasses.dataclass
class RouterConfig:
    n_replicas: int = 2
    # global backpressure: max requests waiting in the router queue;
    # submissions beyond it are shed (status="shed").  0 → unbounded.
    queue_limit: int = 0
    # decode steps per replica per round — the stepper's interleave grain.
    # 0 (default) → one full fused chunk (the session's decode_chunk) per
    # round, so each round costs one on-device dispatch per busy replica;
    # an explicit value restores a finer host-visible grain.
    steps_per_round: int = 0
    # per-request migration budget and per-replica restart budget both
    # come from FaultConfig.max_restarts (backoff_s drives restart delay)


@dataclasses.dataclass
class Replica:
    """Router-side state for one engine replica."""
    engine: Engine
    session: EngineSession
    watchdog: object                       # train.fault.Watchdog
    state: str = "healthy"                 # healthy|degraded|dead|draining
    restarts: int = 0                      # faults survived so far
    restart_at: Optional[float] = None     # clock time to revive at
    drains: int = 0
    # snapshots of this replica's dead/recycled sessions — their counters
    # survive the session so fleet stats never lose a faulted replica's work
    retired_stats: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def alive(self) -> bool:
        return self.state in ("healthy", "degraded", "draining")

    @property
    def admitting(self) -> bool:
        return self.state in ("healthy", "degraded")


class Router:
    """Health-checked request router over M engine replicas.

    Build with a list of :class:`Engine` replicas (share one model's
    params across them: ``Engine(cfg, scfg, params=first.params)``), or
    use :meth:`build` to construct the fleet from configs.  Then either
    ``serve(requests)`` — the blocking batch API, mirroring
    ``Engine.serve`` — or ``submit()`` + ``run_round()`` for continuous
    operation.  ``stats()`` aggregates per-replica session stats through
    ``paging.merge_replica_stats`` and adds the router's own counters
    (``migrations``, ``retries_exhausted``, ``shed``, ``replica_faults``,
    ``replica_restarts``, ``drains``).
    """

    def __init__(self, engines: List[Engine], cfg: RouterConfig = None,
                 fault_cfg=None, clock=None, sleep=None, tracer=None):
        import time
        from repro.train.fault import FaultConfig, Watchdog
        if not engines:
            raise ValueError("Router needs at least one engine replica")
        self.cfg = cfg if cfg is not None else RouterConfig(
            n_replicas=len(engines))
        self.fault_cfg = fault_cfg if fault_cfg is not None \
            else FaultConfig()
        self.clock = clock if clock is not None else engines[0].clock
        # sleep is only invoked when the whole fleet is blocked on a
        # pending restart; inject one that ADVANCES the injected clock
        # (e.g. FakeClock.advance) or serve() spins until the revival time
        self.sleep = sleep if sleep is not None else time.sleep
        # observability (DESIGN.md §13): label + attach the tracer to
        # every engine BEFORE the sessions are built, so each replica's
        # spans land on its own replica<i> track and router-level events
        # (shed, dispatch, failover) on the router track
        self.tracer = tracer if tracer is not None else obs_trace.NOOP
        self.track = ("router", "main")
        if tracer is not None:
            for i, e in enumerate(engines):
                e.tracer = tracer
                e.trace_label = f"replica{i}"
        self.queue: deque = deque()
        self.replicas: List[Replica] = [
            Replica(engine=e, session=e.start_session(),
                    watchdog=Watchdog(self.fault_cfg))
            for e in engines]
        self.counters = {"migrations": 0, "retries_exhausted": 0,
                         "shed": 0, "replica_faults": 0,
                         "replica_restarts": 0, "drains": 0,
                         "degraded_marks": 0}
        # prompt+prefix tokens that restore() re-enqueued at the ROUTER
        # queue (session-resident restores count theirs in session stats);
        # stats() folds this into the merged restore_recompute_tokens
        self._queue_restore_tokens = 0

    @classmethod
    def build(cls, model_cfg, serve_cfg, n_replicas: int,
              cfg: RouterConfig = None, fault_cfg=None, clock=None,
              **router_kw) -> "Router":
        """Construct ``n_replicas`` engines sharing one set of params."""
        first = Engine(model_cfg, serve_cfg, fault_cfg=fault_cfg)
        engines = [first] + [
            Engine(model_cfg, serve_cfg, params=first.params,
                   fault_cfg=fault_cfg) for _ in range(n_replicas - 1)]
        if clock is not None:
            for e in engines:
                e.clock = clock
        return cls(engines, cfg=cfg, fault_cfg=fault_cfg, clock=clock,
                   **router_kw)

    # --------------------------------------------------------- admission
    def submit(self, req: Request) -> bool:
        """Enqueue at the router; False → shed by backpressure.

        The queue bound counts waiting requests only (not residents on
        replicas): it is the promise the router can still keep if every
        replica dies — bounded, so an overloaded fleet refuses work at
        the door instead of accumulating unbounded latency debt.
        """
        if req.arrival_t is None:
            req.arrival_t = self.clock()
        limit = self.cfg.queue_limit
        if limit and len(self.queue) >= limit:
            req.done = True
            req.status = "shed"
            req.error = (f"router queue at capacity ({limit}): request "
                         "shed at admission")
            if req.out is None:
                req.out = []
            self.counters["shed"] += 1
            self.tracer.instant("shed", self.track,
                                queue_len=len(self.queue))
            return False
        self.queue.append(req)
        self.tracer.request_begin(req, self.track,
                                  prompt=len(req.tokens))
        return True

    def _dispatch(self) -> None:
        """Move queue heads onto replicas, one per free slot, preferring
        healthy over degraded and, within a class, the most free pages.
        A replica only takes a new request when its own session queue is
        empty — the global queue is the one FIFO authority, and a request
        never waits behind a replica-local backlog."""
        while self.queue:
            candidates = [r for r in self.replicas
                          if r.admitting and r.session.has_free_slot
                          and r.session.num_queued == 0]
            if not candidates:
                return
            best = max(candidates,
                       key=lambda r: (r.state == "healthy",
                                      r.session.free_pages))
            req = self.queue.popleft()
            if self.tracer.enabled:
                idx = self.replicas.index(best)
                self.tracer.instant(
                    "dispatch", (f"replica{idx}", "session"), replica=idx)
                self.tracer.request_point(req, "dispatched",
                                          (f"replica{idx}", "session"),
                                          replica=idx)
            best.session.submit(req)

    # ---------------------------------------------------------- stepping
    def _on_fault(self, idx: int, exc: Exception) -> None:
        """Replica ``idx`` died mid-step: harvest its in-flight requests,
        re-queue survivors at the head (FIFO: they were admitted before
        anything still waiting), fail the ones whose retry budget is
        spent, and schedule the replica's restart."""
        rep = self.replicas[idx]
        rep.state = "dead"
        rep.restarts += 1
        self.counters["replica_faults"] += 1
        self.tracer.instant("replica_fault", (f"replica{idx}", "session"),
                            replica=idx, error=repr(exc))
        budget = self.fault_cfg.max_restarts
        if rep.restarts <= budget:
            backoff = self.fault_cfg.backoff_s * rep.restarts
            rep.restart_at = self.clock() + backoff
            log.warning("replica %d died (%r); restart %d/%d in %.3fs",
                        idx, exc, rep.restarts, budget, backoff)
        else:
            rep.restart_at = None          # permanently down
            log.error("replica %d died (%r); restart budget exhausted",
                      idx, exc)
        inflight = rep.session.inflight()
        rep.retired_stats.append(rep.session.stats_snapshot())
        rep.session = None                 # lost with the replica
        # reversed + appendleft keeps the harvested FIFO order at the head
        for req in reversed(inflight):
            req.retries += 1
            if req.retries > budget:
                req.done = True
                req.status = "failed"
                req.error = (f"replica {idx} fault ({exc!r}); migration "
                             f"budget exhausted after {req.retries - 1} "
                             "retries")
                if req.out is None:
                    req.out = []
                self.counters["retries_exhausted"] += 1
                self.tracer.request_end(req, self.track, status="failed")
            else:
                self.counters["migrations"] += 1
                # one "migrate" instant per migrations increment, on the
                # faulted replica's track (check_trace pairs them exactly)
                self.tracer.instant("migrate", (f"replica{idx}", "session"),
                                    replica=idx, retries=req.retries)
                self.tracer.request_point(req, "migrated", self.track,
                                          from_replica=idx)
                self.queue.appendleft(req)

    def _maybe_restart(self) -> None:
        now = self.clock()
        for idx, rep in enumerate(self.replicas):
            if rep.state == "dead" and rep.restart_at is not None \
                    and now >= rep.restart_at:
                rep.session = rep.engine.start_session()
                rep.state = "healthy"
                rep.restart_at = None
                self.counters["replica_restarts"] += 1
                self.tracer.instant("replica_restart",
                                    (f"replica{idx}", "session"),
                                    replica=idx, restarts=rep.restarts)
                log.info("replica %d restarted (restart %d)", idx,
                         rep.restarts)

    def _finish_drains(self) -> None:
        """A draining replica whose residents finished gets recycled with
        a fresh session and rejoins the healthy pool."""
        for idx, rep in enumerate(self.replicas):
            if rep.state == "draining" and rep.session.idle:
                rep.retired_stats.append(rep.session.stats_snapshot())
                rep.session = rep.engine.start_session()
                rep.state = "healthy"
                rep.drains += 1
                self.counters["drains"] += 1
                self.tracer.instant("drain", (f"replica{idx}", "session"),
                                    replica=idx)

    def drain_replica(self, idx: int) -> None:
        """Planned maintenance: stop admitting to replica ``idx``; its
        residents finish on subsequent rounds, then it is recycled."""
        rep = self.replicas[idx]
        if not rep.alive:
            raise ValueError(f"replica {idx} is {rep.state}; only a live "
                             "replica can be drained")
        rep.state = "draining"

    def run_round(self) -> int:
        """One scheduling round: revive due replicas, dispatch queue heads,
        then step every live replica ``steps_per_round`` decode steps
        (watchdog-timed; a step that raises triggers failover).  Returns
        total decode steps run; 0 with a non-empty queue means the router
        is waiting on a restart (the injected ``sleep`` is invoked with
        the time until the nearest one)."""
        self._maybe_restart()
        self._finish_drains()
        self._dispatch()
        ran = 0
        for idx, rep in enumerate(self.replicas):
            if not rep.alive or rep.session.idle:
                continue
            t0 = self.clock()
            grain = self.cfg.steps_per_round or \
                max(1, rep.session.cfg.decode_chunk)
            try:
                n = rep.session.step(grain)
            except ProcessKilled:
                # process-tier fault: there is no surviving replica to
                # migrate to — the whole fleet is gone.  Propagate to the
                # crash drill, which rebuilds the router and restores the
                # latest snapshot (DESIGN.md §7.6).
                raise
            except Exception as exc:  # noqa: BLE001 — replica-tier fault
                self._on_fault(idx, exc)
                continue
            ran += n
            # normalize by steps run so a fused chunk is judged per-step
            # (a k-step round must not read as a k× straggler)
            if n and rep.watchdog.observe(rep.session.stats["decode_steps"],
                                          (self.clock() - t0) / n):
                # transiently slow (stragglers) → route around it; the
                # next clean round restores it to the healthy class
                if rep.state == "healthy":
                    rep.state = "degraded"
                    self.counters["degraded_marks"] += 1
                    self.tracer.instant("degraded_mark",
                                        (f"replica{idx}", "session"),
                                        replica=idx)
            elif n and rep.state == "degraded":
                rep.state = "healthy"
        if ran == 0 and self.queue:
            pending = [r.restart_at for r in self.replicas
                       if r.state == "dead" and r.restart_at is not None]
            if pending:
                # idle until the nearest revival — through the injected
                # sleep, so tests advance a FakeClock instead of waiting
                self.sleep(max(0.0, min(pending) - self.clock()))
            elif not any(r.alive for r in self.replicas):
                self._fail_stranded()
        return ran

    def _fail_stranded(self) -> None:
        """Every replica is permanently down: nothing can ever serve the
        queue — fail it rather than spin forever."""
        while self.queue:
            req = self.queue.popleft()
            req.done = True
            req.status = "failed"
            req.error = "all replicas permanently down"
            if req.out is None:
                req.out = []
            self.counters["retries_exhausted"] += 1
            self.tracer.request_end(req, self.track, status="failed")

    # ---------------------------------------------------------- blocking
    @property
    def idle(self) -> bool:
        return not self.queue and all(
            (not r.alive) or r.session.idle for r in self.replicas)

    def serve(self, requests: List[Request]) -> List[Request]:
        """Blocking batch API mirroring ``Engine.serve``: submit all (the
        over-capacity tail is shed), run rounds to quiescence."""
        for req in requests:
            self.submit(req)
        while not self.idle:
            self.run_round()
        return requests

    # ------------------------------------------------- snapshot / restore
    def snapshot(self) -> Dict:
        """Crash-consistent fleet state (DESIGN.md §7.6): every live
        replica session's :meth:`EngineSession.snapshot`, the retired-
        session counters, the router's own counters, and the global queue
        (as rebased request states).  JSON-serializable; persist through
        :class:`repro.train.checkpoint.SnapshotManager` for the atomic
        write + rolling retention."""
        now = self.clock()
        return {
            "version": 1,
            "sessions": [None if rep.session is None
                         else rep.session.snapshot()
                         for rep in self.replicas],
            "retired_stats": [list(rep.retired_stats)
                              for rep in self.replicas],
            "replica_restarts": [rep.restarts for rep in self.replicas],
            "replica_drains": [rep.drains for rep in self.replicas],
            "queue": [request_to_state(req, now) for req in self.queue
                      if not req.done],
            "counters": dict(self.counters),
        }

    def restore(self, snap: Dict) -> List[Request]:
        """Load a :meth:`snapshot` into this freshly-built, idle router.
        Every replica here starts alive (the old process's dead replicas
        come back as fresh engines — their inflight work was already
        migrated into the snapshotted queue at fault time); counters and
        retired-session stats carry over so fleet totals survive the
        restart.  Returns every re-enqueued :class:`Request` handle —
        session residents first (per replica), then the global queue —
        and ``serve([])``/``run_round()`` then drains them
        token-identically to the dead process's streams."""
        sessions = snap.get("sessions", [])
        if len(sessions) != len(self.replicas):
            raise ValueError(
                f"snapshot holds {len(sessions)} replicas but this "
                f"router has {len(self.replicas)}")
        if self.queue or not self.idle:
            raise RuntimeError("restore() needs an idle router")
        now = self.clock()
        restored: List[Request] = []
        for rep, sess_snap, retired, restarts, drains in zip(
                self.replicas, sessions,
                snap.get("retired_stats", [[] for _ in self.replicas]),
                snap.get("replica_restarts", [0] * len(self.replicas)),
                snap.get("replica_drains", [0] * len(self.replicas))):
            rep.retired_stats = [dict(s) for s in retired]
            rep.restarts = restarts
            rep.drains = drains
            if sess_snap is not None:
                restored.extend(rep.session.restore(sess_snap))
        for rs in snap.get("queue", []):
            req = request_from_state(rs, now)
            if req.out:
                # a migrated request parked in the global queue carries a
                # generated prefix that must re-prefill after the restart
                self._queue_restore_tokens += len(req.tokens) + \
                    len(req.out)
            self.queue.append(req)
            restored.append(req)
        for key, val in snap.get("counters", {}).items():
            if key in self.counters:
                self.counters[key] = val
        return restored

    # ------------------------------------------------------------- stats
    def stats(self) -> Dict:
        """Fleet-level stats: merged per-session counters (live sessions +
        every retired one, so faulted replicas' work is not forgotten)
        plus the router's own counters and per-replica health."""
        by_replica = [
            r.retired_stats + ([r.session.stats_snapshot()]
                               if r.session is not None else [])
            for r in self.replicas]
        merged = paging.merge_replica_stats(
            [s for sessions in by_replica for s in sessions])
        if "page_high_water" in merged:
            # merge_replica_stats lists per *session*; fold a replica's
            # retired sessions into one per-replica high-water here
            merged["page_high_water_per_replica"] = [
                max((s.get("page_high_water", 0) for s in sessions),
                    default=0) for sessions in by_replica]
        if "straggler_decode_steps" in merged:
            # same per-replica fold for straggler attribution: sum each
            # replica's retired + live sessions, so one chronically slow
            # host is visible as a skewed entry, not just a bigger total
            merged["straggler_decode_steps_per_replica"] = [
                sum(s.get("straggler_decode_steps", 0) for s in sessions)
                for sessions in by_replica]
        if self._queue_restore_tokens:
            merged["restore_recompute_tokens"] = merged.get(
                "restore_recompute_tokens", 0) + self._queue_restore_tokens
        if "request_timing" in merged:
            # fleet-level p50/p95/p99 over the merged per-request
            # histograms (queue_s / prefill_s / latency_s)
            merged["latency_percentiles"] = obs_metrics.timing_percentiles(
                merged["request_timing"])
        merged.update(self.counters)
        merged["router_queue_len"] = len(self.queue)
        merged["replica_states"] = [r.state for r in self.replicas]
        merged["n_replicas"] = len(self.replicas)
        return merged
