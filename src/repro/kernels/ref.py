"""Pure-jnp oracles for the Pallas kernels.

These re-export the vectorized reference SpMV/SpMM from :mod:`repro.core.spmv`
— the kernels must match them to float tolerance on every shape/dtype sweep
(tests/test_kernels.py).  Keeping the oracle in core/ means the LM framework
and the benchmark harness exercise the *same* semantics the kernels are
validated against.
"""
from __future__ import annotations

from repro.core.spmv import (  # noqa: F401
    spmv as spmv_ref,
    spmm as spmm_ref,
    spmv_csr,
    spmv_coo,
    spmv_ellpack,
    spmv_hybrid,
    spmv_blocked_csr,
    spmv_rgcsr,
    spmv_sliced_ellpack,
    spmm_rgcsr,
    spmm_ellpack,
)

__all__ = [
    "spmv_ref", "spmm_ref",
    "spmv_csr", "spmv_coo", "spmv_ellpack", "spmv_hybrid",
    "spmv_blocked_csr", "spmv_rgcsr", "spmv_sliced_ellpack",
    "spmm_rgcsr", "spmm_ellpack",
]
