"""Per-matrix autotuning of the RgCSR kernel pipeline (DESIGN.md §3.3).

CSR5 (Liu & Vinter 2015) and SELL-C-σ (Kreutzer et al. 2011) both show that
on wide-SIMD hardware the winning sparse schedule is a *tuned tile size*
chosen per matrix.  For our pipeline the knobs are:

* ``chunks_per_step`` — grid coarsening of the SpMV/SpMM kernels: fewer,
  fatter grid steps vs more padding on short groups;
* ``group_size``      — rows per RgCSR group: fill ratio vs lane utilization
  (the paper's Table 4 experiment, now closed-loop);
* ``d_tile``          — SpMM dense-width tile: X-panel residency vs output
  block pressure.

The harness *measures* candidate configs (median wall time of the actual
kernel launch, jit-warmed and blocked) rather than modeling them, prunes
candidates whose padded storage blows up past ``storage_cap`` × the
baseline (the paper's fill-ratio pathology — a config that multiplies
stored bytes on a memory-bound op cannot win), and memoizes the winner per
**matrix signature** so structurally equivalent matrices (same log-bucketed
shape/nnz/row-length profile) reuse the search result.  Winners feed the
``PlanCache``: ``tuned_plan`` returns a ready, cached execution plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.formats import RgCSR
from repro.core.timing import time_us
from repro.kernels import ops
from repro.kernels.rgcsr_spmv import CHUNKS_PER_STEP_CHOICES, LANES

__all__ = ["TuneConfig", "TuneResult", "matrix_signature", "candidate_configs",
           "autotune_spmv", "autotune_spmm", "tuned_plan", "clear_memo",
           "DEFAULT_GROUP_SIZES", "DEFAULT_D_TILES"]

DEFAULT_GROUP_SIZES = (128, 256)
DEFAULT_D_TILES = (128, 256)


@dataclasses.dataclass(frozen=True, order=True)
class TuneConfig:
    """One point in the kernel schedule space."""
    chunks_per_step: int = 1
    group_size: int = 128
    d_tile: int = 128


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Winner of one search, with the full timing table for inspection."""
    config: TuneConfig
    us_per_call: float
    timings: Tuple[Tuple[TuneConfig, float], ...]
    signature: tuple
    from_memo: bool = False

    @property
    def baseline_us(self) -> float:
        """Time of the uncoarsened default config (cps=1, g=128)."""
        for cfg, us in self.timings:
            if cfg.chunks_per_step == 1 and cfg.group_size == 128:
                return us
        return self.timings[0][1]

    @property
    def speedup(self) -> float:
        return self.baseline_us / max(self.us_per_call, 1e-9)


# winner memo: (kind, signature) -> TuneResult
_MEMO: Dict[tuple, TuneResult] = {}
# winning (matrix, plan) per signature — the matrix is retained on purpose:
# PLAN_CACHE evicts entries when their matrix is garbage-collected, so the
# tuned plan stays cached only while we hold the matrix alive here.
_TUNED: Dict[tuple, Tuple[RgCSR, "ops.RgCSRPlan"]] = {}


def clear_memo() -> None:
    _MEMO.clear()
    _TUNED.clear()


def _log_bucket(v: float) -> int:
    return int(np.ceil(np.log2(v + 1.0)))


def matrix_signature(dense: np.ndarray) -> tuple:
    """Structural fingerprint driving winner reuse.

    Log2-bucketed (rows, cols, nnz, row-length max/mean/std) — the same
    row-statistics the paper's Table 6 uses to characterize matrices, which
    are exactly what determines the padding/grid-step trade the tuner
    explores.  Near-identical matrices share a bucket and reuse the winner.
    """
    dense = np.asarray(dense)
    row_lens = (dense != 0).sum(axis=1) if dense.size else np.zeros(1)
    return (
        _log_bucket(dense.shape[0]),
        _log_bucket(dense.shape[1] if dense.ndim > 1 else 0),
        _log_bucket(float(row_lens.sum())),
        _log_bucket(float(row_lens.max(initial=0))),
        _log_bucket(float(row_lens.mean() if row_lens.size else 0.0)),
        _log_bucket(float(row_lens.std() if row_lens.size else 0.0)),
    )


def candidate_configs(
        chunks: Sequence[int] = CHUNKS_PER_STEP_CHOICES,
        group_sizes: Sequence[int] = DEFAULT_GROUP_SIZES,
        d_tiles: Sequence[int] = (LANES,)) -> Tuple[TuneConfig, ...]:
    return tuple(TuneConfig(c, g, d)
                 for g in group_sizes for c in chunks for d in d_tiles)


def _search(dense: np.ndarray, run, kind: str, *,
            candidates: Optional[Iterable[TuneConfig]],
            repeats: int, storage_cap: float,
            memo_key_extra: tuple = ()) -> TuneResult:
    dense = np.asarray(dense)
    sig = matrix_signature(dense)
    if candidates is None:
        candidates = candidate_configs(
            d_tiles=DEFAULT_D_TILES if kind == "spmm" else (LANES,))
    candidates = sorted(set(candidates))
    # the candidate set is part of the memo key: a restricted search must
    # never be answered with a winner outside its own candidate set
    memo_key = (kind, sig, tuple(candidates), *memo_key_extra)
    hit = _MEMO.get(memo_key)
    if hit is not None:
        return dataclasses.replace(hit, from_memo=True)

    mats: Dict[int, RgCSR] = {}
    plans: Dict[Tuple[int, int], ops.RgCSRPlan] = {}
    baseline_slots = None
    timings = []
    for cfg in candidates:
        if cfg.group_size not in mats:
            mats[cfg.group_size] = RgCSR.from_dense(
                dense, group_size=cfg.group_size)
        pkey = (cfg.group_size, cfg.chunks_per_step)
        if pkey not in plans:
            plans[pkey] = ops.PLAN_CACHE.get(
                mats[cfg.group_size], chunks_per_step=cfg.chunks_per_step)
        plan = plans[pkey]
        if baseline_slots is None:
            baseline_slots = plan.stored_slots * plan.group_size
        # fill-ratio pruning: a config that multiplies stored bytes on a
        # memory-bound op cannot win — skip it without timing.
        stored = plan.stored_slots * plan.group_size
        if stored > storage_cap * max(baseline_slots, 1) and timings:
            continue
        us = time_us(run, plan, cfg, repeats=repeats, warmup=1)
        timings.append((cfg, us))

    best_cfg, best_us = min(timings, key=lambda t: t[1])
    result = TuneResult(config=best_cfg, us_per_call=best_us,
                        timings=tuple(timings), signature=sig)
    _MEMO[memo_key] = result
    return result


def autotune_spmv(dense: np.ndarray, *,
                  candidates: Optional[Iterable[TuneConfig]] = None,
                  repeats: int = 3, storage_cap: float = 4.0,
                  interpret: bool | None = None) -> TuneResult:
    """Search (chunks_per_step, group_size) for SpMV on ``dense``.

    The first candidate (the cps=1 baseline) is always timed; later
    candidates are pruned when their padded storage exceeds
    ``storage_cap ×`` the baseline's.  Winners are memoized per
    :func:`matrix_signature`.
    """
    m = dense.shape[1] if np.asarray(dense).ndim > 1 else 0
    x = jnp.asarray(np.random.default_rng(0).standard_normal(m)
                    .astype(np.float32))

    def run(plan, cfg):
        return ops.rgcsr_spmv(plan, x, interpret=interpret)

    return _search(dense, run, "spmv", candidates=candidates,
                   repeats=repeats, storage_cap=storage_cap)


def autotune_spmm(dense: np.ndarray, d: int, *,
                  candidates: Optional[Iterable[TuneConfig]] = None,
                  repeats: int = 3, storage_cap: float = 4.0,
                  interpret: bool | None = None) -> TuneResult:
    """Search (chunks_per_step, group_size, d_tile) for SpMM at width ``d``."""
    m = dense.shape[1] if np.asarray(dense).ndim > 1 else 0
    x = jnp.asarray(np.random.default_rng(0).standard_normal((m, d))
                    .astype(np.float32))

    def run(plan, cfg):
        return ops.rgcsr_spmm(plan, x, d_tile=cfg.d_tile, interpret=interpret)

    return _search(dense, run, "spmm", candidates=candidates,
                   repeats=repeats, storage_cap=storage_cap,
                   memo_key_extra=(_log_bucket(d),))


def tuned_plan(dense: np.ndarray, *, repeats: int = 3,
               interpret: bool | None = None
               ) -> Tuple[ops.RgCSRPlan, TuneResult]:
    """Autotune SpMV for ``dense`` and return the winning cached plan.

    The winning matrix+plan pair is retained per signature (``_TUNED``) so
    the PLAN_CACHE entry survives this call — without the strong reference
    the matrix would be collected at return and its GC finalizer would
    evict the plan immediately, repaying the host repack on every call.
    """
    result = autotune_spmv(dense, repeats=repeats, interpret=interpret)
    key = (result.signature, result.config)
    hit = _TUNED.get(key)
    if hit is not None:
        return hit[1], result
    mat = RgCSR.from_dense(dense, group_size=result.config.group_size)
    plan = ops.PLAN_CACHE.get(
        mat, chunks_per_step=result.config.chunks_per_step)
    _TUNED[key] = (mat, plan)
    return plan, result
