"""Per-matrix autotuning of the RgCSR kernel pipeline (DESIGN.md §3.3).

CSR5 (Liu & Vinter 2015) and SELL-C-σ (Kreutzer et al. 2011) both show that
on wide-SIMD hardware the winning sparse schedule is a *tuned tile size*
chosen per matrix.  For our pipeline the knobs are:

* ``chunks_per_step`` — grid coarsening of the SpMV/SpMM kernels: fewer,
  fatter grid steps vs more padding on short groups;
* ``group_size``      — rows per RgCSR group: fill ratio vs lane utilization
  (the paper's Table 4 experiment, now closed-loop);
* ``d_tile``          — SpMM dense-width tile: X-panel residency vs output
  block pressure;
* ``ordering``        — block (consecutive rows, PR 1) vs adaptive
  (descending-length regrouping, DESIGN.md §5): less padding on skewed
  row-length profiles vs an output gather on the epilogue;
* ``spill_threshold`` — adaptive only: rows longer than this leave the
  grouped storage for a COO tail (the Table 6 pathological-row remedy).
  Candidates are matrix-derived (:func:`spill_threshold_candidates`).

The harness *measures* candidate configs (median wall time of the actual
kernel launch, jit-warmed and blocked) rather than modeling them, prunes
candidates whose padded storage blows up past ``storage_cap`` × the
baseline (the paper's fill-ratio pathology — a config that multiplies
stored bytes on a memory-bound op cannot win), and memoizes the winner per
**matrix signature** so structurally equivalent matrices (same log-bucketed
shape/nnz/row-length profile) reuse the search result.  Winners feed the
``PlanCache``: ``tuned_plan`` returns a ready, cached execution plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import timing as _timing
from repro.core.formats import RgCSR
from repro.core.timing import time_us
from repro.kernels import ops
from repro.kernels.rgcsr_spmv import (CHUNKS_PER_STEP_CHOICES, LANES,
                                      SUBLANES)

__all__ = ["TuneConfig", "TuneResult", "matrix_signature", "candidate_configs",
           "spill_threshold_candidates", "autotune_spmv", "autotune_spmm",
           "tuned_plan", "clear_memo", "set_timing_source", "timing_source",
           "shard_row_blocks", "autotune_spmv_per_shard",
           "harmonize_shard_winners",
           "DEFAULT_GROUP_SIZES", "DEFAULT_D_TILES", "DEFAULT_ORDERINGS"]

DEFAULT_GROUP_SIZES = (128, 256)
DEFAULT_D_TILES = (128, 256)
DEFAULT_ORDERINGS = ("block", "adaptive")


@dataclasses.dataclass(frozen=True, order=True)
class TuneConfig:
    """One point in the kernel schedule space.

    ``ordering``/``spill_threshold`` are the adaptive-grouping axes
    (DESIGN.md §5): ``'adaptive'`` regroups rows by descending length;
    ``spill_threshold > 0`` (adaptive only) additionally routes rows longer
    than the threshold to a COO tail.  ``0`` disables spilling.
    """
    chunks_per_step: int = 1
    group_size: int = 128
    d_tile: int = 128
    ordering: str = "block"
    spill_threshold: int = 0


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Winner of one search, with the full timing table for inspection.

    ``plan_stats`` parallels ``timings``: per measured candidate, the
    plan's ``(stored_slots, stored_elements, n_spilled_elements)`` — the
    deterministic structural figures :func:`harmonize_shard_winners` needs
    to reason about stacked grids without re-measuring.

    ``timing_source`` records which clock produced the timing table:
    ``"profiler"`` (device time from a jax.profiler trace session) or
    ``"wallclock"`` (host ``time_us``).  Every perf claim downstream
    (BENCH meta) carries this provenance.
    """
    config: TuneConfig
    us_per_call: float
    timings: Tuple[Tuple[TuneConfig, float], ...]
    signature: tuple
    from_memo: bool = False
    plan_stats: Tuple[Tuple[int, int, int], ...] = ()
    timing_source: str = "wallclock"

    @property
    def baseline_us(self) -> float:
        """Time of the uncoarsened default config (block, cps=1, g=128) —
        the PR 1 baseline schedule the speedup is quoted against."""
        for cfg, us in self.timings:
            if (cfg.chunks_per_step == 1 and cfg.group_size == 128
                    and cfg.ordering == "block"):
                return us
        return self.timings[0][1]

    @property
    def speedup(self) -> float:
        return self.baseline_us / max(self.us_per_call, 1e-9)


# winner memo: (kind, signature) -> TuneResult
_MEMO: Dict[tuple, TuneResult] = {}
# winning (matrix, plan) per signature — the matrix is retained on purpose:
# PLAN_CACHE evicts entries when their matrix is garbage-collected, so the
# tuned plan stays cached only while we hold the matrix alive here.
_TUNED: Dict[tuple, Tuple[RgCSR, "ops.RgCSRPlan"]] = {}


def clear_memo() -> None:
    _MEMO.clear()
    _TUNED.clear()


# timing-source policy: "auto" prefers the profiler when it works,
# "wallclock" forces host timing, "profiler" insists (still falls back if
# the trace parse fails — a search must never error out over provenance).
_TIMING_SOURCE = "auto"


def set_timing_source(mode: str) -> None:
    global _TIMING_SOURCE
    if mode not in ("auto", "wallclock", "profiler"):
        raise ValueError(f"timing source must be auto/wallclock/profiler, "
                         f"got {mode!r}")
    _TIMING_SOURCE = mode


def timing_source() -> str:
    """The clock the next search will try first.  Resolves to
    ``"wallclock"`` when forced, when the runtime has no working
    profiler, or when ``time_us`` has been monkeypatched (deterministic
    test fixtures replace it with a structural cost model — the profiler
    would bypass the patch and break the determinism those tests pin)."""
    if _TIMING_SOURCE == "wallclock":
        return "wallclock"
    if time_us is not _timing.time_us:
        return "wallclock"
    if not _timing.profiler_available():
        return "wallclock"
    return "profiler"


def _log_bucket(v: float) -> int:
    return int(np.ceil(np.log2(v + 1.0)))


def _plan_bytes(plan: "ops.RgCSRPlan") -> int:
    """HBM bytes one SpMV streams for this plan's matrix storage: grouped
    slots at (itemsize + 4 col) each, COO tail at (itemsize + 8 idx)."""
    itemsize = jnp.dtype(plan.values2d.dtype).itemsize
    return (plan.stored_slots * plan.group_size * (itemsize + 4)
            + plan.n_spilled_elements * (itemsize + 8))


def matrix_signature(dense: np.ndarray) -> tuple:
    """Structural fingerprint driving winner reuse.

    Log2-bucketed (rows, cols, nnz, row-length max/mean/std) — the same
    row-statistics the paper's Table 6 uses to characterize matrices, which
    are exactly what determines the padding/grid-step trade the tuner
    explores.  Near-identical matrices share a bucket and reuse the winner.
    """
    dense = np.asarray(dense)
    row_lens = (dense != 0).sum(axis=1) if dense.size else np.zeros(1)
    return (
        _log_bucket(dense.shape[0]),
        _log_bucket(dense.shape[1] if dense.ndim > 1 else 0),
        _log_bucket(float(row_lens.sum())),
        _log_bucket(float(row_lens.max(initial=0))),
        _log_bucket(float(row_lens.mean() if row_lens.size else 0.0)),
        _log_bucket(float(row_lens.std() if row_lens.size else 0.0)),
    )


def spill_threshold_candidates(row_lens: np.ndarray,
                               max_candidates: int = 2) -> Tuple[int, ...]:
    """Matrix-derived spill thresholds worth measuring (plus 0 = no spill).

    Spilling pays when a few rows are far longer than typical: each spilled
    row trades ``K_g·(itemsize+4)`` grouped bytes for ``len·(itemsize+8)``
    COO bytes *and* deflates its group's slot count for the other G-1 rows.

    Candidates are powers of two at ~2× and ~8× the mean row length,
    emitted only when the max row length's bucket sits strictly above them
    (i.e. spilling would actually split rows off).  Both the thresholds and
    the gate are computed from the *same log2 buckets*
    :func:`matrix_signature` uses — including the same all-rows mean — so
    every matrix in one signature bucket gets the identical candidate set,
    a requirement for winner-memo reuse (the candidate set is part of the
    memo key).
    """
    row_lens = np.asarray(row_lens)
    if row_lens.size == 0 or row_lens.max(initial=0) == 0:
        return (0,)
    mean_b = _log_bucket(float(row_lens.mean()))
    max_b = _log_bucket(float(row_lens.max()))
    # 1 << mean_b is already ~2× the mean (ceil-log bucket), so shifts 0/2
    # put the thresholds at ~2× and ~8× the mean row length — measured to
    # dominate laxer thresholds on both padded bytes and µs for the zipf
    # and few-dense-rows profiles (the COO tail is cheap; the group slots a
    # long row forces on its G-1 neighbours are not).
    cands = []
    for shift in (0, 2):
        if max_b > mean_b + shift:           # bucket-level "max > threshold"
            cands.append(1 << (mean_b + shift))
    return (0,) + tuple(cands[:max_candidates])


def candidate_configs(
        chunks: Sequence[int] = CHUNKS_PER_STEP_CHOICES,
        group_sizes: Sequence[int] = DEFAULT_GROUP_SIZES,
        d_tiles: Sequence[int] = (LANES,),
        orderings: Sequence[str] = ("block",),
        spill_thresholds: Sequence[int] = (0,)) -> Tuple[TuneConfig, ...]:
    """Cartesian schedule grid.  ``spill_thresholds`` applies to adaptive
    configs only (block grouping cannot spill); 0 = no spill."""
    out = []
    for g in group_sizes:
        for c in chunks:
            for d in d_tiles:
                for o in orderings:
                    for t in (spill_thresholds if o == "adaptive" else (0,)):
                        out.append(TuneConfig(c, g, d, o, t))
    return tuple(out)


def _search(dense: np.ndarray, run, kind: str, *,
            candidates: Optional[Iterable[TuneConfig]],
            repeats: int, storage_cap: float,
            memo_key_extra: tuple = ()) -> TuneResult:
    dense = np.asarray(dense)
    sig = matrix_signature(dense)
    if candidates is None:
        row_lens = ((dense != 0).sum(axis=1) if dense.size
                    else np.zeros(0, np.int64))
        candidates = candidate_configs(
            d_tiles=DEFAULT_D_TILES if kind == "spmm" else (LANES,),
            orderings=DEFAULT_ORDERINGS,
            spill_thresholds=spill_threshold_candidates(row_lens))
    # block configs sort (and therefore time) first so the storage-pruning
    # baseline and TuneResult.baseline_us are the PR 1 block schedule
    candidates = sorted(set(candidates),
                        key=lambda c: (c.ordering != "block", c))
    # the candidate set is part of the memo key: a restricted search must
    # never be answered with a winner outside its own candidate set
    memo_key = (kind, sig, tuple(candidates), *memo_key_extra)
    hit = _MEMO.get(memo_key)
    if hit is not None:
        return dataclasses.replace(hit, from_memo=True)

    # pass 1 — selection: build plans and apply the structural pruning
    # (no timing yet, so the whole surviving set can share one profiler
    # trace session in pass 2)
    mats: Dict[int, RgCSR] = {}
    plans: Dict[Tuple[int, int, str, int], ops.RgCSRPlan] = {}
    block_bytes: Dict[Tuple[int, int], Tuple[int, int]] = {}
    baseline_slots = None
    selected = []
    for cfg in candidates:
        if cfg.group_size not in mats:
            mats[cfg.group_size] = RgCSR.from_dense(
                dense, group_size=cfg.group_size)
        pkey = (cfg.group_size, cfg.chunks_per_step, cfg.ordering,
                cfg.spill_threshold)
        if pkey not in plans:
            plans[pkey] = ops.PLAN_CACHE.get(
                mats[cfg.group_size], chunks_per_step=cfg.chunks_per_step,
                ordering=cfg.ordering,
                spill_threshold=cfg.spill_threshold)
        plan = plans[pkey]
        if baseline_slots is None:
            baseline_slots = plan.stored_elements
        if cfg.ordering == "block":
            block_bytes[(cfg.group_size, cfg.chunks_per_step)] = \
                (_plan_bytes(plan), plan.num_steps)
        else:
            # dominance pruning: an adaptive plan that moves the same (or
            # more) HBM bytes (the TPU cost model) AND launches the same
            # (or more) grid steps (the interpret-mode cost model) as the
            # already-selected block plan of the same (G, cps) buys
            # nothing in either regime and still pays the output gather —
            # it cannot win, so don't let measurement noise crown it.
            # Flat row-length profiles (stencils) prune their whole
            # adaptive side here; a plan cheaper under either model is
            # still timed.
            bb = block_bytes.get((cfg.group_size, cfg.chunks_per_step))
            if bb is not None and _plan_bytes(plan) >= bb[0] \
                    and plan.num_steps >= bb[1]:
                continue
        # fill-ratio pruning: a config that multiplies stored bytes on a
        # memory-bound op cannot win — skip it without timing.
        if plan.stored_elements > storage_cap * max(baseline_slots, 1) \
                and selected:
            continue
        selected.append((cfg, plan))

    # pass 2 — measurement: device time from one shared profiler trace
    # session when available, host wall-clock otherwise; record which.
    source = timing_source()
    us_list = None
    if source == "profiler":
        fns = [(lambda plan=plan, cfg=cfg: run(plan, cfg))
               for cfg, plan in selected]
        us_list = _timing.profiled_time_us_group(fns, repeats=repeats,
                                                 warmup=1)
        if us_list is None:
            source = "wallclock"
    if us_list is None:
        us_list = [time_us(run, plan, cfg, repeats=repeats, warmup=1)
                   for cfg, plan in selected]
    timings = [(cfg, us) for (cfg, _), us in zip(selected, us_list)]
    stats = [(plan.stored_slots, plan.stored_elements,
              plan.n_spilled_elements) for _, plan in selected]

    best_cfg, best_us = min(timings, key=lambda t: t[1])
    result = TuneResult(config=best_cfg, us_per_call=best_us,
                        timings=tuple(timings), signature=sig,
                        plan_stats=tuple(stats), timing_source=source)
    _MEMO[memo_key] = result
    return result


def autotune_spmv(dense: np.ndarray, *,
                  candidates: Optional[Iterable[TuneConfig]] = None,
                  repeats: int = 3, storage_cap: float = 4.0,
                  interpret: bool | None = None) -> TuneResult:
    """Search (chunks_per_step, group_size) for SpMV on ``dense``.

    The first candidate (the cps=1 baseline) is always timed; later
    candidates are pruned when their padded storage exceeds
    ``storage_cap ×`` the baseline's.  Winners are memoized per
    :func:`matrix_signature`.
    """
    m = dense.shape[1] if np.asarray(dense).ndim > 1 else 0
    x = jnp.asarray(np.random.default_rng(0).standard_normal(m)
                    .astype(np.float32))

    def run(plan, cfg):
        return ops.rgcsr_spmv(plan, x, interpret=interpret)

    return _search(dense, run, "spmv", candidates=candidates,
                   repeats=repeats, storage_cap=storage_cap)


def autotune_spmm(dense: np.ndarray, d: int, *,
                  candidates: Optional[Iterable[TuneConfig]] = None,
                  repeats: int = 3, storage_cap: float = 4.0,
                  interpret: bool | None = None) -> TuneResult:
    """Search (chunks_per_step, group_size, d_tile) for SpMM at width ``d``."""
    m = dense.shape[1] if np.asarray(dense).ndim > 1 else 0
    x = jnp.asarray(np.random.default_rng(0).standard_normal((m, d))
                    .astype(np.float32))

    def run(plan, cfg):
        return ops.rgcsr_spmm(plan, x, d_tile=cfg.d_tile, interpret=interpret)

    return _search(dense, run, "spmm", candidates=candidates,
                   repeats=repeats, storage_cap=storage_cap,
                   memo_key_extra=(_log_bucket(d),))


def shard_row_blocks(dense: np.ndarray, n_shards: int,
                     x_mode: str = "replicated") -> list:
    """The per-device blocks a :class:`ShardedRgCSR` over ``n_shards``
    would *group* — each padded to ``rows_per_shard`` rows, matching the
    shard layout exactly so per-shard tuning measures the real profile.

    ``x_mode='split'`` additionally restricts each block to the shard's
    **local** column slice (padded to ``cols_per_shard``): split-mode
    grouped storage holds only local-column entries (DESIGN.md §12.1 —
    remote entries ride the config-independent exchange tail), so that is
    the matrix the schedule knobs actually shape.
    """
    from repro.core.formats import ShardedRgCSR
    dense = np.asarray(dense)
    n, m = dense.shape
    rps, cstride = ShardedRgCSR.shard_layout(n, m, n_shards)
    blocks = []
    for d in range(n_shards):
        lo, hi = d * rps, min((d + 1) * rps, n)
        if x_mode == "split":
            clo, chi = d * cstride, min((d + 1) * cstride, m)
            blk = np.zeros((rps, cstride), dense.dtype)
            if hi > lo and chi > clo:
                blk[: hi - lo, : chi - clo] = dense[lo:hi, clo:chi]
        else:
            blk = np.zeros((rps, m), dense.dtype)
            if hi > lo:
                blk[: hi - lo] = dense[lo:hi]
        blocks.append(blk)
    return blocks


def autotune_spmv_per_shard(dense: np.ndarray, n_shards: int, *,
                            group_size: int = 128, repeats: int = 3,
                            storage_cap: float = 4.0,
                            x_mode: str = "replicated",
                            interpret: bool | None = None
                            ) -> Tuple[TuneResult, ...]:
    """Tune each row shard independently (DESIGN.md §12).

    One global winner wastes the skewed case: the shard holding the heavy
    rows wants spill/adaptive while light shards want plain block cps>1
    (arXiv:1203.5737's per-profile grouping, applied per shard).  Each
    shard's block — its local-column slice in split mode, since that is
    what the grouped plan stores — runs its own :func:`autotune_spmv`
    search over ``(chunks_per_step, ordering, spill_threshold)`` at the
    fixed ``group_size`` (the stacked plan needs one G across shards);
    spill candidates derive from the *shard's own* row-length profile.
    Winners are memoized per shard signature via the ordinary ``_MEMO``,
    so the structurally identical light shards of a skewed matrix search
    once and share the result.  The returned configs feed
    ``make_sharded_plan(shard_configs=...)`` directly.
    """
    results = []
    for blk in shard_row_blocks(dense, n_shards, x_mode=x_mode):
        row_lens = (blk != 0).sum(axis=1)
        cands = candidate_configs(
            group_sizes=(group_size,), orderings=DEFAULT_ORDERINGS,
            spill_thresholds=spill_threshold_candidates(row_lens))
        results.append(autotune_spmv(blk, candidates=cands, repeats=repeats,
                                     storage_cap=storage_cap,
                                     interpret=interpret))
    return tuple(results)


def harmonize_shard_winners(results: Sequence[TuneResult]) -> list:
    """Per-shard configs that *stack* well (DESIGN.md §12.2).

    Taking each shard's independent winner ignores the SPMD coupling: the
    kernel cps is the gcd of the per-shard cps values, every shard's step
    table expands by ``cps_d / gcd``, and the stacked grid runs the *max*
    step count over shards — so at kernel cps ``k`` every shard pays its
    cps-``k`` grid-step count and only the bottleneck shard's figure
    matters.  Per-shard *measured* µs cannot see that coupling, and on
    small shards the candidates sit within host jitter of each other, so
    ranking on µs alone makes the stacked pick flip run to run.  The
    stacked cost is therefore scored **structurally first** from the
    deterministic ``plan_stats`` the search recorded (the same byte/step
    models §3.3 already prunes with): for each candidate kernel cps ``k``,
    each shard contributes its best config at ``chunks_per_step == k``
    (falling back to configs above ``k`` — runnable at ``k`` via
    step-table expansion) ranked by grid steps at ``k``, then stored
    bytes, then measured µs; ``k`` itself is scored by the stacked
    ``(max steps, total stored, bottleneck µs)``, ties to larger ``k``.
    Ordering/spill still specialize freely per shard — the skewed-matrix
    win: the heavy shard keeps spill/adaptive (fewer steps, a plan
    property), light shards keep plain block (no epilogue), and the
    result is reproducible across runs.
    """
    if not results:
        raise ValueError("harmonize_shard_winners needs >= 1 shard result")
    best = None
    for k in sorted(CHUNKS_PER_STEP_CHOICES):
        rows_per_step = SUBLANES * k
        picks = []
        for r in results:
            stats = r.plan_stats or ((0, 0, 0),) * len(r.timings)
            cands = [(slots // rows_per_step, elems, us, cfg)
                     for (cfg, us), (slots, elems, _) in zip(r.timings,
                                                             stats)
                     if cfg.chunks_per_step == k]
            if not cands:
                cands = [(slots // rows_per_step, elems, us, cfg)
                         for (cfg, us), (slots, elems, _) in zip(r.timings,
                                                                 stats)
                         if cfg.chunks_per_step > k]
            if not cands:
                picks = None
                break
            picks.append(min(cands))
        if picks is None:
            continue
        key = (max(p[0] for p in picks), sum(p[1] for p in picks),
               max(p[2] for p in picks), -k)
        if best is None or key < best[0]:
            best = (key, [p[3] for p in picks])
    if best is None:
        raise ValueError("no measured candidates to harmonize")
    return best[1]


def tuned_plan(dense: np.ndarray, *, repeats: int = 3,
               interpret: bool | None = None
               ) -> Tuple[ops.RgCSRPlan, TuneResult]:
    """Autotune SpMV for ``dense`` and return the winning cached plan.

    The winning matrix+plan pair is retained per signature (``_TUNED``) so
    the PLAN_CACHE entry survives this call — without the strong reference
    the matrix would be collected at return and its GC finalizer would
    evict the plan immediately, repaying the host repack on every call.
    """
    result = autotune_spmv(dense, repeats=repeats, interpret=interpret)
    key = (result.signature, result.config)
    hit = _TUNED.get(key)
    if hit is not None:
        return hit[1], result
    mat = RgCSR.from_dense(dense, group_size=result.config.group_size)
    plan = ops.PLAN_CACHE.get(
        mat, chunks_per_step=result.config.chunks_per_step,
        ordering=result.config.ordering,
        spill_threshold=result.config.spill_threshold)
    _TUNED[key] = (mat, plan)
    return plan, result
