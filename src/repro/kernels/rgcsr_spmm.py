"""RgCSR SpMM (sparse A × dense X) Pallas TPU kernel.

This is the kernel the LM framework actually uses (SparseLinear: pruned
weight matrix in RgCSR × activation batch).  Extending the paper's SpMV
schedule to SpMM multiplies arithmetic intensity by ``d`` (the dense width):
per stored element we still move ``itemsize + 4`` bytes of matrix but now do
``2 d`` flops against an X row that lives in VMEM.  This is exactly why
weight sparsity can pay on TPU despite SpMV itself being hopelessly
memory-bound (paper §1: intensity ≤ 1).

Schedule: grid ``(d_tiles, num_chunks)`` — chunk dim innermost so the output
block ``(group, d_tile)`` is revisited consecutively while a fixed
``(n_pad, DT)`` X panel stays VMEM-resident; the matrix streams once per
d-tile (weights-streamed schedule; optimal when X-panel reuse dominates,
i.e. small d — for large d swap the grid, see ops.spmm_grid_order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES = 8
LANES = 128

__all__ = ["rgcsr_spmm_kernel", "rgcsr_spmm_pallas"]


def rgcsr_spmm_kernel(chunk_group_ref, chunk_first_ref,
                      values_ref, columns_ref, x_ref, y_ref):
    """Blocks: values/columns (8, G); x (n_pad, DT) whole-rows panel; y (G, DT)."""
    c = pl.program_id(1)

    @pl.when(chunk_first_ref[c] == 1)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = values_ref[...]                      # (8, G)
    cols = columns_ref[...]                     # (8, G)
    x = x_ref[...]                              # (n_pad, DT)
    acc = y_ref[...]
    for s in range(SUBLANES):                   # static unroll: 8 FMA waves
        xg = jnp.take(x, cols[s], axis=0)       # (G, DT) row gather
        acc = acc + vals[s][:, None] * xg
    y_ref[...] = acc


@functools.partial(jax.jit,
                   static_argnames=("n_groups", "group_size", "d_tile", "interpret"))
def rgcsr_spmm_pallas(chunk_group, chunk_first, values2d, columns2d, x_pad,
                      *, n_groups: int, group_size: int, d_tile: int = LANES,
                      interpret: bool = True):
    """Launch RgCSR SpMM.  ``x_pad``: (n_pad, d_pad); returns (n_groups*G, d_pad)."""
    num_chunks = chunk_group.shape[0]
    g = group_size
    n_pad, d_pad = x_pad.shape
    d_tiles = d_pad // d_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(d_tiles, num_chunks),
        in_specs=[
            pl.BlockSpec((SUBLANES, g), lambda t, c, cg, cf: (c, 0)),
            pl.BlockSpec((SUBLANES, g), lambda t, c, cg, cf: (c, 0)),
            pl.BlockSpec((n_pad, d_tile), lambda t, c, cg, cf: (0, t)),
        ],
        out_specs=pl.BlockSpec((g, d_tile), lambda t, c, cg, cf: (cg[c], t)),
    )
    return pl.pallas_call(
        rgcsr_spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_groups * g, d_pad), values2d.dtype),
        interpret=interpret,
    )(chunk_group, chunk_first, values2d, columns2d, x_pad)
