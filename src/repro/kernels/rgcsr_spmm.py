"""RgCSR SpMM (sparse A × dense X) Pallas TPU kernel.

This is the kernel the LM framework actually uses (SparseLinear: pruned
weight matrix in RgCSR × activation batch).  Extending the paper's SpMV
schedule to SpMM multiplies arithmetic intensity by ``d`` (the dense width):
per stored element we still move ``itemsize + 4`` bytes of matrix but now do
``2 d`` flops against an X row that lives in VMEM.  This is exactly why
weight sparsity can pay on TPU despite SpMV itself being hopelessly
memory-bound (paper §1: intensity ≤ 1).

Schedule: grid ``(d_tiles, num_steps)`` — step dim innermost so the output
block ``(group, d_tile)`` is revisited consecutively while a fixed
``(n_pad, DT)`` X panel stays VMEM-resident; the matrix streams once per
d-tile (weights-streamed schedule; optimal when X-panel reuse dominates,
i.e. small d — for large d swap the grid, see ops.spmm_grid_order).

**Chunk coarsening** (DESIGN.md §3): one grid step processes
``chunks_per_step`` 8-slot chunks of one group — the same step table and
group-padded ``(S, G)`` storage as the SpMV kernel, so one
:class:`repro.kernels.ops.RgCSRPlan` drives both kernels.  Coarsening
amortizes the per-step grid overhead over ``8·chunks_per_step`` FMA waves
and enlarges the per-step contiguous matrix DMA.

Like the SpMV kernel, the output index map is the step table alone, so
adaptive (length-regrouped) plans run unchanged: ``y`` rows come back in
the permuted row space and ``ops.rgcsr_spmm`` fuses the inverse gather +
COO spill tail on the way out (DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES = 8
LANES = 128

__all__ = ["rgcsr_spmm_kernel", "rgcsr_spmm_pallas"]


def rgcsr_spmm_kernel(step_group_ref, step_first_ref,
                      values_ref, columns_ref, x_ref, y_ref):
    """Blocks: values/columns (R, G), R = 8·chunks_per_step;
    x (n_pad, DT) whole-rows panel; y (G, DT)."""
    s = pl.program_id(1)

    @pl.when(step_first_ref[s] == 1)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = values_ref[...]                      # (R, G)
    cols = columns_ref[...]                     # (R, G)
    x = x_ref[...]                              # (n_pad, DT)
    acc = y_ref[...]
    for k in range(vals.shape[0]):              # static unroll: R FMA waves
        xg = jnp.take(x, cols[k], axis=0)       # (G, DT) row gather
        acc = acc + vals[k][:, None] * xg
    y_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("n_groups", "group_size", "d_tile", "chunks_per_step",
                     "interpret"))
def rgcsr_spmm_pallas(step_group, step_first, values2d, columns2d, x_pad,
                      *, n_groups: int, group_size: int, d_tile: int = LANES,
                      chunks_per_step: int = 1, interpret: bool = True):
    """Launch RgCSR SpMM.  ``x_pad``: (n_pad, d_pad); returns (n_groups*G, d_pad)."""
    num_steps = step_group.shape[0]
    g = group_size
    rows_per_step = chunks_per_step * SUBLANES
    n_pad, d_pad = x_pad.shape
    d_tiles = d_pad // d_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(d_tiles, num_steps),
        in_specs=[
            pl.BlockSpec((rows_per_step, g), lambda t, s, sg, sf: (s, 0)),
            pl.BlockSpec((rows_per_step, g), lambda t, s, sg, sf: (s, 0)),
            pl.BlockSpec((n_pad, d_tile), lambda t, s, sg, sf: (0, t)),
        ],
        out_specs=pl.BlockSpec((g, d_tile), lambda t, s, sg, sf: (sg[s], t)),
    )
    return pl.pallas_call(
        rgcsr_spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_groups * g, d_pad), values2d.dtype),
        interpret=interpret,
    )(step_group, step_first, values2d, columns2d, x_pad)
