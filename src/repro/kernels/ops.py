"""Jit'd public wrappers around the Pallas kernels + the plan/cache layer.

``RgCSRPlan`` is the device-resident execution plan built once per
(matrix, kernel config) — the analogue of a real framework's format-compile
step: the flat grouped storage reshaped into the ``(S, G)`` slot-major tile
the kernel consumes, plus the **step table** that drives the data-dependent
grid.  With ``chunks_per_step > 1`` every group's slot count is padded up to
a multiple of ``8·chunks_per_step`` so one grid step covers several 8-slot
chunks of the same group (DESIGN.md §3); the padding is exact zeros with
ghost column index 0, i.e. masked at plan time.

``PlanCache`` is the process-wide memo: SpMV-heavy paths (core dispatch, the
serving engine, the benchmark harness) fetch plans through ``get_plan``
instead of rebuilding host-side layouts per call.  Entries are keyed on
matrix identity + config and evicted when the matrix is garbage-collected.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python with identical semantics; on a real TPU pass
``interpret=False`` (the default resolves via ``jax.default_backend()``).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import weakref
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import ELLPACK, RgCSR
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.rgcsr_spmm import rgcsr_spmm_pallas
from repro.kernels.rgcsr_spmv import (CHUNKS_PER_STEP_CHOICES, LANES,
                                      SUBLANES, rgcsr_spmv_pallas)

__all__ = ["RgCSRPlan", "make_plan", "rgcsr_spmv", "rgcsr_spmm",
           "EllPlan", "make_ell_plan", "ell_spmv", "default_interpret",
           "PlanCache", "PLAN_CACHE", "get_plan",
           "plan_from_params", "warm_plans_from_params",
           "DEFAULT_X_TILE_ELEMS"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


# x elements staged into VMEM per SpMV grid step before column tiling kicks
# in.  2^21 fp32 = 8 MiB — half the ~16 MiB/core VMEM, leaving room for the
# (R, G) matrix tiles and the (1, G) accumulator.  Matrices at or below this
# width keep the seed kernel's single unmasked whole-x stage; only wider
# ones pay the masked multi-tile path.
DEFAULT_X_TILE_ELEMS = 1 << 21


@dataclasses.dataclass(frozen=True)
class RgCSRPlan:
    """Kernel-ready layout for one RgCSR matrix at one kernel config.

    ``step_group``/``step_first`` form the coarsened step table: grid step
    ``s`` covers slot rows ``[R·s, R·(s+1))`` of ``values2d``/``columns2d``
    (``R = 8·chunks_per_step``) and belongs to group ``step_group[s]``.
    """

    values2d: Any       # (S, G)
    columns2d: Any      # (S, G) int32
    step_group: Any     # (num_steps,) int32
    step_first: Any     # (num_steps,) int32
    n_rows: int
    n_cols: int
    n_groups: int
    group_size: int
    chunks_per_step: int = 1

    @property
    def num_steps(self) -> int:
        """Grid steps the SpMV kernel launches (per x tile)."""
        return int(self.step_group.shape[0])

    @property
    def num_chunks(self) -> int:
        """8-slot chunks covered (= num_steps · chunks_per_step)."""
        return self.num_steps * self.chunks_per_step

    @property
    def stored_slots(self) -> int:
        return int(self.values2d.shape[0])


def make_plan(m: RgCSR, *, chunks_per_step: int = 1) -> RgCSRPlan:
    """Host-side plan construction (format-compile).

    ``chunks_per_step`` coarsens the grid: each group's ``(K_g, G)`` tile is
    re-padded so ``K_g`` is a multiple of ``8·chunks_per_step`` and one grid
    step consumes the whole coarsened sub-tile.  The extra padding rows are
    exact zeros (ghost column 0), so in-kernel accumulation over them is a
    masked no-op — the paper's artificial-zeros accounting extended to the
    coarsened tile.  The trade (fewer grid steps vs more padded bytes) is
    what :mod:`repro.kernels.autotune` measures per matrix.
    """
    if m.group_size % LANES != 0:
        raise ValueError(
            f"TPU plan needs group_size % {LANES} == 0, got {m.group_size} "
            f"(use group_size=128/256/512; smaller groups are modeled, not run "
            f"— DESIGN.md §2)")
    if m.slot_pad % SUBLANES != 0:
        raise ValueError(f"slot_pad must be a multiple of {SUBLANES}")
    if chunks_per_step not in CHUNKS_PER_STEP_CHOICES:
        raise ValueError(
            f"chunks_per_step must be one of {CHUNKS_PER_STEP_CHOICES}, "
            f"got {chunks_per_step}")
    g = m.group_size
    rows_per_step = chunks_per_step * SUBLANES
    slots = np.asarray(m.slots_per_group)
    n_groups = len(slots)
    total_slots = int(slots.sum())
    values2d = np.asarray(m.values).reshape(total_slots, g)
    columns2d = np.asarray(m.columns).reshape(total_slots, g).astype(np.int32)

    padded = (-(-slots // rows_per_step) * rows_per_step).astype(np.int64)
    if int(padded.sum()) != total_slots:
        # re-pad each group's tile up to the coarsened step granularity
        src_off = np.concatenate([[0], np.cumsum(slots)[:-1]])
        dst_off = np.concatenate([[0], np.cumsum(padded)[:-1]])
        vp = np.zeros((int(padded.sum()), g), values2d.dtype)
        cp = np.zeros((int(padded.sum()), g), np.int32)
        for gi in range(n_groups):
            k = int(slots[gi])
            vp[dst_off[gi]: dst_off[gi] + k] = values2d[src_off[gi]: src_off[gi] + k]
            cp[dst_off[gi]: dst_off[gi] + k] = columns2d[src_off[gi]: src_off[gi] + k]
        values2d, columns2d = vp, cp

    steps_per_group = (padded // rows_per_step).astype(np.int64)
    step_group = np.repeat(np.arange(n_groups, dtype=np.int32), steps_per_group)
    first_idx = np.cumsum(np.concatenate([[0], steps_per_group[:-1]]))
    step_first = np.zeros(len(step_group), dtype=np.int32)
    step_first[first_idx] = 1
    return RgCSRPlan(
        values2d=jnp.asarray(values2d),
        columns2d=jnp.asarray(columns2d),
        step_group=jnp.asarray(step_group),
        step_first=jnp.asarray(step_first),
        n_rows=m.shape[0],
        n_cols=m.shape[1],
        n_groups=m.n_groups,
        group_size=g,
        chunks_per_step=chunks_per_step,
    )


# ---------------------------------------------------------------------------
# PlanCache — process-wide memo of (matrix identity, config) -> RgCSRPlan
# ---------------------------------------------------------------------------


class PlanCache:
    """LRU plan cache keyed on matrix identity + kernel config.

    Keys use ``id(matrix)``; a ``weakref.finalize`` hook evicts every config
    of a matrix when it is garbage-collected (CPython runs the finalizer
    during deallocation, before the id can be reused).  Thread-safe; plan
    *construction* happens outside the lock so concurrent misses on
    different matrices don't serialize.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._plans: "collections.OrderedDict[tuple, RgCSRPlan]" = \
            collections.OrderedDict()
        self._finalized: set = set()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def get(self, m: RgCSR, *, chunks_per_step: int = 1) -> RgCSRPlan:
        key = (id(m), chunks_per_step)
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
        plan = make_plan(m, chunks_per_step=chunks_per_step)
        with self._lock:
            if key not in self._plans:
                self.misses += 1
                self._plans[key] = plan
                if id(m) not in self._finalized:
                    self._finalized.add(id(m))
                    weakref.finalize(m, self._evict, id(m))
                while len(self._plans) > self.maxsize:
                    self._plans.popitem(last=False)
            else:
                self.hits += 1
                plan = self._plans[key]
        return plan

    def _evict(self, mid: int) -> None:
        with self._lock:
            self._finalized.discard(mid)
            for key in [k for k in self._plans if k[0] == mid]:
                del self._plans[key]

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._finalized.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._plans)}

    def __len__(self) -> int:
        return len(self._plans)


PLAN_CACHE = PlanCache()


def get_plan(m: RgCSR, *, chunks_per_step: int = 1) -> RgCSRPlan:
    """Fetch (or build and memoize) the kernel plan for ``m``."""
    return PLAN_CACHE.get(m, chunks_per_step=chunks_per_step)


# ---------------------------------------------------------------------------
# SpMV / SpMM wrappers
# ---------------------------------------------------------------------------


def _x_tile_for(n_pad_min: int, x_tile: Optional[int]) -> Tuple[int, int]:
    """Resolve the x column-tile width and the final padded x length."""
    if x_tile is None:
        if n_pad_min <= DEFAULT_X_TILE_ELEMS:
            return n_pad_min, n_pad_min          # single tile — seed behaviour
        x_tile = DEFAULT_X_TILE_ELEMS
    x_tile = _pad_to(x_tile, LANES)
    return x_tile, _pad_to(n_pad_min, x_tile)


def rgcsr_spmv(plan: RgCSRPlan, x, *, interpret: bool | None = None,
               x_tile: int | None = None):
    """y = A @ x via the Pallas kernel. x: (n_cols,) -> y: (n_rows,).

    ``x_tile`` bounds the x slice staged into VMEM per grid step; ``None``
    stages x whole when it fits (``DEFAULT_X_TILE_ELEMS``) and tiles it
    otherwise, so wide matrices degrade smoothly instead of exhausting VMEM.
    """
    if interpret is None:
        interpret = default_interpret()
    n_pad_min = _pad_to(max(plan.n_cols, 1), LANES)
    xt, n_pad = _x_tile_for(n_pad_min, x_tile)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = rgcsr_spmv_pallas(
        plan.step_group, plan.step_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        chunks_per_step=plan.chunks_per_step, x_tile=xt,
        interpret=interpret)
    return y.reshape(-1)[: plan.n_rows]


def rgcsr_spmm(plan: RgCSRPlan, x, *, d_tile: int = LANES,
               interpret: bool | None = None):
    """Y = A @ X via the Pallas kernel. X: (n_cols, d) -> Y: (n_rows, d)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = x.shape
    n_pad = _pad_to(max(n, 1), SUBLANES)
    d_pad = _pad_to(max(d, 1), d_tile)
    x_pad = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    y = rgcsr_spmm_pallas(
        plan.step_group, plan.step_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        d_tile=d_tile, chunks_per_step=plan.chunks_per_step,
        interpret=interpret)
    return y[: plan.n_rows, :d]


# ---------------------------------------------------------------------------
# Plans over SparseLinear parameter trees (serving path)
# ---------------------------------------------------------------------------

# Memo keyed on (id(columns2d), dtype, d_out, d_in, group_size) — the dims
# are part of the key so an entry built with different/misinferred dims can
# never shadow a caller's correct ones.  The stored strong reference to the
# source values array both validates the entry (values identity must match —
# a training step invalidates it) and keeps the id stable.
_PARAM_PLANS: "collections.OrderedDict[tuple, tuple]" = collections.OrderedDict()
_PARAM_PLANS_MAX = 64
_PARAM_PLANS_LOCK = threading.Lock()


def plan_from_params(params, dtype, *, d_out: int, d_in: int,
                     group_size: int) -> RgCSRPlan:
    """RgCSRPlan view over SparseLinear param arrays (no host repack —
    the params already live in the kernel's slot-major layout, cps=1).

    With concrete arrays (eager per-layer paths) the container is memoized
    so each layer's plan is built once per process (``Engine`` warms this at
    init); under jit tracing the memo is bypassed and the container is
    rebuilt per trace, which is free — the jit'd serving path never pays
    per-call host plan work by construction.
    """
    n_groups = -(-d_out // group_size)
    # either array traced means we're inside a transform (grad over values
    # closes over concrete structure buffers) — never memoize tracers
    tracing = (isinstance(params["columns2d"], jax.core.Tracer)
               or isinstance(params["values2d"], jax.core.Tracer))
    key = (id(params["columns2d"]), jnp.dtype(dtype).str, d_out, d_in,
           group_size)
    if not tracing:
        with _PARAM_PLANS_LOCK:
            entry = _PARAM_PLANS.get(key)
            if entry is not None and entry[0] is params["values2d"]:
                _PARAM_PLANS.move_to_end(key)
                return entry[1]
    values = params["values2d"]
    if values.dtype != jnp.dtype(dtype):   # avoid a same-dtype device copy
        values = values.astype(dtype)
    plan = RgCSRPlan(
        values2d=values,
        columns2d=params["columns2d"],
        step_group=params["chunk_group"],
        step_first=params["chunk_first"],
        n_rows=d_out, n_cols=d_in, n_groups=int(n_groups),
        group_size=group_size, chunks_per_step=1)
    if not tracing:
        with _PARAM_PLANS_LOCK:
            _PARAM_PLANS[key] = (params["values2d"], plan)
            while len(_PARAM_PLANS) > _PARAM_PLANS_MAX:
                _PARAM_PLANS.popitem(last=False)
    return plan


def param_plan_stats() -> Dict[str, int]:
    """Size of the SparseLinear param-plan memo (serving-path cache)."""
    with _PARAM_PLANS_LOCK:
        return {"entries": len(_PARAM_PLANS)}


def warm_plans_from_params(params, dtype=jnp.float32) -> int:
    """Pre-stage SpMM plans for every SparseLinear subtree in ``params``.

    Walks the parameter tree for the RgCSR layout signature
    (``values2d``/``columns2d``/``chunk_group``/``chunk_first``) and builds
    each layer's plan once so the first *eager* per-layer call pays no
    host-side plan work.  Scope limits, by construction:

    * the jit'd prefill/decode path assembles plan containers at trace time
      (free) and never consults this memo — warming helps eager paths only;
    * layer-stacked (3-D) sparse params are skipped — the stacked scan path
      only ever sees traced slices;
    * ``d_in``/``d_out`` are inferred from the buffers (max column + 1,
      ``n_groups·G``); an eager caller passing different exact dims simply
      misses this entry and builds its own (dims are part of the memo key —
      a misinferred warm entry can never shadow correct dims).

    Returns #plans warmed.
    """
    warmed = 0

    def visit(node) -> None:
        nonlocal warmed
        if not isinstance(node, dict):
            return
        if {"values2d", "columns2d", "chunk_group", "chunk_first"} <= set(node):
            if getattr(node["values2d"], "ndim", 0) == 2:
                g = int(node["columns2d"].shape[1])
                n_groups = int(np.asarray(node["chunk_group"])[-1]) + 1 \
                    if node["chunk_group"].shape[0] else 1
                d_in = int(np.asarray(node["columns2d"]).max()) + 1
                plan_from_params(node, dtype, d_out=n_groups * g,
                                 d_in=d_in, group_size=g)
                warmed += 1
            return
        for v in node.values():
            visit(v)

    visit(params)
    return warmed


# ---------------------------------------------------------------------------
# ELLPACK
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EllPlan:
    values2d: Any   # (K_pad, N_pad)
    columns2d: Any  # (K_pad, N_pad)
    n_rows: int
    n_cols: int


def make_ell_plan(m: ELLPACK) -> EllPlan:
    vals = np.asarray(m.values)
    cols = np.asarray(m.columns).astype(np.int32)
    k, n = vals.shape
    k_pad, n_pad = _pad_to(k, SUBLANES), _pad_to(n, LANES)
    vp = np.zeros((k_pad, n_pad), vals.dtype)
    cp = np.zeros((k_pad, n_pad), np.int32)
    vp[:k, :n] = vals
    cp[:k, :n] = cols
    return EllPlan(values2d=jnp.asarray(vp), columns2d=jnp.asarray(cp),
                   n_rows=m.shape[0], n_cols=m.shape[1])


def ell_spmv(plan: EllPlan, x, *, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    n_pad = _pad_to(max(plan.n_cols, 1), LANES)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = ell_spmv_pallas(plan.values2d, plan.columns2d, x_pad,
                        interpret=interpret)
    return y[0, : plan.n_rows]
