"""Jit'd public wrappers around the Pallas kernels + the plan/cache layer.

``RgCSRPlan`` is the device-resident execution plan built once per
(matrix, kernel config) — the analogue of a real framework's format-compile
step: the flat grouped storage reshaped into the ``(S, G)`` slot-major tile
the kernel consumes, plus the **step table** that drives the data-dependent
grid.  With ``chunks_per_step > 1`` every group's slot count is padded up to
a multiple of ``8·chunks_per_step`` so one grid step covers several 8-slot
chunks of the same group (DESIGN.md §3); the padding is exact zeros with
ghost column index 0, i.e. masked at plan time.

``PlanCache`` is the process-wide memo: SpMV-heavy paths (core dispatch, the
serving engine, the benchmark harness) fetch plans through ``get_plan``
instead of rebuilding host-side layouts per call.  Entries are keyed on
matrix identity + config and evicted when the matrix is garbage-collected.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python with identical semantics; on a real TPU pass
``interpret=False`` (the default resolves via ``jax.default_backend()``).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
import weakref
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import ELLPACK, RgCSR
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.rgcsr_spmm import rgcsr_spmm_pallas
from repro.kernels.rgcsr_spmv import (CHUNKS_PER_STEP_CHOICES, LANES,
                                      SUBLANES, rgcsr_spmv_pallas)

__all__ = ["RgCSRPlan", "make_plan", "rgcsr_spmv", "rgcsr_spmm",
           "EllPlan", "make_ell_plan", "ell_spmv", "default_interpret",
           "PlanCache", "PLAN_CACHE", "get_plan",
           "plan_from_params", "warm_plans_from_params",
           "DEFAULT_X_TILE_ELEMS"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


# x elements staged into VMEM per SpMV grid step before column tiling kicks
# in.  2^21 fp32 = 8 MiB — half the ~16 MiB/core VMEM, leaving room for the
# (R, G) matrix tiles and the (1, G) accumulator.  Matrices at or below this
# width keep the seed kernel's single unmasked whole-x stage; only wider
# ones pay the masked multi-tile path.
DEFAULT_X_TILE_ELEMS = 1 << 21


@dataclasses.dataclass(frozen=True)
class RgCSRPlan:
    """Kernel-ready layout for one RgCSR matrix at one kernel config.

    ``step_group``/``step_first`` form the coarsened step table: grid step
    ``s`` covers slot rows ``[R·s, R·(s+1))`` of ``values2d``/``columns2d``
    (``R = 8·chunks_per_step``) and belongs to group ``step_group[s]``.

    **Adaptive plans** (``ordering='adaptive'``, DESIGN.md §5): groups hold
    length-sorted rows instead of consecutive ones, so the kernel's output
    lives in the *permuted* row space.  ``gather_idx``/``grouped_mask`` are
    the fused inverse-permutation map back to original rows, and rows longer
    than ``spill_threshold`` live in the COO tail (``spill_*``), combined
    with a segment-sum in the epilogue.  Block plans leave these ``None``.
    """

    values2d: Any       # (S, G)
    columns2d: Any      # (S, G) int32
    step_group: Any     # (num_steps,) int32
    step_first: Any     # (num_steps,) int32
    n_rows: int
    n_cols: int
    n_groups: int
    group_size: int
    chunks_per_step: int = 1
    # --- adaptive grouping (None/defaults on block plans) ---
    ordering: str = "block"        # "block" | "adaptive"
    spill_threshold: int = 0       # 0 = no spill
    nnz: int = -1                  # true nonzeros incl. spill (-1 = unknown)
    gather_idx: Any = None         # (n_rows,) int32: flat kernel-output index
    grouped_mask: Any = None       # (n_rows,) bool: False = row is spilled
    spill_values: Any = None       # (nnz_spill,)
    spill_rows: Any = None         # (nnz_spill,) int32 original row ids
    spill_columns: Any = None      # (nnz_spill,) int32

    @property
    def num_steps(self) -> int:
        """Grid steps the SpMV kernel launches (per x tile)."""
        return int(self.step_group.shape[0])

    @property
    def num_chunks(self) -> int:
        """8-slot chunks covered (= num_steps · chunks_per_step)."""
        return self.num_steps * self.chunks_per_step

    @property
    def stored_slots(self) -> int:
        return int(self.values2d.shape[0])

    @property
    def n_spilled_elements(self) -> int:
        return 0 if self.spill_values is None else int(
            self.spill_values.shape[0])

    @property
    def stored_elements(self) -> int:
        """Grouped slots × lanes + COO tail (the format's byte footprint)."""
        return self.stored_slots * self.group_size + self.n_spilled_elements

    @property
    def padded_slot_fraction(self) -> float:
        """Fraction of stored elements that are padding (artificial zeros).

        The paper's fill-ratio metric normalized to stored bytes: on a
        memory-bound op this is directly the fraction of wasted HBM traffic.
        Requires ``nnz`` (set by ``make_plan``; -1 on raw param-view plans).
        """
        if self.nnz < 0 or self.stored_elements == 0:
            return 0.0
        return (self.stored_elements - self.nnz) / self.stored_elements


def make_plan(m: RgCSR, *, chunks_per_step: int = 1,
              ordering: str = "block",
              spill_threshold: int = 0) -> RgCSRPlan:
    """Host-side plan construction (format-compile).

    ``chunks_per_step`` coarsens the grid: each group's ``(K_g, G)`` tile is
    re-padded so ``K_g`` is a multiple of ``8·chunks_per_step`` and one grid
    step consumes the whole coarsened sub-tile.  The extra padding rows are
    exact zeros (ghost column 0), so in-kernel accumulation over them is a
    masked no-op — the paper's artificial-zeros accounting extended to the
    coarsened tile.  The trade (fewer grid steps vs more padded bytes) is
    what :mod:`repro.kernels.autotune` measures per matrix.

    ``ordering='adaptive'`` (DESIGN.md §5) regroups rows by descending
    length so same-length rows share groups (each group's slot count is its
    own max, not the max over an arbitrary consecutive window), and rows
    longer than ``spill_threshold`` (> 0) leave the grouped storage for a
    COO tail.  The kernel then computes in the permuted row space; the
    SpMV/SpMM wrappers fuse the inverse gather + tail back in.
    """
    if m.group_size % LANES != 0:
        raise ValueError(
            f"TPU plan needs group_size % {LANES} == 0, got {m.group_size} "
            f"(use group_size=128/256/512; smaller groups are modeled, not run "
            f"— DESIGN.md §2)")
    if m.slot_pad % SUBLANES != 0:
        raise ValueError(f"slot_pad must be a multiple of {SUBLANES}")
    if chunks_per_step not in CHUNKS_PER_STEP_CHOICES:
        raise ValueError(
            f"chunks_per_step must be one of {CHUNKS_PER_STEP_CHOICES}, "
            f"got {chunks_per_step}")
    if ordering not in ("block", "adaptive"):
        raise ValueError(
            f"ordering must be 'block' or 'adaptive', got {ordering!r}")
    if ordering == "adaptive":
        return _make_adaptive_plan(m, chunks_per_step=chunks_per_step,
                                   spill_threshold=int(spill_threshold))
    if spill_threshold:
        raise ValueError(
            "spill_threshold requires ordering='adaptive' (block grouping "
            "cannot drop rows without a permutation gather)")
    g = m.group_size
    rows_per_step = chunks_per_step * SUBLANES
    slots = np.asarray(m.slots_per_group)
    n_groups = len(slots)
    total_slots = int(slots.sum())
    values2d = np.asarray(m.values).reshape(total_slots, g)
    columns2d = np.asarray(m.columns).reshape(total_slots, g).astype(np.int32)

    padded = (-(-slots // rows_per_step) * rows_per_step).astype(np.int64)
    if int(padded.sum()) != total_slots:
        # re-pad each group's tile up to the coarsened step granularity
        src_off = np.concatenate([[0], np.cumsum(slots)[:-1]])
        dst_off = np.concatenate([[0], np.cumsum(padded)[:-1]])
        vp = np.zeros((int(padded.sum()), g), values2d.dtype)
        cp = np.zeros((int(padded.sum()), g), np.int32)
        for gi in range(n_groups):
            k = int(slots[gi])
            vp[dst_off[gi]: dst_off[gi] + k] = values2d[src_off[gi]: src_off[gi] + k]
            cp[dst_off[gi]: dst_off[gi] + k] = columns2d[src_off[gi]: src_off[gi] + k]
        values2d, columns2d = vp, cp

    step_group, step_first = _step_table(padded, rows_per_step)
    return RgCSRPlan(
        values2d=jnp.asarray(values2d),
        columns2d=jnp.asarray(columns2d),
        step_group=jnp.asarray(step_group),
        step_first=jnp.asarray(step_first),
        n_rows=m.shape[0],
        n_cols=m.shape[1],
        n_groups=m.n_groups,
        group_size=g,
        chunks_per_step=chunks_per_step,
        nnz=m.nnz,
    )


def _step_table(padded_slots: np.ndarray, rows_per_step: int):
    """(step_group, step_first) for per-group padded slot counts."""
    steps_per_group = (padded_slots // rows_per_step).astype(np.int64)
    n_groups = len(steps_per_group)
    step_group = np.repeat(np.arange(n_groups, dtype=np.int32),
                           steps_per_group)
    first_idx = np.cumsum(np.concatenate([[0], steps_per_group[:-1]]))
    step_first = np.zeros(len(step_group), dtype=np.int32)
    step_first[first_idx] = 1
    return step_group, step_first


def _make_adaptive_plan(m: RgCSR, *, chunks_per_step: int,
                        spill_threshold: int) -> RgCSRPlan:
    """Length-aware regrouping + pathological-row spill (DESIGN.md §5).

    1. rows with nnz > ``spill_threshold`` (if > 0) leave for the COO tail;
    2. remaining rows are permuted by descending length (stable), so each
       group of ``G`` rows has near-uniform lengths and its slot count
       ``K_g = roundup(max len in group, 8·chunks_per_step)`` carries
       minimal padding under the alignment constraint;
    3. the kernel output is in permuted space — ``gather_idx`` maps original
       row ``r`` to its flat output lane, ``grouped_mask`` marks spilled
       rows (their value comes from the tail's segment-sum alone).
    """
    from repro.core.ordering import descending_from_lengths, split_spill_rows

    g = m.group_size
    rows_per_step = chunks_per_step * SUBLANES
    n_rows, n_cols = m.shape
    row_lens = np.asarray(m.row_lengths).astype(np.int64)
    csr_v, csr_c, row_ptr = m.to_csr_arrays()

    grouped_rows, spilled_rows = split_spill_rows(row_lens, spill_threshold)
    order = descending_from_lengths(row_lens[grouped_rows])
    perm = grouped_rows[order]                 # position p holds row perm[p]
    n_grouped = len(perm)
    n_groups = max(1, -(-n_grouped // g))

    # per-group slot counts: own max length, aligned to the step granularity
    slots = np.empty(n_groups, dtype=np.int64)
    for gi in range(n_groups):
        rows_g = perm[gi * g: (gi + 1) * g]
        k_g = int(row_lens[rows_g].max()) if len(rows_g) else 0
        slots[gi] = -(-max(k_g, 1) // rows_per_step) * rows_per_step
    offsets = np.concatenate([[0], np.cumsum(slots)[:-1]])

    values2d = np.zeros((int(slots.sum()), g), np.asarray(m.values).dtype)
    columns2d = np.zeros((int(slots.sum()), g), np.int32)
    for p in range(n_grouped):
        r = int(perm[p])
        gi, lane = p // g, p % g
        lo, hi = int(row_ptr[r]), int(row_ptr[r + 1])
        base = int(offsets[gi])
        values2d[base: base + (hi - lo), lane] = csr_v[lo:hi]
        columns2d[base: base + (hi - lo), lane] = csr_c[lo:hi]

    step_group, step_first = _step_table(slots, rows_per_step)

    gather_idx = np.zeros(n_rows, np.int32)
    grouped_mask = np.zeros(n_rows, bool)
    gather_idx[perm] = np.arange(n_grouped, dtype=np.int32)
    grouped_mask[perm] = True

    spill_sel = np.zeros(len(csr_v), bool)
    for r in spilled_rows:
        spill_sel[int(row_ptr[r]): int(row_ptr[r + 1])] = True
    spill_row_ids = np.repeat(
        spilled_rows.astype(np.int32),
        (row_ptr[spilled_rows + 1] - row_ptr[spilled_rows]).astype(np.int64)
        if len(spilled_rows) else np.empty(0, np.int64))

    return RgCSRPlan(
        values2d=jnp.asarray(values2d),
        columns2d=jnp.asarray(columns2d),
        step_group=jnp.asarray(step_group),
        step_first=jnp.asarray(step_first),
        n_rows=n_rows,
        n_cols=n_cols,
        n_groups=n_groups,
        group_size=g,
        chunks_per_step=chunks_per_step,
        ordering="adaptive",
        spill_threshold=spill_threshold,
        nnz=m.nnz,
        gather_idx=jnp.asarray(gather_idx),
        grouped_mask=jnp.asarray(grouped_mask),
        spill_values=jnp.asarray(csr_v[spill_sel]),
        spill_rows=jnp.asarray(spill_row_ids),
        spill_columns=jnp.asarray(csr_c[spill_sel].astype(np.int32)),
    )


# ---------------------------------------------------------------------------
# PlanCache — process-wide memo of (matrix identity, config) -> RgCSRPlan
# ---------------------------------------------------------------------------


class PlanCache:
    """LRU plan cache keyed on matrix identity + kernel config.

    Keys use ``id(matrix)`` plus every plan-shaping config field —
    ``(chunks_per_step, ordering, spill_threshold)`` — so a block plan and
    an adaptive plan of the same matrix (or two adaptive plans at different
    spill thresholds) can never shadow each other.  A ``weakref.finalize``
    hook evicts every config of a matrix when it is garbage-collected
    (CPython runs the finalizer during deallocation, before the id can be
    reused).  Thread-safe; plan *construction* happens outside the lock so
    concurrent misses on different matrices don't serialize.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._plans: "collections.OrderedDict[tuple, RgCSRPlan]" = \
            collections.OrderedDict()
        self._finalized: set = set()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def get(self, m: RgCSR, *, chunks_per_step: int = 1,
            ordering: str = "block", spill_threshold: int = 0) -> RgCSRPlan:
        key = (id(m), chunks_per_step, ordering, int(spill_threshold))
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
        plan = make_plan(m, chunks_per_step=chunks_per_step,
                         ordering=ordering, spill_threshold=spill_threshold)
        with self._lock:
            if key not in self._plans:
                self.misses += 1
                self._plans[key] = plan
                if id(m) not in self._finalized:
                    self._finalized.add(id(m))
                    weakref.finalize(m, self._evict, id(m))
                while len(self._plans) > self.maxsize:
                    self._plans.popitem(last=False)
            else:
                self.hits += 1
                plan = self._plans[key]
        return plan

    def _evict(self, mid: int) -> None:
        with self._lock:
            self._finalized.discard(mid)
            for key in [k for k in self._plans if k[0] == mid]:
                del self._plans[key]

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._finalized.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._plans)}

    def __len__(self) -> int:
        return len(self._plans)


PLAN_CACHE = PlanCache()


def get_plan(m: RgCSR, *, chunks_per_step: int = 1, ordering: str = "block",
             spill_threshold: int = 0) -> RgCSRPlan:
    """Fetch (or build and memoize) the kernel plan for ``m``."""
    return PLAN_CACHE.get(m, chunks_per_step=chunks_per_step,
                          ordering=ordering, spill_threshold=spill_threshold)


# ---------------------------------------------------------------------------
# SpMV / SpMM wrappers
# ---------------------------------------------------------------------------


def _x_tile_for(n_pad_min: int, x_tile: Optional[int]) -> Tuple[int, int]:
    """Resolve the x column-tile width and the final padded x length."""
    if x_tile is None:
        if n_pad_min <= DEFAULT_X_TILE_ELEMS:
            return n_pad_min, n_pad_min          # single tile — seed behaviour
        x_tile = DEFAULT_X_TILE_ELEMS
    x_tile = _pad_to(x_tile, LANES)
    return x_tile, _pad_to(n_pad_min, x_tile)


@functools.partial(jax.jit, static_argnames=("n_rows", "has_spill"))
def _adaptive_finish_spmv(y_flat, x, gather_idx, grouped_mask,
                          spill_values, spill_rows, spill_columns,
                          *, n_rows: int, has_spill: bool):
    """Fused adaptive epilogue: inverse-permutation gather + COO tail.

    One jit region, no materialized scatter: original row ``r`` reads lane
    ``gather_idx[r]`` of the permuted kernel output (spilled rows masked to
    zero) and the pathological rows come back as a segment-sum over the COO
    tail — both fuse into a single gather/scatter pass over HBM.
    """
    out = jnp.where(grouped_mask, jnp.take(y_flat, gather_idx, axis=0),
                    jnp.zeros((), y_flat.dtype))
    if has_spill:
        prods = spill_values * jnp.take(x, spill_columns, axis=0)
        out = out + jax.ops.segment_sum(prods, spill_rows,
                                        num_segments=n_rows)
    return out


@functools.partial(jax.jit, static_argnames=("n_rows", "has_spill"))
def _adaptive_finish_spmm(y2d, x, gather_idx, grouped_mask,
                          spill_values, spill_rows, spill_columns,
                          *, n_rows: int, has_spill: bool):
    """SpMM twin of :func:`_adaptive_finish_spmv` (row gather over axis 0)."""
    out = jnp.where(grouped_mask[:, None],
                    jnp.take(y2d, gather_idx, axis=0),
                    jnp.zeros((), y2d.dtype))[:, : x.shape[1]]
    if has_spill:
        prods = jnp.take(x, spill_columns, axis=0) * spill_values[:, None]
        out = out + jax.ops.segment_sum(prods, spill_rows,
                                        num_segments=n_rows)
    return out


def rgcsr_spmv(plan: RgCSRPlan, x, *, interpret: bool | None = None,
               x_tile: int | None = None):
    """y = A @ x via the Pallas kernel. x: (n_cols,) -> y: (n_rows,).

    ``x_tile`` bounds the x slice staged into VMEM per grid step; ``None``
    stages x whole when it fits (``DEFAULT_X_TILE_ELEMS``) and tiles it
    otherwise, so wide matrices degrade smoothly instead of exhausting VMEM.

    Adaptive plans return through the fused epilogue (inverse gather +
    spill segment-sum); block plans slice the contiguous rows as before.
    """
    if interpret is None:
        interpret = default_interpret()
    n_pad_min = _pad_to(max(plan.n_cols, 1), LANES)
    xt, n_pad = _x_tile_for(n_pad_min, x_tile)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = rgcsr_spmv_pallas(
        plan.step_group, plan.step_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        chunks_per_step=plan.chunks_per_step, x_tile=xt,
        interpret=interpret)
    y_flat = y.reshape(-1)
    if plan.ordering != "adaptive":
        return y_flat[: plan.n_rows]
    return _adaptive_finish_spmv(
        y_flat, jnp.asarray(x), plan.gather_idx, plan.grouped_mask,
        plan.spill_values, plan.spill_rows, plan.spill_columns,
        n_rows=plan.n_rows, has_spill=plan.n_spilled_elements > 0)


def rgcsr_spmm(plan: RgCSRPlan, x, *, d_tile: int = LANES,
               interpret: bool | None = None):
    """Y = A @ X via the Pallas kernel. X: (n_cols, d) -> Y: (n_rows, d)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = x.shape
    n_pad = _pad_to(max(n, 1), SUBLANES)
    d_pad = _pad_to(max(d, 1), d_tile)
    x_pad = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    y = rgcsr_spmm_pallas(
        plan.step_group, plan.step_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        d_tile=d_tile, chunks_per_step=plan.chunks_per_step,
        interpret=interpret)
    if plan.ordering != "adaptive":
        return y[: plan.n_rows, :d]
    return _adaptive_finish_spmm(
        y, jnp.asarray(x), plan.gather_idx, plan.grouped_mask,
        plan.spill_values, plan.spill_rows, plan.spill_columns,
        n_rows=plan.n_rows, has_spill=plan.n_spilled_elements > 0)


# ---------------------------------------------------------------------------
# Plans over SparseLinear parameter trees (serving path)
# ---------------------------------------------------------------------------

# Memo keyed on (id(columns2d), dtype, d_out, d_in, group_size) — the dims
# are part of the key so an entry built with different/misinferred dims can
# never shadow a caller's correct ones.  The stored strong reference to the
# source values array both validates the entry (values identity must match —
# a training step invalidates it) and keeps the id stable.
_PARAM_PLANS: "collections.OrderedDict[tuple, tuple]" = collections.OrderedDict()
_PARAM_PLANS_MAX = 64
_PARAM_PLANS_LOCK = threading.Lock()


def plan_from_params(params, dtype, *, d_out: int, d_in: int,
                     group_size: int) -> RgCSRPlan:
    """RgCSRPlan view over SparseLinear param arrays (no host repack —
    the params already live in the kernel's slot-major layout, cps=1).

    With concrete arrays (eager per-layer paths) the container is memoized
    so each layer's plan is built once per process (``Engine`` warms this at
    init); under jit tracing the memo is bypassed and the container is
    rebuilt per trace, which is free — the jit'd serving path never pays
    per-call host plan work by construction.
    """
    n_groups = -(-d_out // group_size)
    # either array traced means we're inside a transform (grad over values
    # closes over concrete structure buffers) — never memoize tracers
    tracing = (isinstance(params["columns2d"], jax.core.Tracer)
               or isinstance(params["values2d"], jax.core.Tracer))
    key = (id(params["columns2d"]), jnp.dtype(dtype).str, d_out, d_in,
           group_size)
    if not tracing:
        with _PARAM_PLANS_LOCK:
            entry = _PARAM_PLANS.get(key)
            if entry is not None and entry[0] is params["values2d"]:
                _PARAM_PLANS.move_to_end(key)
                return entry[1]
    values = params["values2d"]
    if values.dtype != jnp.dtype(dtype):   # avoid a same-dtype device copy
        values = values.astype(dtype)
    plan = RgCSRPlan(
        values2d=values,
        columns2d=params["columns2d"],
        step_group=params["chunk_group"],
        step_first=params["chunk_first"],
        n_rows=d_out, n_cols=d_in, n_groups=int(n_groups),
        group_size=group_size, chunks_per_step=1)
    if not tracing:
        with _PARAM_PLANS_LOCK:
            _PARAM_PLANS[key] = (params["values2d"], plan)
            while len(_PARAM_PLANS) > _PARAM_PLANS_MAX:
                _PARAM_PLANS.popitem(last=False)
    return plan


def param_plan_stats() -> Dict[str, int]:
    """Size of the SparseLinear param-plan memo (serving-path cache)."""
    with _PARAM_PLANS_LOCK:
        return {"entries": len(_PARAM_PLANS)}


def warm_plans_from_params(params, dtype=jnp.float32) -> int:
    """Pre-stage SpMM plans for every SparseLinear subtree in ``params``.

    Walks the parameter tree for the RgCSR layout signature
    (``values2d``/``columns2d``/``chunk_group``/``chunk_first``) and builds
    each layer's plan once so the first *eager* per-layer call pays no
    host-side plan work.  Scope limits, by construction:

    * the jit'd prefill/decode path assembles plan containers at trace time
      (free) and never consults this memo — warming helps eager paths only;
    * layer-stacked (3-D) sparse params are skipped — the stacked scan path
      only ever sees traced slices;
    * ``d_in``/``d_out`` are inferred from the buffers (max column + 1,
      ``n_groups·G``); an eager caller passing different exact dims simply
      misses this entry and builds its own (dims are part of the memo key —
      a misinferred warm entry can never shadow correct dims).

    Returns #plans warmed.
    """
    warmed = 0

    def visit(node) -> None:
        nonlocal warmed
        if not isinstance(node, dict):
            return
        if {"values2d", "columns2d", "chunk_group", "chunk_first"} <= set(node):
            if getattr(node["values2d"], "ndim", 0) == 2:
                g = int(node["columns2d"].shape[1])
                n_groups = int(np.asarray(node["chunk_group"])[-1]) + 1 \
                    if node["chunk_group"].shape[0] else 1
                d_in = int(np.asarray(node["columns2d"]).max()) + 1
                plan_from_params(node, dtype, d_out=n_groups * g,
                                 d_in=d_in, group_size=g)
                warmed += 1
            return
        for v in node.values():
            visit(v)

    visit(params)
    return warmed


# ---------------------------------------------------------------------------
# ELLPACK
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EllPlan:
    values2d: Any   # (K_pad, N_pad)
    columns2d: Any  # (K_pad, N_pad)
    n_rows: int
    n_cols: int


def make_ell_plan(m: ELLPACK) -> EllPlan:
    vals = np.asarray(m.values)
    cols = np.asarray(m.columns).astype(np.int32)
    k, n = vals.shape
    k_pad, n_pad = _pad_to(k, SUBLANES), _pad_to(n, LANES)
    vp = np.zeros((k_pad, n_pad), vals.dtype)
    cp = np.zeros((k_pad, n_pad), np.int32)
    vp[:k, :n] = vals
    cp[:k, :n] = cols
    return EllPlan(values2d=jnp.asarray(vp), columns2d=jnp.asarray(cp),
                   n_rows=m.shape[0], n_cols=m.shape[1])


def ell_spmv(plan: EllPlan, x, *, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    n_pad = _pad_to(max(plan.n_cols, 1), LANES)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = ell_spmv_pallas(plan.values2d, plan.columns2d, x_pad,
                        interpret=interpret)
    return y[0, : plan.n_rows]
