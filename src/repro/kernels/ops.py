"""Jit'd public wrappers around the Pallas kernels + the plan/cache layer.

``RgCSRPlan`` is the device-resident execution plan built once per
(matrix, kernel config) — the analogue of a real framework's format-compile
step: the flat grouped storage reshaped into the ``(S, G)`` slot-major tile
the kernel consumes, plus the **step table** that drives the data-dependent
grid.  With ``chunks_per_step > 1`` every group's slot count is padded up to
a multiple of ``8·chunks_per_step`` so one grid step covers several 8-slot
chunks of the same group (DESIGN.md §3); the padding is exact zeros with
ghost column index 0, i.e. masked at plan time.

``PlanCache`` is the process-wide memo: SpMV-heavy paths (core dispatch, the
serving engine, the benchmark harness) fetch plans through ``get_plan``
instead of rebuilding host-side layouts per call.  Entries are keyed on
matrix identity + config and evicted when the matrix is garbage-collected.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python with identical semantics; on a real TPU pass
``interpret=False`` (the default resolves via ``jax.default_backend()``).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
import weakref
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import ELLPACK, RgCSR, ShardedRgCSR
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.rgcsr_spmm import rgcsr_spmm_pallas
from repro.kernels.rgcsr_spmv import (CHUNKS_PER_STEP_CHOICES, LANES,
                                      SUBLANES, rgcsr_spmv_pallas)

__all__ = ["RgCSRPlan", "make_plan", "rgcsr_spmv", "rgcsr_spmm",
           "EllPlan", "make_ell_plan", "ell_spmv", "default_interpret",
           "PlanCache", "PLAN_CACHE", "get_plan",
           "ShardedRgCSRPlan", "make_sharded_plan", "get_sharded_plan",
           "sharded_rgcsr_spmv", "sharded_rgcsr_spmm",
           "sharded_plan_cache_stats",
           "plan_from_params", "warm_plans_from_params",
           "DEFAULT_X_TILE_ELEMS"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


# x elements staged into VMEM per SpMV grid step before column tiling kicks
# in.  2^21 fp32 = 8 MiB — half the ~16 MiB/core VMEM, leaving room for the
# (R, G) matrix tiles and the (1, G) accumulator.  Matrices at or below this
# width keep the seed kernel's single unmasked whole-x stage; only wider
# ones pay the masked multi-tile path.
DEFAULT_X_TILE_ELEMS = 1 << 21


@dataclasses.dataclass(frozen=True)
class RgCSRPlan:
    """Kernel-ready layout for one RgCSR matrix at one kernel config.

    ``step_group``/``step_first`` form the coarsened step table: grid step
    ``s`` covers slot rows ``[R·s, R·(s+1))`` of ``values2d``/``columns2d``
    (``R = 8·chunks_per_step``) and belongs to group ``step_group[s]``.

    **Adaptive plans** (``ordering='adaptive'``, DESIGN.md §5): groups hold
    length-sorted rows instead of consecutive ones, so the kernel's output
    lives in the *permuted* row space.  ``gather_idx``/``grouped_mask`` are
    the fused inverse-permutation map back to original rows, and rows longer
    than ``spill_threshold`` live in the COO tail (``spill_*``), combined
    with a segment-sum in the epilogue.  Block plans leave these ``None``.
    """

    values2d: Any       # (S, G)
    columns2d: Any      # (S, G) int32
    step_group: Any     # (num_steps,) int32
    step_first: Any     # (num_steps,) int32
    n_rows: int
    n_cols: int
    n_groups: int
    group_size: int
    chunks_per_step: int = 1
    # --- adaptive grouping (None/defaults on block plans) ---
    ordering: str = "block"        # "block" | "adaptive"
    spill_threshold: int = 0       # 0 = no spill
    nnz: int = -1                  # true nonzeros incl. spill (-1 = unknown)
    gather_idx: Any = None         # (n_rows,) int32: flat kernel-output index
    grouped_mask: Any = None       # (n_rows,) bool: False = row is spilled
    spill_values: Any = None       # (nnz_spill,)
    spill_rows: Any = None         # (nnz_spill,) int32 original row ids
    spill_columns: Any = None      # (nnz_spill,) int32

    @property
    def num_steps(self) -> int:
        """Grid steps the SpMV kernel launches (per x tile)."""
        return int(self.step_group.shape[0])

    @property
    def num_chunks(self) -> int:
        """8-slot chunks covered (= num_steps · chunks_per_step)."""
        return self.num_steps * self.chunks_per_step

    @property
    def stored_slots(self) -> int:
        return int(self.values2d.shape[0])

    @property
    def n_spilled_elements(self) -> int:
        return 0 if self.spill_values is None else int(
            self.spill_values.shape[0])

    @property
    def stored_elements(self) -> int:
        """Grouped slots × lanes + COO tail (the format's byte footprint)."""
        return self.stored_slots * self.group_size + self.n_spilled_elements

    @property
    def padded_slot_fraction(self) -> float:
        """Fraction of stored elements that are padding (artificial zeros).

        The paper's fill-ratio metric normalized to stored bytes: on a
        memory-bound op this is directly the fraction of wasted HBM traffic.
        Requires ``nnz`` (set by ``make_plan``; -1 on raw param-view plans).
        """
        if self.nnz < 0 or self.stored_elements == 0:
            return 0.0
        return (self.stored_elements - self.nnz) / self.stored_elements


def make_plan(m: RgCSR, *, chunks_per_step: int = 1,
              ordering: str = "block",
              spill_threshold: int = 0) -> RgCSRPlan:
    """Host-side plan construction (format-compile).

    ``chunks_per_step`` coarsens the grid: each group's ``(K_g, G)`` tile is
    re-padded so ``K_g`` is a multiple of ``8·chunks_per_step`` and one grid
    step consumes the whole coarsened sub-tile.  The extra padding rows are
    exact zeros (ghost column 0), so in-kernel accumulation over them is a
    masked no-op — the paper's artificial-zeros accounting extended to the
    coarsened tile.  The trade (fewer grid steps vs more padded bytes) is
    what :mod:`repro.kernels.autotune` measures per matrix.

    ``ordering='adaptive'`` (DESIGN.md §5) regroups rows by descending
    length so same-length rows share groups (each group's slot count is its
    own max, not the max over an arbitrary consecutive window), and rows
    longer than ``spill_threshold`` (> 0) leave the grouped storage for a
    COO tail.  The kernel then computes in the permuted row space; the
    SpMV/SpMM wrappers fuse the inverse gather + tail back in.
    """
    if m.group_size % LANES != 0:
        raise ValueError(
            f"TPU plan needs group_size % {LANES} == 0, got {m.group_size} "
            f"(use group_size=128/256/512; smaller groups are modeled, not run "
            f"— DESIGN.md §2)")
    if m.slot_pad % SUBLANES != 0:
        raise ValueError(f"slot_pad must be a multiple of {SUBLANES}")
    if chunks_per_step not in CHUNKS_PER_STEP_CHOICES:
        raise ValueError(
            f"chunks_per_step must be one of {CHUNKS_PER_STEP_CHOICES}, "
            f"got {chunks_per_step}")
    if ordering not in ("block", "adaptive"):
        raise ValueError(
            f"ordering must be 'block' or 'adaptive', got {ordering!r}")
    if ordering == "adaptive":
        return _make_adaptive_plan(m, chunks_per_step=chunks_per_step,
                                   spill_threshold=int(spill_threshold))
    if spill_threshold:
        raise ValueError(
            "spill_threshold requires ordering='adaptive' (block grouping "
            "cannot drop rows without a permutation gather)")
    g = m.group_size
    rows_per_step = chunks_per_step * SUBLANES
    slots = np.asarray(m.slots_per_group)
    n_groups = len(slots)
    total_slots = int(slots.sum())
    values2d = np.asarray(m.values).reshape(total_slots, g)
    columns2d = np.asarray(m.columns).reshape(total_slots, g).astype(np.int32)

    padded = (-(-slots // rows_per_step) * rows_per_step).astype(np.int64)
    if int(padded.sum()) != total_slots:
        # re-pad each group's tile up to the coarsened step granularity
        src_off = np.concatenate([[0], np.cumsum(slots)[:-1]])
        dst_off = np.concatenate([[0], np.cumsum(padded)[:-1]])
        vp = np.zeros((int(padded.sum()), g), values2d.dtype)
        cp = np.zeros((int(padded.sum()), g), np.int32)
        for gi in range(n_groups):
            k = int(slots[gi])
            vp[dst_off[gi]: dst_off[gi] + k] = values2d[src_off[gi]: src_off[gi] + k]
            cp[dst_off[gi]: dst_off[gi] + k] = columns2d[src_off[gi]: src_off[gi] + k]
        values2d, columns2d = vp, cp

    step_group, step_first = _step_table(padded, rows_per_step)
    return RgCSRPlan(
        values2d=jnp.asarray(values2d),
        columns2d=jnp.asarray(columns2d),
        step_group=jnp.asarray(step_group),
        step_first=jnp.asarray(step_first),
        n_rows=m.shape[0],
        n_cols=m.shape[1],
        n_groups=m.n_groups,
        group_size=g,
        chunks_per_step=chunks_per_step,
        nnz=m.nnz,
    )


def _step_table(padded_slots: np.ndarray, rows_per_step: int):
    """(step_group, step_first) for per-group padded slot counts."""
    steps_per_group = (padded_slots // rows_per_step).astype(np.int64)
    n_groups = len(steps_per_group)
    step_group = np.repeat(np.arange(n_groups, dtype=np.int32),
                           steps_per_group)
    first_idx = np.cumsum(np.concatenate([[0], steps_per_group[:-1]]))
    step_first = np.zeros(len(step_group), dtype=np.int32)
    step_first[first_idx] = 1
    return step_group, step_first


def _make_adaptive_plan(m: RgCSR, *, chunks_per_step: int,
                        spill_threshold: int) -> RgCSRPlan:
    """Length-aware regrouping + pathological-row spill (DESIGN.md §5).

    1. rows with nnz > ``spill_threshold`` (if > 0) leave for the COO tail;
    2. remaining rows are permuted by descending length (stable), so each
       group of ``G`` rows has near-uniform lengths and its slot count
       ``K_g = roundup(max len in group, 8·chunks_per_step)`` carries
       minimal padding under the alignment constraint;
    3. the kernel output is in permuted space — ``gather_idx`` maps original
       row ``r`` to its flat output lane, ``grouped_mask`` marks spilled
       rows (their value comes from the tail's segment-sum alone).
    """
    from repro.core.ordering import descending_from_lengths, split_spill_rows

    g = m.group_size
    rows_per_step = chunks_per_step * SUBLANES
    n_rows, n_cols = m.shape
    row_lens = np.asarray(m.row_lengths).astype(np.int64)
    csr_v, csr_c, row_ptr = m.to_csr_arrays()

    grouped_rows, spilled_rows = split_spill_rows(row_lens, spill_threshold)
    order = descending_from_lengths(row_lens[grouped_rows])
    perm = grouped_rows[order]                 # position p holds row perm[p]
    n_grouped = len(perm)
    n_groups = max(1, -(-n_grouped // g))

    # per-group slot counts: own max length, aligned to the step granularity
    slots = np.empty(n_groups, dtype=np.int64)
    for gi in range(n_groups):
        rows_g = perm[gi * g: (gi + 1) * g]
        k_g = int(row_lens[rows_g].max()) if len(rows_g) else 0
        slots[gi] = -(-max(k_g, 1) // rows_per_step) * rows_per_step
    offsets = np.concatenate([[0], np.cumsum(slots)[:-1]])

    values2d = np.zeros((int(slots.sum()), g), np.asarray(m.values).dtype)
    columns2d = np.zeros((int(slots.sum()), g), np.int32)
    for p in range(n_grouped):
        r = int(perm[p])
        gi, lane = p // g, p % g
        lo, hi = int(row_ptr[r]), int(row_ptr[r + 1])
        base = int(offsets[gi])
        values2d[base: base + (hi - lo), lane] = csr_v[lo:hi]
        columns2d[base: base + (hi - lo), lane] = csr_c[lo:hi]

    step_group, step_first = _step_table(slots, rows_per_step)

    gather_idx = np.zeros(n_rows, np.int32)
    grouped_mask = np.zeros(n_rows, bool)
    gather_idx[perm] = np.arange(n_grouped, dtype=np.int32)
    grouped_mask[perm] = True

    spill_sel = np.zeros(len(csr_v), bool)
    for r in spilled_rows:
        spill_sel[int(row_ptr[r]): int(row_ptr[r + 1])] = True
    spill_row_ids = np.repeat(
        spilled_rows.astype(np.int32),
        (row_ptr[spilled_rows + 1] - row_ptr[spilled_rows]).astype(np.int64)
        if len(spilled_rows) else np.empty(0, np.int64))

    return RgCSRPlan(
        values2d=jnp.asarray(values2d),
        columns2d=jnp.asarray(columns2d),
        step_group=jnp.asarray(step_group),
        step_first=jnp.asarray(step_first),
        n_rows=n_rows,
        n_cols=n_cols,
        n_groups=n_groups,
        group_size=g,
        chunks_per_step=chunks_per_step,
        ordering="adaptive",
        spill_threshold=spill_threshold,
        nnz=m.nnz,
        gather_idx=jnp.asarray(gather_idx),
        grouped_mask=jnp.asarray(grouped_mask),
        spill_values=jnp.asarray(csr_v[spill_sel]),
        spill_rows=jnp.asarray(spill_row_ids),
        spill_columns=jnp.asarray(csr_c[spill_sel].astype(np.int32)),
    )


# ---------------------------------------------------------------------------
# PlanCache — process-wide memo of (matrix identity, config) -> RgCSRPlan
# ---------------------------------------------------------------------------


class PlanCache:
    """LRU plan cache keyed on matrix identity + kernel config.

    Keys use ``id(matrix)`` plus every plan-shaping config field —
    ``(chunks_per_step, ordering, spill_threshold)`` — so a block plan and
    an adaptive plan of the same matrix (or two adaptive plans at different
    spill thresholds) can never shadow each other.  A ``weakref.finalize``
    hook evicts every config of a matrix when it is garbage-collected
    (CPython runs the finalizer during deallocation, before the id can be
    reused).  Thread-safe; plan *construction* happens outside the lock so
    concurrent misses on different matrices don't serialize.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._plans: "collections.OrderedDict[tuple, RgCSRPlan]" = \
            collections.OrderedDict()
        self._finalized: set = set()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def get(self, m: RgCSR, *, chunks_per_step: int = 1,
            ordering: str = "block", spill_threshold: int = 0) -> RgCSRPlan:
        key = (id(m), chunks_per_step, ordering, int(spill_threshold))
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
        plan = make_plan(m, chunks_per_step=chunks_per_step,
                         ordering=ordering, spill_threshold=spill_threshold)
        with self._lock:
            if key not in self._plans:
                self.misses += 1
                self._plans[key] = plan
                if id(m) not in self._finalized:
                    self._finalized.add(id(m))
                    weakref.finalize(m, self._evict, id(m))
                while len(self._plans) > self.maxsize:
                    self._plans.popitem(last=False)
            else:
                self.hits += 1
                plan = self._plans[key]
        return plan

    def _evict(self, mid: int) -> None:
        with self._lock:
            self._finalized.discard(mid)
            for key in [k for k in self._plans if k[0] == mid]:
                del self._plans[key]

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._finalized.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._plans)}

    def __len__(self) -> int:
        return len(self._plans)


PLAN_CACHE = PlanCache()


def get_plan(m: RgCSR, *, chunks_per_step: int = 1, ordering: str = "block",
             spill_threshold: int = 0) -> RgCSRPlan:
    """Fetch (or build and memoize) the kernel plan for ``m``."""
    return PLAN_CACHE.get(m, chunks_per_step=chunks_per_step,
                          ordering=ordering, spill_threshold=spill_threshold)


# ---------------------------------------------------------------------------
# SpMV / SpMM wrappers
# ---------------------------------------------------------------------------


def _x_tile_for(n_pad_min: int, x_tile: Optional[int]) -> Tuple[int, int]:
    """Resolve the x column-tile width and the final padded x length."""
    if x_tile is None:
        if n_pad_min <= DEFAULT_X_TILE_ELEMS:
            return n_pad_min, n_pad_min          # single tile — seed behaviour
        x_tile = DEFAULT_X_TILE_ELEMS
    x_tile = _pad_to(x_tile, LANES)
    return x_tile, _pad_to(n_pad_min, x_tile)


@functools.partial(jax.jit, static_argnames=("n_rows", "has_spill"))
def _adaptive_finish_spmv(y_flat, x, gather_idx, grouped_mask,
                          spill_values, spill_rows, spill_columns,
                          *, n_rows: int, has_spill: bool):
    """Fused adaptive epilogue: inverse-permutation gather + COO tail.

    One jit region, no materialized scatter: original row ``r`` reads lane
    ``gather_idx[r]`` of the permuted kernel output (spilled rows masked to
    zero) and the pathological rows come back as a segment-sum over the COO
    tail — both fuse into a single gather/scatter pass over HBM.
    """
    out = jnp.where(grouped_mask, jnp.take(y_flat, gather_idx, axis=0),
                    jnp.zeros((), y_flat.dtype))
    if has_spill:
        prods = spill_values * jnp.take(x, spill_columns, axis=0)
        out = out + jax.ops.segment_sum(prods, spill_rows,
                                        num_segments=n_rows)
    return out


@functools.partial(jax.jit, static_argnames=("n_rows", "has_spill"))
def _adaptive_finish_spmm(y2d, x, gather_idx, grouped_mask,
                          spill_values, spill_rows, spill_columns,
                          *, n_rows: int, has_spill: bool):
    """SpMM twin of :func:`_adaptive_finish_spmv` (row gather over axis 0)."""
    out = jnp.where(grouped_mask[:, None],
                    jnp.take(y2d, gather_idx, axis=0),
                    jnp.zeros((), y2d.dtype))[:, : x.shape[1]]
    if has_spill:
        prods = jnp.take(x, spill_columns, axis=0) * spill_values[:, None]
        out = out + jax.ops.segment_sum(prods, spill_rows,
                                        num_segments=n_rows)
    return out


def rgcsr_spmv(plan: RgCSRPlan, x, *, interpret: bool | None = None,
               x_tile: int | None = None):
    """y = A @ x via the Pallas kernel. x: (n_cols,) -> y: (n_rows,).

    ``x_tile`` bounds the x slice staged into VMEM per grid step; ``None``
    stages x whole when it fits (``DEFAULT_X_TILE_ELEMS``) and tiles it
    otherwise, so wide matrices degrade smoothly instead of exhausting VMEM.

    Adaptive plans return through the fused epilogue (inverse gather +
    spill segment-sum); block plans slice the contiguous rows as before.
    """
    if interpret is None:
        interpret = default_interpret()
    n_pad_min = _pad_to(max(plan.n_cols, 1), LANES)
    xt, n_pad = _x_tile_for(n_pad_min, x_tile)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = rgcsr_spmv_pallas(
        plan.step_group, plan.step_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        chunks_per_step=plan.chunks_per_step, x_tile=xt,
        interpret=interpret)
    y_flat = y.reshape(-1)
    if plan.ordering != "adaptive":
        return y_flat[: plan.n_rows]
    return _adaptive_finish_spmv(
        y_flat, jnp.asarray(x), plan.gather_idx, plan.grouped_mask,
        plan.spill_values, plan.spill_rows, plan.spill_columns,
        n_rows=plan.n_rows, has_spill=plan.n_spilled_elements > 0)


def rgcsr_spmm(plan: RgCSRPlan, x, *, d_tile: int = LANES,
               interpret: bool | None = None):
    """Y = A @ X via the Pallas kernel. X: (n_cols, d) -> Y: (n_rows, d)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = x.shape
    n_pad = _pad_to(max(n, 1), SUBLANES)
    d_pad = _pad_to(max(d, 1), d_tile)
    x_pad = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    y = rgcsr_spmm_pallas(
        plan.step_group, plan.step_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        d_tile=d_tile, chunks_per_step=plan.chunks_per_step,
        interpret=interpret)
    if plan.ordering != "adaptive":
        return y[: plan.n_rows, :d]
    return _adaptive_finish_spmm(
        y, jnp.asarray(x), plan.gather_idx, plan.grouped_mask,
        plan.spill_values, plan.spill_rows, plan.spill_columns,
        n_rows=plan.n_rows, has_spill=plan.n_spilled_elements > 0)


# ---------------------------------------------------------------------------
# Row-sharded multi-device SpMV/SpMM (DESIGN.md §11)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedRgCSRPlan:
    """Stacked, device-major execution plan for a :class:`ShardedRgCSR`.

    Each shard's :class:`RgCSRPlan` (built by the unchanged ``make_plan`` —
    block or adaptive grouping applies *per shard*, at that shard's own
    tuned ``(chunks_per_step, ordering, spill_threshold)`` from
    ``shard_configs``) is padded to the across-shard maxima and stacked on
    a leading device axis, which is what ``shard_map`` needs: one SPMD
    program, per-device slices of uniform shape.  Padding rows are exact
    zeros; padding *steps* point at the shard's own last real group with
    ``step_first = 0``, so they accumulate zeros into an already-initialized
    output block (the Pallas revisit rule stays satisfied: padded steps
    extend the last group's consecutive run).  Because the SPMD kernel has
    one static ``chunks_per_step``, per-shard winners are reconciled at the
    table level: each shard's layout is padded at its *own* winner
    granularity and its step table is expanded to the common kernel
    ``chunks_per_step`` (the gcd of the winners — DESIGN.md §12).

    ``x_mode`` fixes how the dense vector is reconciled (arXiv:1112.5588's
    local/remote split):

    * ``'replicated'`` — x is replicated; columns keep global indices.
      Zero communication, D× x memory: the fast path while x fits.
    * ``'split'`` — x is row-sharded over the same axis
      (``cols_per_shard`` entries per device) and the exchange is a
      plan-driven **sparse collective** (DESIGN.md §12): grouped storage
      holds only the shard's *local*-column entries (columns remapped into
      ``[0, cols_per_shard)``), each shard's *remote* entries live in a COO
      remote tail (``rem_*``) indexed into the exchange receive buffer, and
      ``send_idx``/``edge_counts`` form the per-(src, dst) send schedule —
      padded to the static per-edge max ``e_max`` for jittability — that
      the run path executes as one ``all_to_all`` of only the remote x
      entries.  The kernel reads only the local slice, so the exchange
      overlaps the local-partial launch, and per-device exchange volume is
      exactly that shard's plan-time remote column count.
    """

    values3d: Any        # (D, S_pad, G)
    columns3d: Any       # (D, S_pad, G) int32 (global; local-only in split)
    step_group2d: Any    # (D, T_max) int32
    step_first2d: Any    # (D, T_max) int32
    n_rows: int
    n_cols: int
    n_shards: int
    rows_per_shard: int
    cols_per_shard: int          # x entries owned per device (split mode)
    n_groups: int                # max over shards (uniform kernel out shape)
    group_size: int
    chunks_per_step: int = 1     # kernel cps (gcd of per-shard winners)
    ordering: str = "block"      # 'adaptive' when ANY shard is adaptive
    spill_threshold: int = 0     # the broadcast arg only — per-shard truth
    #                              (incl. tuned thresholds) is shard_configs
    x_mode: str = "replicated"
    nnz: int = -1
    # per-shard (chunks_per_step, ordering, spill_threshold) actually built
    shard_configs: Tuple[Tuple[int, str, int], ...] = ()
    remote_cols: Any = None      # (D, R_max) int32 (split: plan-time sets)
    # --- sparse-exchange schedule (split mode with a non-empty exchange) ---
    send_idx: Any = None         # (D_src, D_dst, e_max) int32 local col idx
    edge_counts: Any = None      # (D_src, D_dst) int64 true edge sizes (host)
    e_max: int = 0               # static per-edge pad (0 = no exchange)
    rem_values: Any = None       # (D, E_t) remote-entry COO tail values
    rem_rows: Any = None         # (D, E_t) int32 local row ids
    rem_xidx: Any = None         # (D, E_t) int32 index into recv buffer
    gather_idx: Any = None       # (D, rows_per_shard) int32 (adaptive)
    grouped_mask: Any = None     # (D, rows_per_shard) bool (adaptive)
    spill_values: Any = None     # (D, E_max) (adaptive + spill)
    spill_rows: Any = None       # (D, E_max) int32 local row ids
    spill_columns: Any = None    # (D, E_max) int32 (local in split mode)
    # true per-shard figures, pre-stacking (the ~1/D acceptance numbers)
    shard_stored_slots: Tuple[int, ...] = ()
    shard_num_steps: Tuple[int, ...] = ()
    shard_remote_cols: Tuple[int, ...] = ()
    shard_remote_entries: Tuple[int, ...] = ()   # rem-tail nnz per shard
    shard_spill_counts: Tuple[int, ...] = ()     # spill-tail nnz per shard

    @property
    def num_steps_max(self) -> int:
        return int(self.step_group2d.shape[1])

    @property
    def stored_slots_max(self) -> int:
        """Per-device stored slot rows after stacking (= max over shards)."""
        return int(self.values3d.shape[1])

    @property
    def n_spilled_max(self) -> int:
        return 0 if self.spill_values is None else int(
            self.spill_values.shape[1])

    @property
    def stored_elements(self) -> int:
        """True (unstacked) grouped slots × lanes + COO tails, all shards —
        including split mode's remote exchange tails, which store one entry
        per remote nonzero (they are part of the format's footprint, and
        without them a mostly-remote matrix would show stored < nnz)."""
        spilled = sum(self.shard_spilled_elements)
        return (sum(self.shard_stored_slots) * self.group_size + spilled
                + sum(self.shard_remote_entries))

    @property
    def shard_spilled_elements(self) -> Tuple[int, ...]:
        """True spill-tail entries per shard — positional (recorded at
        build), never inferred from values: a stored spill value may
        legitimately be 0.0 (same rule as ``RgCSR.to_csr_arrays``)."""
        if self.spill_values is None:
            return (0,) * self.n_shards
        return self.shard_spill_counts or (0,) * self.n_shards

    @property
    def padded_slot_fraction(self) -> float:
        if self.nnz < 0 or self.stored_elements == 0:
            return 0.0
        return (self.stored_elements - self.nnz) / self.stored_elements

    # ------------------------------------------------- exchange accounting
    @property
    def has_exchange(self) -> bool:
        """Whether the run path executes the sparse collective at all."""
        return self.x_mode == "split" and self.e_max > 0

    @property
    def shard_exchange_recv_cols(self) -> Tuple[int, ...]:
        """x entries device d *receives* per the plan schedule — equals
        ``shard_remote_cols[d]`` by construction (the tentpole bound)."""
        if self.edge_counts is None:
            return (0,) * self.n_shards
        ec = np.asarray(self.edge_counts)
        return tuple(int(ec[:, d].sum()) for d in range(self.n_shards))

    @property
    def shard_exchange_send_cols(self) -> Tuple[int, ...]:
        """x entries device d *sends* per the plan schedule."""
        if self.edge_counts is None:
            return (0,) * self.n_shards
        ec = np.asarray(self.edge_counts)
        return tuple(int(ec[d, :].sum()) for d in range(self.n_shards))

    @property
    def shard_exchange_bytes(self) -> Tuple[int, ...]:
        """Exchange volume per device in bytes (received x entries ×
        itemsize) — the number the all_gather path paid ``n_cols ×
        itemsize`` for regardless of the remote set size.  Itemsize is the
        stored-values dtype; a run-time x of a different width scales the
        wire bytes accordingly (the recv *counts* are the exact figures)."""
        itemsize = jnp.dtype(self.values3d.dtype).itemsize
        return tuple(c * itemsize for c in self.shard_exchange_recv_cols)

    @property
    def exchange_padded_recv_cols(self) -> int:
        """Static recv-buffer width (D·e_max) — the jittability pad; the
        collective moves this many slots, only ``recv_cols`` are real."""
        return self.n_shards * self.e_max


def _normalize_shard_configs(shard_configs, n_shards: int,
                             chunks_per_step: int, ordering: str,
                             spill_threshold: int,
                             group_size: Optional[int] = None
                             ) -> Tuple[Tuple[int, str, int], ...]:
    """Per-shard (cps, ordering, spill) tuples; the global args broadcast
    when ``shard_configs`` is None.  Accepts TuneConfig-likes, dicts, or
    bare 3-tuples so tuner winners thread through without conversion.
    A config that *carries* a group size (TuneConfig/dict) must match the
    matrix's — winners measured at a different G would silently mis-tune
    the plan otherwise."""
    if shard_configs is None:
        return ((int(chunks_per_step), str(ordering),
                 int(spill_threshold)),) * n_shards
    norm = []
    for c in shard_configs:
        cfg_g = None
        if hasattr(c, "chunks_per_step"):          # autotune.TuneConfig
            cps, o, t = c.chunks_per_step, c.ordering, c.spill_threshold
            cfg_g = getattr(c, "group_size", None)
        elif isinstance(c, dict):
            # missing keys inherit the caller's broadcast globals, never
            # silently reset to the defaults
            cps = c.get("chunks_per_step", chunks_per_step)
            o = c.get("ordering", ordering)
            t = c.get("spill_threshold", spill_threshold)
            cfg_g = c.get("group_size")
        else:
            cps, o, t = c
        if group_size is not None and cfg_g is not None \
                and int(cfg_g) != int(group_size):
            raise ValueError(
                f"shard config tuned at group_size={cfg_g} cannot build a "
                f"plan for a group_size={group_size} matrix — re-tune at "
                f"the matrix's group size")
        norm.append((int(cps), str(o), int(t)))
    if len(norm) != n_shards:
        raise ValueError(f"shard_configs has {len(norm)} entries for "
                         f"{n_shards} shards")
    return tuple(norm)


def _exchange_schedule(remotes, cstride: int, d_sh: int):
    """Per-(src, dst) send schedule from the per-dst remote column sets.

    Edge (s → d) holds dst d's remote columns owned by src s, in sorted
    order; every edge is padded to the static across-edge max ``e_max`` so
    the run-time ``all_to_all`` buffer shape is jittable.  Returns
    ``(send_idx (D, D, e_max) local col offsets at the src,
    edge_counts (D, D) true sizes, e_max, xidx_lut)`` where ``xidx_lut[d]``
    maps a global remote column to its slot ``src·e_max + pos`` in dst d's
    flattened receive buffer.
    """
    edge_cols = [[None] * d_sh for _ in range(d_sh)]
    counts = np.zeros((d_sh, d_sh), np.int64)
    for dst, remote in enumerate(remotes):
        owner = remote // cstride
        for s in range(d_sh):
            ec = remote[owner == s]
            edge_cols[s][dst] = ec
            counts[s, dst] = len(ec)
    e_max = int(counts.max()) if counts.size else 0
    send_idx = np.zeros((d_sh, d_sh, e_max), np.int32)
    xidx_lut = []
    for dst in range(d_sh):
        lut = np.zeros(max(cstride * d_sh, 1), np.int32)
        for s in range(d_sh):
            ec = edge_cols[s][dst]
            send_idx[s, dst, : len(ec)] = ec - s * cstride
            lut[ec] = s * e_max + np.arange(len(ec), dtype=np.int32)
        xidx_lut.append(lut)
    return send_idx, counts, e_max, xidx_lut


def make_sharded_plan(sm: ShardedRgCSR, *, chunks_per_step: int = 1,
                      ordering: str = "block", spill_threshold: int = 0,
                      x_mode: str = "replicated",
                      shard_configs=None) -> ShardedRgCSRPlan:
    """Build per-shard plans via :func:`make_plan`, then pad + stack them.

    Reuses the whole single-device plan machinery per shard — the adaptive
    length-aware permutation, per-group slot sizing, and COO spill are each
    computed inside a shard's own row block.  ``shard_configs`` (one
    ``(chunks_per_step, ordering, spill_threshold)`` per shard, e.g. the
    per-shard autotune winners) lets each shard keep its own schedule: the
    grouped layout is padded at the shard's own winner granularity and its
    step table is expanded to the common kernel ``chunks_per_step`` (the
    gcd of the winners) so one SPMD program still runs everywhere.

    In ``x_mode='split'`` the grouped storage keeps only each shard's
    **local**-column entries (columns remapped into ``[0, cols_per_shard)``
    — exactly the shard's own slice of x, so the kernel never waits on the
    exchange); remote entries move to the ``rem_*`` COO tail indexed into
    the receive buffer of the plan-time ``send_idx`` exchange schedule.
    """
    if x_mode not in ("replicated", "split"):
        raise ValueError(
            f"x_mode must be 'replicated' or 'split', got {x_mode!r}")
    d_sh = sm.n_shards
    n_rows, n_cols = sm.shape
    g = sm.group_size
    cfgs = _normalize_shard_configs(shard_configs, d_sh, chunks_per_step,
                                    ordering, spill_threshold,
                                    group_size=g)
    for cps_d, o_d, _ in cfgs:
        if cps_d not in CHUNKS_PER_STEP_CHOICES:
            raise ValueError(
                f"chunks_per_step must be one of {CHUNKS_PER_STEP_CHOICES}, "
                f"got {cps_d}")
        if o_d not in ("block", "adaptive"):
            raise ValueError(f"ordering must be 'block' or 'adaptive', "
                             f"got {o_d!r}")
    # the SPMD kernel has one static cps; per-shard winners keep their own
    # padding granularity and expand their step tables down to the gcd
    # (powers of two, so gcd == min)
    kernel_cps = min(c[0] for c in cfgs)
    rows_per_step = kernel_cps * SUBLANES
    any_adaptive = any(c[1] == "adaptive" for c in cfgs)
    _, cstride = ShardedRgCSR.shard_layout(n_rows, n_cols, d_sh)

    # split mode: local/remote entry split + per-(src,dst) exchange schedule
    remotes = []
    rem_tails = []                      # (values, rows, global cols) per dst
    if x_mode == "split":
        sources = []
        for d, shard in enumerate(sm.shards):
            lo, hi = d * cstride, min((d + 1) * cstride, n_cols)
            # CSR-based split: only the (rps, cols_per_shard) local block is
            # ever densified (for RgCSR.from_dense); the remote entries stay
            # as index triplets — no full-width densification
            csr_v, csr_c, row_ptr = shard.to_csr_arrays()
            csr_r = np.repeat(np.arange(sm.rows_per_shard, dtype=np.int32),
                              np.diff(row_ptr))
            is_local = (csr_c >= lo) & (csr_c < hi)
            local = np.zeros((sm.rows_per_shard, cstride), csr_v.dtype)
            local[csr_r[is_local], csr_c[is_local] - lo] = csr_v[is_local]
            sources.append(RgCSR.from_dense(local, group_size=g,
                                            slot_pad=sm.slot_pad))
            rc = csr_c[~is_local].astype(np.int64)
            remotes.append(np.unique(rc))
            rem_tails.append((csr_v[~is_local], csr_r[~is_local], rc))
        send_idx, edge_counts, e_max, xidx_lut = _exchange_schedule(
            remotes, cstride, d_sh)
        e_tail = max(len(v) for v, _, _ in rem_tails)
        r_max = max(len(r) for r in remotes)
    else:
        sources = list(sm.shards)
        send_idx = edge_counts = None
        e_max = e_tail = r_max = 0

    plans = [make_plan(src, chunks_per_step=c[0], ordering=c[1],
                       spill_threshold=c[2])
             for src, c in zip(sources, cfgs)]
    # expand each shard's step table to the kernel cps: one coarse step of
    # cps_d chunks becomes cps_d/kernel_cps consecutive fine steps of the
    # same group (step_first only on the first — the revisit rule holds)
    tables = []
    for p, (cps_d, _, _) in zip(plans, cfgs):
        f = cps_d // kernel_cps
        sg = np.repeat(np.asarray(p.step_group), f)
        sf = np.zeros(len(sg), np.int32)
        if len(sg):
            sf[::f] = np.asarray(p.step_first)
        tables.append((sg, sf))
    n_groups = max(p.n_groups for p in plans)
    t_max = max(len(sg) for sg, _ in tables)
    s_pad = t_max * rows_per_step

    vals = np.zeros((d_sh, s_pad, g),
                    np.asarray(plans[0].values2d).dtype)
    cols = np.zeros((d_sh, s_pad, g), np.int32)
    sg2 = np.zeros((d_sh, t_max), np.int32)
    sf2 = np.zeros((d_sh, t_max), np.int32)
    remote_cols = np.zeros((d_sh, r_max), np.int32)
    rm_v = np.zeros((d_sh, e_tail), vals.dtype)
    rm_r = np.zeros((d_sh, e_tail), np.int32)
    rm_x = np.zeros((d_sh, e_tail), np.int32)
    sp_max = max(p.n_spilled_elements for p in plans) if any_adaptive else 0
    gidx = np.zeros((d_sh, sm.rows_per_shard), np.int32)
    gmask = np.zeros((d_sh, sm.rows_per_shard), bool)
    sp_v = np.zeros((d_sh, sp_max), vals.dtype)
    sp_r = np.zeros((d_sh, sp_max), np.int32)
    sp_c = np.zeros((d_sh, sp_max), np.int32)

    for d, p in enumerate(plans):
        s_d = p.stored_slots
        sg, sf = tables[d]
        t_d = len(sg)
        vals[d, :s_d] = np.asarray(p.values2d)
        cols[d, :s_d] = np.asarray(p.columns2d)
        sg2[d, :t_d] = sg
        # padding steps extend the shard's own last group (step_first = 0,
        # zero values): consecutive revisit of an initialized block
        sg2[d, t_d:] = int(sg[-1]) if t_d else 0
        sf2[d, :t_d] = sf
        if x_mode == "split":
            remote_cols[d, : len(remotes[d])] = remotes[d]
            rv, rr, rc = rem_tails[d]
            if len(rv):
                rm_v[d, : len(rv)] = rv
                rm_r[d, : len(rv)] = rr
                rm_x[d, : len(rv)] = xidx_lut[d][rc]
        if any_adaptive:
            if p.ordering == "adaptive":
                gidx[d] = np.asarray(p.gather_idx)
                gmask[d] = np.asarray(p.grouped_mask)
                e_d = p.n_spilled_elements
                if e_d:
                    sp_v[d, :e_d] = np.asarray(p.spill_values)
                    sp_r[d, :e_d] = np.asarray(p.spill_rows)
                    sp_c[d, :e_d] = np.asarray(p.spill_columns)
            else:
                # block shard inside a mixed stack: identity gather —
                # kernel output index of row r IS r for consecutive groups
                gidx[d] = np.arange(sm.rows_per_shard, dtype=np.int32)
                gmask[d] = True
    split = x_mode == "split"
    return ShardedRgCSRPlan(
        values3d=jnp.asarray(vals),
        columns3d=jnp.asarray(cols),
        step_group2d=jnp.asarray(sg2),
        step_first2d=jnp.asarray(sf2),
        n_rows=n_rows, n_cols=n_cols, n_shards=d_sh,
        rows_per_shard=sm.rows_per_shard, cols_per_shard=cstride,
        n_groups=n_groups, group_size=g, chunks_per_step=kernel_cps,
        ordering="adaptive" if any_adaptive else "block",
        spill_threshold=int(spill_threshold),
        x_mode=x_mode, nnz=sm.nnz, shard_configs=cfgs,
        # host numpy on purpose: the run path consumes send_idx/rem_* only;
        # remote_cols feeds host-side stats/tests — no device upload needed
        remote_cols=remote_cols if split else None,
        send_idx=jnp.asarray(send_idx) if split and e_max else None,
        edge_counts=edge_counts,
        e_max=e_max,
        rem_values=jnp.asarray(rm_v) if split and e_max else None,
        rem_rows=jnp.asarray(rm_r) if split and e_max else None,
        rem_xidx=jnp.asarray(rm_x) if split and e_max else None,
        gather_idx=jnp.asarray(gidx) if any_adaptive else None,
        grouped_mask=jnp.asarray(gmask) if any_adaptive else None,
        spill_values=jnp.asarray(sp_v) if any_adaptive else None,
        spill_rows=jnp.asarray(sp_r) if any_adaptive else None,
        spill_columns=jnp.asarray(sp_c) if any_adaptive else None,
        shard_stored_slots=tuple(p.stored_slots for p in plans),
        shard_num_steps=tuple(len(sg) for sg, _ in tables),
        shard_remote_cols=tuple(len(r) for r in remotes) if remotes
        else (0,) * d_sh,
        shard_remote_entries=tuple(len(v) for v, _, _ in rem_tails)
        if rem_tails else (0,) * d_sh,
        shard_spill_counts=tuple(p.n_spilled_elements for p in plans),
    )


# sharded plan memo: (id(matrix), shard count, x_mode, per-shard configs)
# -> plan, GC-evicted like PLAN_CACHE.  Keys carry the shard/device count
# explicitly (not just matrix identity) so re-warming on a resized mesh can
# never reuse a stale stacked plan, and the full per-shard config tuple so
# per-shard-tuned plans coexist with uniform ones; x_mode is keyed because
# split mode stores local-only column indices + the exchange schedule.
_SHARDED_PLANS: "collections.OrderedDict[tuple, ShardedRgCSRPlan]" = \
    collections.OrderedDict()
_SHARDED_PLANS_MAX = 64
_SHARDED_LOCK = threading.RLock()
_SHARDED_FINALIZED: set = set()
_SHARDED_STATS = {"hits": 0, "misses": 0}


def get_sharded_plan(sm: ShardedRgCSR, *, chunks_per_step: int = 1,
                     ordering: str = "block", spill_threshold: int = 0,
                     x_mode: str = "replicated",
                     shard_configs=None) -> ShardedRgCSRPlan:
    """Fetch (or build and memoize) the stacked sharded plan for ``sm``."""
    cfgs = _normalize_shard_configs(shard_configs, sm.n_shards,
                                    chunks_per_step, ordering,
                                    spill_threshold,
                                    group_size=sm.group_size)
    key = (id(sm), sm.n_shards, x_mode, cfgs)
    with _SHARDED_LOCK:
        plan = _SHARDED_PLANS.get(key)
        if plan is not None:
            _SHARDED_STATS["hits"] += 1
            _SHARDED_PLANS.move_to_end(key)
            return plan
    plan = make_sharded_plan(sm, chunks_per_step=chunks_per_step,
                             ordering=ordering,
                             spill_threshold=spill_threshold, x_mode=x_mode,
                             shard_configs=cfgs)
    with _SHARDED_LOCK:
        if key not in _SHARDED_PLANS:
            _SHARDED_STATS["misses"] += 1
            _SHARDED_PLANS[key] = plan
            if id(sm) not in _SHARDED_FINALIZED:
                _SHARDED_FINALIZED.add(id(sm))
                weakref.finalize(sm, _evict_sharded, id(sm))
            while len(_SHARDED_PLANS) > _SHARDED_PLANS_MAX:
                _SHARDED_PLANS.popitem(last=False)
        else:
            _SHARDED_STATS["hits"] += 1
            plan = _SHARDED_PLANS[key]
    return plan


def _evict_sharded(mid: int) -> None:
    with _SHARDED_LOCK:
        _SHARDED_FINALIZED.discard(mid)
        for key in [k for k in _SHARDED_PLANS if k[0] == mid]:
            del _SHARDED_PLANS[key]


def sharded_plan_cache_stats() -> Dict[str, int]:
    with _SHARDED_LOCK:
        return {"hits": _SHARDED_STATS["hits"],
                "misses": _SHARDED_STATS["misses"],
                "entries": len(_SHARDED_PLANS)}


# memo of jitted shard_map executables per (plan, mesh, axis, kind) — the
# shard_map wrapper must be a stable callable for jax's jit cache to hit
_SHARDED_EXEC: "collections.OrderedDict[tuple, Any]" = \
    collections.OrderedDict()
_SHARDED_EXEC_MAX = 32


def _sharded_args(plan: ShardedRgCSRPlan):
    """(args, per-arg PartitionSpec dim-count) in the inner-fn unpack order."""
    args = [plan.values3d, plan.columns3d, plan.step_group2d,
            plan.step_first2d]
    ndims = [3, 3, 2, 2]
    if plan.has_exchange:
        # send schedule is sharded on its *source* axis (each device gets
        # its own (D_dst, e_max) row); the remote tail on its dst axis
        args += [plan.send_idx, plan.rem_values, plan.rem_rows,
                 plan.rem_xidx]
        ndims += [3, 2, 2, 2]
    if plan.ordering == "adaptive":
        args += [plan.gather_idx, plan.grouped_mask]
        ndims += [2, 2]
        if plan.n_spilled_max > 0:
            args += [plan.spill_values, plan.spill_rows, plan.spill_columns]
            ndims += [2, 2, 2]
    return args, ndims


def _build_sharded_exec(plan: ShardedRgCSRPlan, kind: str, mesh, axis: str,
                        interpret: bool, d_tile: int):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    split = plan.x_mode == "split"
    exchange = plan.has_exchange
    adaptive = plan.ordering == "adaptive"
    has_spill = adaptive and plan.n_spilled_max > 0
    # hoist every plan attribute the body needs into scalars: the closure
    # must NOT reference `plan` itself, or the cached jitted fn would pin
    # the stacked device arrays and the plan-death exec eviction
    # (weakref.finalize below) could never fire before LRU turnover
    rps = plan.rows_per_shard
    recv_width = plan.n_shards * plan.e_max
    n_groups, group_size = plan.n_groups, plan.group_size
    kernel_cps = plan.chunks_per_step
    empty_v = jnp.zeros((0,), plan.values3d.dtype)
    empty_i = jnp.zeros((0,), jnp.int32)

    def per_shard(*a):
        it = iter(a)
        vals, cols = next(it)[0], next(it)[0]            # (S_pad, G)
        sg, sf = next(it)[0], next(it)[0]                # (T_max,)
        sidx = next(it)[0] if exchange else None         # (D, e_max)
        rm_v = next(it)[0] if exchange else empty_v      # (E_t,)
        rm_r = next(it)[0] if exchange else empty_i
        rm_x = next(it)[0] if exchange else empty_i
        gi = next(it)[0] if adaptive else None
        gm = next(it)[0] if adaptive else None
        sv = next(it)[0] if has_spill else empty_v
        sr = next(it)[0] if has_spill else empty_i
        sc = next(it)[0] if has_spill else empty_i
        x_in = next(it)
        recv_flat = None
        if exchange:
            # plan-driven sparse collective (DESIGN.md §12): move ONLY the
            # remote x entries — each device sends its (D, e_max) schedule
            # rows, one all_to_all delivers recv[s] = what src s sent us.
            # Issued before the kernel, which reads only x_in: the two are
            # dataflow-independent, so the scheduler can overlap the
            # exchange with the local-partial launch.
            send = jnp.take(x_in, sidx, axis=0)    # (D, e_max[, d])
            recv = jax.lax.all_to_all(send, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
            recv_flat = recv.reshape((recv_width,) + x_in.shape[1:])
        # split mode: grouped storage is local-column-only, so the kernel's
        # x working set is exactly this device's slice (cols_per_shard)
        x_use = x_in
        if kind == "spmv":
            n_eff = x_use.shape[0]
            # same VMEM-bounded column tiling as the single-device wrapper:
            # single tile while x fits, masked multi-tile beyond
            xt, n_pad = _x_tile_for(_pad_to(max(n_eff, 1), LANES), None)
            x_pad = jnp.zeros((1, n_pad), x_use.dtype).at[0, :n_eff].set(
                x_use)
            y = rgcsr_spmv_pallas(
                sg, sf, vals, cols, x_pad, n_groups=n_groups,
                group_size=group_size,
                chunks_per_step=kernel_cps, x_tile=xt,
                interpret=interpret)
            y_flat = y.reshape(-1)
            if adaptive:
                y_loc = _adaptive_finish_spmv(
                    y_flat, x_use, gi, gm, sv, sr, sc, n_rows=rps,
                    has_spill=has_spill)
            else:
                y_loc = y_flat[:rps]
            if recv_flat is None:
                return y_loc
            # remote contributions: COO tail over the received entries
            prods = rm_v * jnp.take(recv_flat, rm_x, axis=0)
            return y_loc + jax.ops.segment_sum(prods, rm_r,
                                               num_segments=rps)
        n_eff, d = x_use.shape
        n_pad = _pad_to(max(n_eff, 1), SUBLANES)
        d_pad = _pad_to(max(d, 1), d_tile)
        x_pad = jnp.zeros((n_pad, d_pad), x_use.dtype).at[
            :n_eff, :d].set(x_use)
        y = rgcsr_spmm_pallas(
            sg, sf, vals, cols, x_pad, n_groups=n_groups,
            group_size=group_size, d_tile=d_tile,
            chunks_per_step=kernel_cps, interpret=interpret)
        if adaptive:
            y_loc = _adaptive_finish_spmm(
                y, x_use, gi, gm, sv, sr, sc, n_rows=rps,
                has_spill=has_spill)
        else:
            y_loc = y[:rps, :d]
        if recv_flat is None:
            return y_loc
        prods = jnp.take(recv_flat, rm_x, axis=0) * rm_v[:, None]
        return y_loc + jax.ops.segment_sum(prods, rm_r, num_segments=rps)

    _, ndims = _sharded_args(plan)
    in_specs = [P(*((axis,) + (None,) * (nd - 1))) for nd in ndims]
    if kind == "spmv":
        in_specs.append(P(axis) if split else P())
        out_spec = P(axis)
    else:
        in_specs.append(P(axis, None) if split else P(None, None))
        out_spec = P(axis, None)
    return jax.jit(shard_map(per_shard, mesh=mesh,
                             in_specs=tuple(in_specs), out_specs=out_spec,
                             check_rep=False))


# mesh-signature memo: a Mesh's topology is immutable, so the O(n_devices)
# signature walk runs once per mesh object instead of on every sharded
# dispatch (the weak keying preserves the resized-mesh aliasing guarantee:
# a dead mesh's entry vanishes with it, a rebuilt mesh recomputes)
_MESH_SIGS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _mesh_signature(mesh) -> tuple:
    """Value identity of a mesh (axis names/sizes + device ids) for cache
    keys — ``id(mesh)`` alone can alias a resized/rebuilt mesh after GC."""
    from repro.sharding.partitioner import mesh_signature
    try:
        sig = _MESH_SIGS.get(mesh)
        if sig is None:
            sig = mesh_signature(mesh)
            _MESH_SIGS[mesh] = sig
        return sig
    except TypeError:          # mesh not weakref-able/hashable: just compute
        return mesh_signature(mesh)


def _sharded_exec(plan: ShardedRgCSRPlan, kind: str, mesh, axis: str,
                  interpret: bool, d_tile: int = LANES):
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
    if mesh.shape[axis] != plan.n_shards:
        raise ValueError(
            f"plan built for {plan.n_shards} shards but mesh axis "
            f"{axis!r} has {mesh.shape[axis]} devices")
    key = (id(plan), kind, _mesh_signature(mesh), axis, interpret, d_tile)
    with _SHARDED_LOCK:
        fn = _SHARDED_EXEC.get(key)
        if fn is not None:
            _SHARDED_EXEC.move_to_end(key)
            return fn
    fn = _build_sharded_exec(plan, kind, mesh, axis, interpret, d_tile)
    with _SHARDED_LOCK:
        if key not in _SHARDED_EXEC:
            _SHARDED_EXEC[key] = fn
            weakref.finalize(plan, _evict_sharded_exec, id(plan))
            while len(_SHARDED_EXEC) > _SHARDED_EXEC_MAX:
                _SHARDED_EXEC.popitem(last=False)
        else:
            fn = _SHARDED_EXEC[key]
    return fn


def _evict_sharded_exec(pid: int) -> None:
    with _SHARDED_LOCK:
        for key in [k for k in _SHARDED_EXEC if k[0] == pid]:
            del _SHARDED_EXEC[key]


def sharded_rgcsr_spmv(plan: ShardedRgCSRPlan, x, *, mesh, axis: str,
                       interpret: bool | None = None):
    """y = A @ x over a 1-D mesh axis: one shard_map program, each device
    running the existing Pallas kernel on its row shard's local slice.

    ``x``: the full (n_cols,) vector; in ``'split'`` mode it is padded to
    ``n_shards · cols_per_shard`` and row-sharded over ``axis`` by GSPMD,
    in ``'replicated'`` mode it is broadcast.  Returns (n_rows,).
    """
    if interpret is None:
        interpret = default_interpret()
    fn = _sharded_exec(plan, "spmv", mesh, axis, interpret)
    args, _ = _sharded_args(plan)
    x = jnp.asarray(x)
    if plan.x_mode == "split":
        xw = plan.n_shards * plan.cols_per_shard
        x = jnp.zeros((xw,), x.dtype).at[: plan.n_cols].set(x)
    y = fn(*args, x)
    return y[: plan.n_rows]


def sharded_rgcsr_spmm(plan: ShardedRgCSRPlan, x, *, mesh, axis: str,
                       d_tile: int = LANES, interpret: bool | None = None):
    """Y = A @ X over a 1-D mesh axis (X dense (n_cols, d)) -> (n_rows, d)."""
    if interpret is None:
        interpret = default_interpret()
    fn = _sharded_exec(plan, "spmm", mesh, axis, interpret, d_tile)
    args, _ = _sharded_args(plan)
    x = jnp.asarray(x)
    if plan.x_mode == "split":
        xw = plan.n_shards * plan.cols_per_shard
        x = jnp.zeros((xw, x.shape[1]), x.dtype).at[: plan.n_cols].set(x)
    y = fn(*args, x)
    return y[: plan.n_rows, : x.shape[1]]


# ---------------------------------------------------------------------------
# Plans over SparseLinear parameter trees (serving path)
# ---------------------------------------------------------------------------

# Memo keyed on (id(columns2d), dtype, d_out, d_in, group_size) — the dims
# are part of the key so an entry built with different/misinferred dims can
# never shadow a caller's correct ones.  The stored strong reference to the
# source values array both validates the entry (values identity must match —
# a training step invalidates it) and keeps the id stable.
_PARAM_PLANS: "collections.OrderedDict[tuple, tuple]" = collections.OrderedDict()
_PARAM_PLANS_MAX = 64
_PARAM_PLANS_LOCK = threading.Lock()


def plan_from_params(params, dtype, *, d_out: int, d_in: int,
                     group_size: int) -> RgCSRPlan:
    """RgCSRPlan view over SparseLinear param arrays (no host repack —
    the params already live in the kernel's slot-major layout, cps=1).

    With concrete arrays (eager per-layer paths) the container is memoized
    so each layer's plan is built once per process (``Engine`` warms this at
    init); under jit tracing the memo is bypassed and the container is
    rebuilt per trace, which is free — the jit'd serving path never pays
    per-call host plan work by construction.
    """
    n_groups = -(-d_out // group_size)
    # either array traced means we're inside a transform (grad over values
    # closes over concrete structure buffers) — never memoize tracers
    tracing = (isinstance(params["columns2d"], jax.core.Tracer)
               or isinstance(params["values2d"], jax.core.Tracer))
    key = (id(params["columns2d"]), jnp.dtype(dtype).str, d_out, d_in,
           group_size)
    if not tracing:
        with _PARAM_PLANS_LOCK:
            entry = _PARAM_PLANS.get(key)
            if entry is not None and entry[0] is params["values2d"]:
                _PARAM_PLANS.move_to_end(key)
                return entry[1]
    values = params["values2d"]
    if values.dtype != jnp.dtype(dtype):   # avoid a same-dtype device copy
        values = values.astype(dtype)
    plan = RgCSRPlan(
        values2d=values,
        columns2d=params["columns2d"],
        step_group=params["chunk_group"],
        step_first=params["chunk_first"],
        n_rows=d_out, n_cols=d_in, n_groups=int(n_groups),
        group_size=group_size, chunks_per_step=1)
    if not tracing:
        with _PARAM_PLANS_LOCK:
            _PARAM_PLANS[key] = (params["values2d"], plan)
            while len(_PARAM_PLANS) > _PARAM_PLANS_MAX:
                _PARAM_PLANS.popitem(last=False)
    return plan


def param_plan_stats() -> Dict[str, int]:
    """Size of the SparseLinear param-plan memo (serving-path cache)."""
    with _PARAM_PLANS_LOCK:
        return {"entries": len(_PARAM_PLANS)}


def warm_plans_from_params(params, dtype=jnp.float32) -> int:
    """Pre-stage SpMM plans for every SparseLinear subtree in ``params``.

    Walks the parameter tree for the RgCSR layout signature
    (``values2d``/``columns2d``/``chunk_group``/``chunk_first``) and builds
    each layer's plan once so the first *eager* per-layer call pays no
    host-side plan work.  Scope limits, by construction:

    * the jit'd prefill/decode path assembles plan containers at trace time
      (free) and never consults this memo — warming helps eager paths only;
    * layer-stacked (3-D) sparse params are skipped — the stacked scan path
      only ever sees traced slices;
    * ``d_in``/``d_out`` are inferred from the buffers (max column + 1,
      ``n_groups·G``); an eager caller passing different exact dims simply
      misses this entry and builds its own (dims are part of the memo key —
      a misinferred warm entry can never shadow correct dims).

    Returns #plans warmed.
    """
    warmed = 0

    def visit(node) -> None:
        nonlocal warmed
        if not isinstance(node, dict):
            return
        if {"values2d", "columns2d", "chunk_group", "chunk_first"} <= set(node):
            if getattr(node["values2d"], "ndim", 0) == 2:
                g = int(node["columns2d"].shape[1])
                n_groups = int(np.asarray(node["chunk_group"])[-1]) + 1 \
                    if node["chunk_group"].shape[0] else 1
                d_in = int(np.asarray(node["columns2d"]).max()) + 1
                plan_from_params(node, dtype, d_out=n_groups * g,
                                 d_in=d_in, group_size=g)
                warmed += 1
            return
        for v in node.values():
            visit(v)

    visit(params)
    return warmed


# ---------------------------------------------------------------------------
# ELLPACK
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EllPlan:
    values2d: Any   # (K_pad, N_pad)
    columns2d: Any  # (K_pad, N_pad)
    n_rows: int
    n_cols: int


def make_ell_plan(m: ELLPACK) -> EllPlan:
    vals = np.asarray(m.values)
    cols = np.asarray(m.columns).astype(np.int32)
    k, n = vals.shape
    k_pad, n_pad = _pad_to(k, SUBLANES), _pad_to(n, LANES)
    vp = np.zeros((k_pad, n_pad), vals.dtype)
    cp = np.zeros((k_pad, n_pad), np.int32)
    vp[:k, :n] = vals
    cp[:k, :n] = cols
    return EllPlan(values2d=jnp.asarray(vp), columns2d=jnp.asarray(cp),
                   n_rows=m.shape[0], n_cols=m.shape[1])


def ell_spmv(plan: EllPlan, x, *, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    n_pad = _pad_to(max(plan.n_cols, 1), LANES)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = ell_spmv_pallas(plan.values2d, plan.columns2d, x_pad,
                        interpret=interpret)
    return y[0, : plan.n_rows]
