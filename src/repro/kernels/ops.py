"""Jit'd public wrappers around the Pallas kernels.

``RgCSRPlan`` is the device-resident execution plan built once per matrix
(the analogue of a real framework's format-compile step): the flat grouped
storage reshaped into the ``(S, G)`` slot-major tile the kernel consumes,
plus the chunk table that drives the data-dependent grid.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python with identical semantics; on a real TPU pass
``interpret=False`` (the default resolves via ``jax.default_backend()``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import ELLPACK, RgCSR
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.rgcsr_spmm import rgcsr_spmm_pallas
from repro.kernels.rgcsr_spmv import LANES, SUBLANES, rgcsr_spmv_pallas

__all__ = ["RgCSRPlan", "make_plan", "rgcsr_spmv", "rgcsr_spmm",
           "EllPlan", "make_ell_plan", "ell_spmv", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class RgCSRPlan:
    """Kernel-ready layout for one RgCSR matrix."""

    values2d: Any       # (S, G)
    columns2d: Any      # (S, G) int32
    chunk_group: Any    # (num_chunks,) int32
    chunk_first: Any    # (num_chunks,) int32
    n_rows: int
    n_cols: int
    n_groups: int
    group_size: int

    @property
    def num_chunks(self) -> int:
        return int(self.chunk_group.shape[0])


def make_plan(m: RgCSR) -> RgCSRPlan:
    """Host-side plan construction (format-compile)."""
    if m.group_size % LANES != 0:
        raise ValueError(
            f"TPU plan needs group_size % {LANES} == 0, got {m.group_size} "
            f"(use group_size=128/256/512; smaller groups are modeled, not run "
            f"— DESIGN.md §2)")
    if m.slot_pad % SUBLANES != 0:
        raise ValueError(f"slot_pad must be a multiple of {SUBLANES}")
    g = m.group_size
    slots = np.asarray(m.slots_per_group)
    total_slots = int(slots.sum())
    values2d = np.asarray(m.values).reshape(total_slots, g)
    columns2d = np.asarray(m.columns).reshape(total_slots, g).astype(np.int32)

    chunks_per_group = slots // SUBLANES
    chunk_group = np.repeat(np.arange(len(slots), dtype=np.int32), chunks_per_group)
    first_idx = np.cumsum(np.concatenate([[0], chunks_per_group[:-1]]))
    chunk_first = np.zeros(len(chunk_group), dtype=np.int32)
    chunk_first[first_idx] = 1
    return RgCSRPlan(
        values2d=jnp.asarray(values2d),
        columns2d=jnp.asarray(columns2d),
        chunk_group=jnp.asarray(chunk_group),
        chunk_first=jnp.asarray(chunk_first),
        n_rows=m.shape[0],
        n_cols=m.shape[1],
        n_groups=m.n_groups,
        group_size=g,
    )


def rgcsr_spmv(plan: RgCSRPlan, x, *, interpret: bool | None = None):
    """y = A @ x via the Pallas kernel. x: (n_cols,) -> y: (n_rows,)."""
    if interpret is None:
        interpret = default_interpret()
    n_pad = _pad_to(max(plan.n_cols, 1), LANES)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = rgcsr_spmv_pallas(
        plan.chunk_group, plan.chunk_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        interpret=interpret)
    return y.reshape(-1)[: plan.n_rows]


def rgcsr_spmm(plan: RgCSRPlan, x, *, d_tile: int = LANES,
               interpret: bool | None = None):
    """Y = A @ X via the Pallas kernel. X: (n_cols, d) -> Y: (n_rows, d)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = x.shape
    n_pad = _pad_to(max(n, 1), SUBLANES)
    d_pad = _pad_to(max(d, 1), d_tile)
    x_pad = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    y = rgcsr_spmm_pallas(
        plan.chunk_group, plan.chunk_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        d_tile=d_tile, interpret=interpret)
    return y[: plan.n_rows, :d]


@dataclasses.dataclass(frozen=True)
class EllPlan:
    values2d: Any   # (K_pad, N_pad)
    columns2d: Any  # (K_pad, N_pad)
    n_rows: int
    n_cols: int


def make_ell_plan(m: ELLPACK) -> EllPlan:
    vals = np.asarray(m.values)
    cols = np.asarray(m.columns).astype(np.int32)
    k, n = vals.shape
    k_pad, n_pad = _pad_to(k, SUBLANES), _pad_to(n, LANES)
    vp = np.zeros((k_pad, n_pad), vals.dtype)
    cp = np.zeros((k_pad, n_pad), np.int32)
    vp[:k, :n] = vals
    cp[:k, :n] = cols
    return EllPlan(values2d=jnp.asarray(vp), columns2d=jnp.asarray(cp),
                   n_rows=m.shape[0], n_cols=m.shape[1])


def ell_spmv(plan: EllPlan, x, *, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    n_pad = _pad_to(max(plan.n_cols, 1), LANES)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = ell_spmv_pallas(plan.values2d, plan.columns2d, x_pad,
                        interpret=interpret)
    return y[0, : plan.n_rows]
