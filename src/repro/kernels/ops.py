"""Jit'd public wrappers around the Pallas kernels + the plan/cache layer.

``RgCSRPlan`` is the device-resident execution plan built once per
(matrix, kernel config) — the analogue of a real framework's format-compile
step: the flat grouped storage reshaped into the ``(S, G)`` slot-major tile
the kernel consumes, plus the **step table** that drives the data-dependent
grid.  With ``chunks_per_step > 1`` every group's slot count is padded up to
a multiple of ``8·chunks_per_step`` so one grid step covers several 8-slot
chunks of the same group (DESIGN.md §3); the padding is exact zeros with
ghost column index 0, i.e. masked at plan time.

``PlanCache`` is the process-wide memo: SpMV-heavy paths (core dispatch, the
serving engine, the benchmark harness) fetch plans through ``get_plan``
instead of rebuilding host-side layouts per call.  Entries are keyed on
matrix identity + config and evicted when the matrix is garbage-collected.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python with identical semantics; on a real TPU pass
``interpret=False`` (the default resolves via ``jax.default_backend()``).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
import weakref
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import ELLPACK, RgCSR, ShardedRgCSR
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.rgcsr_spmm import rgcsr_spmm_pallas
from repro.kernels.rgcsr_spmv import (CHUNKS_PER_STEP_CHOICES, LANES,
                                      SUBLANES, rgcsr_spmv_pallas)

__all__ = ["RgCSRPlan", "make_plan", "rgcsr_spmv", "rgcsr_spmm",
           "EllPlan", "make_ell_plan", "ell_spmv", "default_interpret",
           "PlanCache", "PLAN_CACHE", "get_plan",
           "ShardedRgCSRPlan", "make_sharded_plan", "get_sharded_plan",
           "sharded_rgcsr_spmv", "sharded_rgcsr_spmm",
           "sharded_plan_cache_stats",
           "plan_from_params", "warm_plans_from_params",
           "DEFAULT_X_TILE_ELEMS"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


# x elements staged into VMEM per SpMV grid step before column tiling kicks
# in.  2^21 fp32 = 8 MiB — half the ~16 MiB/core VMEM, leaving room for the
# (R, G) matrix tiles and the (1, G) accumulator.  Matrices at or below this
# width keep the seed kernel's single unmasked whole-x stage; only wider
# ones pay the masked multi-tile path.
DEFAULT_X_TILE_ELEMS = 1 << 21


@dataclasses.dataclass(frozen=True)
class RgCSRPlan:
    """Kernel-ready layout for one RgCSR matrix at one kernel config.

    ``step_group``/``step_first`` form the coarsened step table: grid step
    ``s`` covers slot rows ``[R·s, R·(s+1))`` of ``values2d``/``columns2d``
    (``R = 8·chunks_per_step``) and belongs to group ``step_group[s]``.

    **Adaptive plans** (``ordering='adaptive'``, DESIGN.md §5): groups hold
    length-sorted rows instead of consecutive ones, so the kernel's output
    lives in the *permuted* row space.  ``gather_idx``/``grouped_mask`` are
    the fused inverse-permutation map back to original rows, and rows longer
    than ``spill_threshold`` live in the COO tail (``spill_*``), combined
    with a segment-sum in the epilogue.  Block plans leave these ``None``.
    """

    values2d: Any       # (S, G)
    columns2d: Any      # (S, G) int32
    step_group: Any     # (num_steps,) int32
    step_first: Any     # (num_steps,) int32
    n_rows: int
    n_cols: int
    n_groups: int
    group_size: int
    chunks_per_step: int = 1
    # --- adaptive grouping (None/defaults on block plans) ---
    ordering: str = "block"        # "block" | "adaptive"
    spill_threshold: int = 0       # 0 = no spill
    nnz: int = -1                  # true nonzeros incl. spill (-1 = unknown)
    gather_idx: Any = None         # (n_rows,) int32: flat kernel-output index
    grouped_mask: Any = None       # (n_rows,) bool: False = row is spilled
    spill_values: Any = None       # (nnz_spill,)
    spill_rows: Any = None         # (nnz_spill,) int32 original row ids
    spill_columns: Any = None      # (nnz_spill,) int32

    @property
    def num_steps(self) -> int:
        """Grid steps the SpMV kernel launches (per x tile)."""
        return int(self.step_group.shape[0])

    @property
    def num_chunks(self) -> int:
        """8-slot chunks covered (= num_steps · chunks_per_step)."""
        return self.num_steps * self.chunks_per_step

    @property
    def stored_slots(self) -> int:
        return int(self.values2d.shape[0])

    @property
    def n_spilled_elements(self) -> int:
        return 0 if self.spill_values is None else int(
            self.spill_values.shape[0])

    @property
    def stored_elements(self) -> int:
        """Grouped slots × lanes + COO tail (the format's byte footprint)."""
        return self.stored_slots * self.group_size + self.n_spilled_elements

    @property
    def padded_slot_fraction(self) -> float:
        """Fraction of stored elements that are padding (artificial zeros).

        The paper's fill-ratio metric normalized to stored bytes: on a
        memory-bound op this is directly the fraction of wasted HBM traffic.
        Requires ``nnz`` (set by ``make_plan``; -1 on raw param-view plans).
        """
        if self.nnz < 0 or self.stored_elements == 0:
            return 0.0
        return (self.stored_elements - self.nnz) / self.stored_elements


def make_plan(m: RgCSR, *, chunks_per_step: int = 1,
              ordering: str = "block",
              spill_threshold: int = 0) -> RgCSRPlan:
    """Host-side plan construction (format-compile).

    ``chunks_per_step`` coarsens the grid: each group's ``(K_g, G)`` tile is
    re-padded so ``K_g`` is a multiple of ``8·chunks_per_step`` and one grid
    step consumes the whole coarsened sub-tile.  The extra padding rows are
    exact zeros (ghost column 0), so in-kernel accumulation over them is a
    masked no-op — the paper's artificial-zeros accounting extended to the
    coarsened tile.  The trade (fewer grid steps vs more padded bytes) is
    what :mod:`repro.kernels.autotune` measures per matrix.

    ``ordering='adaptive'`` (DESIGN.md §5) regroups rows by descending
    length so same-length rows share groups (each group's slot count is its
    own max, not the max over an arbitrary consecutive window), and rows
    longer than ``spill_threshold`` (> 0) leave the grouped storage for a
    COO tail.  The kernel then computes in the permuted row space; the
    SpMV/SpMM wrappers fuse the inverse gather + tail back in.
    """
    if m.group_size % LANES != 0:
        raise ValueError(
            f"TPU plan needs group_size % {LANES} == 0, got {m.group_size} "
            f"(use group_size=128/256/512; smaller groups are modeled, not run "
            f"— DESIGN.md §2)")
    if m.slot_pad % SUBLANES != 0:
        raise ValueError(f"slot_pad must be a multiple of {SUBLANES}")
    if chunks_per_step not in CHUNKS_PER_STEP_CHOICES:
        raise ValueError(
            f"chunks_per_step must be one of {CHUNKS_PER_STEP_CHOICES}, "
            f"got {chunks_per_step}")
    if ordering not in ("block", "adaptive"):
        raise ValueError(
            f"ordering must be 'block' or 'adaptive', got {ordering!r}")
    if ordering == "adaptive":
        return _make_adaptive_plan(m, chunks_per_step=chunks_per_step,
                                   spill_threshold=int(spill_threshold))
    if spill_threshold:
        raise ValueError(
            "spill_threshold requires ordering='adaptive' (block grouping "
            "cannot drop rows without a permutation gather)")
    g = m.group_size
    rows_per_step = chunks_per_step * SUBLANES
    slots = np.asarray(m.slots_per_group)
    n_groups = len(slots)
    total_slots = int(slots.sum())
    values2d = np.asarray(m.values).reshape(total_slots, g)
    columns2d = np.asarray(m.columns).reshape(total_slots, g).astype(np.int32)

    padded = (-(-slots // rows_per_step) * rows_per_step).astype(np.int64)
    if int(padded.sum()) != total_slots:
        # re-pad each group's tile up to the coarsened step granularity
        src_off = np.concatenate([[0], np.cumsum(slots)[:-1]])
        dst_off = np.concatenate([[0], np.cumsum(padded)[:-1]])
        vp = np.zeros((int(padded.sum()), g), values2d.dtype)
        cp = np.zeros((int(padded.sum()), g), np.int32)
        for gi in range(n_groups):
            k = int(slots[gi])
            vp[dst_off[gi]: dst_off[gi] + k] = values2d[src_off[gi]: src_off[gi] + k]
            cp[dst_off[gi]: dst_off[gi] + k] = columns2d[src_off[gi]: src_off[gi] + k]
        values2d, columns2d = vp, cp

    step_group, step_first = _step_table(padded, rows_per_step)
    return RgCSRPlan(
        values2d=jnp.asarray(values2d),
        columns2d=jnp.asarray(columns2d),
        step_group=jnp.asarray(step_group),
        step_first=jnp.asarray(step_first),
        n_rows=m.shape[0],
        n_cols=m.shape[1],
        n_groups=m.n_groups,
        group_size=g,
        chunks_per_step=chunks_per_step,
        nnz=m.nnz,
    )


def _step_table(padded_slots: np.ndarray, rows_per_step: int):
    """(step_group, step_first) for per-group padded slot counts."""
    steps_per_group = (padded_slots // rows_per_step).astype(np.int64)
    n_groups = len(steps_per_group)
    step_group = np.repeat(np.arange(n_groups, dtype=np.int32),
                           steps_per_group)
    first_idx = np.cumsum(np.concatenate([[0], steps_per_group[:-1]]))
    step_first = np.zeros(len(step_group), dtype=np.int32)
    step_first[first_idx] = 1
    return step_group, step_first


def _make_adaptive_plan(m: RgCSR, *, chunks_per_step: int,
                        spill_threshold: int) -> RgCSRPlan:
    """Length-aware regrouping + pathological-row spill (DESIGN.md §5).

    1. rows with nnz > ``spill_threshold`` (if > 0) leave for the COO tail;
    2. remaining rows are permuted by descending length (stable), so each
       group of ``G`` rows has near-uniform lengths and its slot count
       ``K_g = roundup(max len in group, 8·chunks_per_step)`` carries
       minimal padding under the alignment constraint;
    3. the kernel output is in permuted space — ``gather_idx`` maps original
       row ``r`` to its flat output lane, ``grouped_mask`` marks spilled
       rows (their value comes from the tail's segment-sum alone).
    """
    from repro.core.ordering import descending_from_lengths, split_spill_rows

    g = m.group_size
    rows_per_step = chunks_per_step * SUBLANES
    n_rows, n_cols = m.shape
    row_lens = np.asarray(m.row_lengths).astype(np.int64)
    csr_v, csr_c, row_ptr = m.to_csr_arrays()

    grouped_rows, spilled_rows = split_spill_rows(row_lens, spill_threshold)
    order = descending_from_lengths(row_lens[grouped_rows])
    perm = grouped_rows[order]                 # position p holds row perm[p]
    n_grouped = len(perm)
    n_groups = max(1, -(-n_grouped // g))

    # per-group slot counts: own max length, aligned to the step granularity
    slots = np.empty(n_groups, dtype=np.int64)
    for gi in range(n_groups):
        rows_g = perm[gi * g: (gi + 1) * g]
        k_g = int(row_lens[rows_g].max()) if len(rows_g) else 0
        slots[gi] = -(-max(k_g, 1) // rows_per_step) * rows_per_step
    offsets = np.concatenate([[0], np.cumsum(slots)[:-1]])

    values2d = np.zeros((int(slots.sum()), g), np.asarray(m.values).dtype)
    columns2d = np.zeros((int(slots.sum()), g), np.int32)
    for p in range(n_grouped):
        r = int(perm[p])
        gi, lane = p // g, p % g
        lo, hi = int(row_ptr[r]), int(row_ptr[r + 1])
        base = int(offsets[gi])
        values2d[base: base + (hi - lo), lane] = csr_v[lo:hi]
        columns2d[base: base + (hi - lo), lane] = csr_c[lo:hi]

    step_group, step_first = _step_table(slots, rows_per_step)

    gather_idx = np.zeros(n_rows, np.int32)
    grouped_mask = np.zeros(n_rows, bool)
    gather_idx[perm] = np.arange(n_grouped, dtype=np.int32)
    grouped_mask[perm] = True

    spill_sel = np.zeros(len(csr_v), bool)
    for r in spilled_rows:
        spill_sel[int(row_ptr[r]): int(row_ptr[r + 1])] = True
    spill_row_ids = np.repeat(
        spilled_rows.astype(np.int32),
        (row_ptr[spilled_rows + 1] - row_ptr[spilled_rows]).astype(np.int64)
        if len(spilled_rows) else np.empty(0, np.int64))

    return RgCSRPlan(
        values2d=jnp.asarray(values2d),
        columns2d=jnp.asarray(columns2d),
        step_group=jnp.asarray(step_group),
        step_first=jnp.asarray(step_first),
        n_rows=n_rows,
        n_cols=n_cols,
        n_groups=n_groups,
        group_size=g,
        chunks_per_step=chunks_per_step,
        ordering="adaptive",
        spill_threshold=spill_threshold,
        nnz=m.nnz,
        gather_idx=jnp.asarray(gather_idx),
        grouped_mask=jnp.asarray(grouped_mask),
        spill_values=jnp.asarray(csr_v[spill_sel]),
        spill_rows=jnp.asarray(spill_row_ids),
        spill_columns=jnp.asarray(csr_c[spill_sel].astype(np.int32)),
    )


# ---------------------------------------------------------------------------
# PlanCache — process-wide memo of (matrix identity, config) -> RgCSRPlan
# ---------------------------------------------------------------------------


class PlanCache:
    """LRU plan cache keyed on matrix identity + kernel config.

    Keys use ``id(matrix)`` plus every plan-shaping config field —
    ``(chunks_per_step, ordering, spill_threshold)`` — so a block plan and
    an adaptive plan of the same matrix (or two adaptive plans at different
    spill thresholds) can never shadow each other.  A ``weakref.finalize``
    hook evicts every config of a matrix when it is garbage-collected
    (CPython runs the finalizer during deallocation, before the id can be
    reused).  Thread-safe; plan *construction* happens outside the lock so
    concurrent misses on different matrices don't serialize.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._plans: "collections.OrderedDict[tuple, RgCSRPlan]" = \
            collections.OrderedDict()
        self._finalized: set = set()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def get(self, m: RgCSR, *, chunks_per_step: int = 1,
            ordering: str = "block", spill_threshold: int = 0) -> RgCSRPlan:
        key = (id(m), chunks_per_step, ordering, int(spill_threshold))
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
        plan = make_plan(m, chunks_per_step=chunks_per_step,
                         ordering=ordering, spill_threshold=spill_threshold)
        with self._lock:
            if key not in self._plans:
                self.misses += 1
                self._plans[key] = plan
                if id(m) not in self._finalized:
                    self._finalized.add(id(m))
                    weakref.finalize(m, self._evict, id(m))
                while len(self._plans) > self.maxsize:
                    self._plans.popitem(last=False)
            else:
                self.hits += 1
                plan = self._plans[key]
        return plan

    def _evict(self, mid: int) -> None:
        with self._lock:
            self._finalized.discard(mid)
            for key in [k for k in self._plans if k[0] == mid]:
                del self._plans[key]

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._finalized.clear()
            self.hits = 0
            self.misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._plans)}

    def __len__(self) -> int:
        return len(self._plans)


PLAN_CACHE = PlanCache()


def get_plan(m: RgCSR, *, chunks_per_step: int = 1, ordering: str = "block",
             spill_threshold: int = 0) -> RgCSRPlan:
    """Fetch (or build and memoize) the kernel plan for ``m``."""
    return PLAN_CACHE.get(m, chunks_per_step=chunks_per_step,
                          ordering=ordering, spill_threshold=spill_threshold)


# ---------------------------------------------------------------------------
# SpMV / SpMM wrappers
# ---------------------------------------------------------------------------


def _x_tile_for(n_pad_min: int, x_tile: Optional[int]) -> Tuple[int, int]:
    """Resolve the x column-tile width and the final padded x length."""
    if x_tile is None:
        if n_pad_min <= DEFAULT_X_TILE_ELEMS:
            return n_pad_min, n_pad_min          # single tile — seed behaviour
        x_tile = DEFAULT_X_TILE_ELEMS
    x_tile = _pad_to(x_tile, LANES)
    return x_tile, _pad_to(n_pad_min, x_tile)


@functools.partial(jax.jit, static_argnames=("n_rows", "has_spill"))
def _adaptive_finish_spmv(y_flat, x, gather_idx, grouped_mask,
                          spill_values, spill_rows, spill_columns,
                          *, n_rows: int, has_spill: bool):
    """Fused adaptive epilogue: inverse-permutation gather + COO tail.

    One jit region, no materialized scatter: original row ``r`` reads lane
    ``gather_idx[r]`` of the permuted kernel output (spilled rows masked to
    zero) and the pathological rows come back as a segment-sum over the COO
    tail — both fuse into a single gather/scatter pass over HBM.
    """
    out = jnp.where(grouped_mask, jnp.take(y_flat, gather_idx, axis=0),
                    jnp.zeros((), y_flat.dtype))
    if has_spill:
        prods = spill_values * jnp.take(x, spill_columns, axis=0)
        out = out + jax.ops.segment_sum(prods, spill_rows,
                                        num_segments=n_rows)
    return out


@functools.partial(jax.jit, static_argnames=("n_rows", "has_spill"))
def _adaptive_finish_spmm(y2d, x, gather_idx, grouped_mask,
                          spill_values, spill_rows, spill_columns,
                          *, n_rows: int, has_spill: bool):
    """SpMM twin of :func:`_adaptive_finish_spmv` (row gather over axis 0)."""
    out = jnp.where(grouped_mask[:, None],
                    jnp.take(y2d, gather_idx, axis=0),
                    jnp.zeros((), y2d.dtype))[:, : x.shape[1]]
    if has_spill:
        prods = jnp.take(x, spill_columns, axis=0) * spill_values[:, None]
        out = out + jax.ops.segment_sum(prods, spill_rows,
                                        num_segments=n_rows)
    return out


def rgcsr_spmv(plan: RgCSRPlan, x, *, interpret: bool | None = None,
               x_tile: int | None = None):
    """y = A @ x via the Pallas kernel. x: (n_cols,) -> y: (n_rows,).

    ``x_tile`` bounds the x slice staged into VMEM per grid step; ``None``
    stages x whole when it fits (``DEFAULT_X_TILE_ELEMS``) and tiles it
    otherwise, so wide matrices degrade smoothly instead of exhausting VMEM.

    Adaptive plans return through the fused epilogue (inverse gather +
    spill segment-sum); block plans slice the contiguous rows as before.
    """
    if interpret is None:
        interpret = default_interpret()
    n_pad_min = _pad_to(max(plan.n_cols, 1), LANES)
    xt, n_pad = _x_tile_for(n_pad_min, x_tile)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = rgcsr_spmv_pallas(
        plan.step_group, plan.step_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        chunks_per_step=plan.chunks_per_step, x_tile=xt,
        interpret=interpret)
    y_flat = y.reshape(-1)
    if plan.ordering != "adaptive":
        return y_flat[: plan.n_rows]
    return _adaptive_finish_spmv(
        y_flat, jnp.asarray(x), plan.gather_idx, plan.grouped_mask,
        plan.spill_values, plan.spill_rows, plan.spill_columns,
        n_rows=plan.n_rows, has_spill=plan.n_spilled_elements > 0)


def rgcsr_spmm(plan: RgCSRPlan, x, *, d_tile: int = LANES,
               interpret: bool | None = None):
    """Y = A @ X via the Pallas kernel. X: (n_cols, d) -> Y: (n_rows, d)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = x.shape
    n_pad = _pad_to(max(n, 1), SUBLANES)
    d_pad = _pad_to(max(d, 1), d_tile)
    x_pad = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    y = rgcsr_spmm_pallas(
        plan.step_group, plan.step_first, plan.values2d, plan.columns2d,
        x_pad, n_groups=plan.n_groups, group_size=plan.group_size,
        d_tile=d_tile, chunks_per_step=plan.chunks_per_step,
        interpret=interpret)
    if plan.ordering != "adaptive":
        return y[: plan.n_rows, :d]
    return _adaptive_finish_spmm(
        y, jnp.asarray(x), plan.gather_idx, plan.grouped_mask,
        plan.spill_values, plan.spill_rows, plan.spill_columns,
        n_rows=plan.n_rows, has_spill=plan.n_spilled_elements > 0)


# ---------------------------------------------------------------------------
# Row-sharded multi-device SpMV/SpMM (DESIGN.md §10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedRgCSRPlan:
    """Stacked, device-major execution plan for a :class:`ShardedRgCSR`.

    Each shard's :class:`RgCSRPlan` (built by the unchanged ``make_plan`` —
    block or adaptive grouping applies *per shard*) is padded to the
    across-shard maxima and stacked on a leading device axis, which is what
    ``shard_map`` needs: one SPMD program, per-device slices of uniform
    shape.  Padding rows are exact zeros; padding *steps* point at the
    shard's own last real group with ``step_first = 0``, so they accumulate
    zeros into an already-initialized output block (the Pallas revisit rule
    stays satisfied: padded steps extend the last group's consecutive run).

    ``x_mode`` fixes how the dense vector is reconciled (arXiv:1112.5588's
    local/remote split):

    * ``'replicated'`` — x is replicated; columns keep global indices.
      Zero communication, D× x memory: the fast path while x fits.
    * ``'split'`` — x is row-sharded over the same axis
      (``cols_per_shard`` entries per device).  At plan time each shard's
      referenced columns are split into *local* (owned by this device) and
      *remote* (``remote_cols``, usually tiny); stored column indices are
      remapped into the compact ``[local ‖ remote]`` space, and at run time
      the remote entries are gathered before the kernel.  The kernel's x
      working set drops from ``n_cols`` to ``cols_per_shard + R_max``.
    """

    values3d: Any        # (D, S_pad, G)
    columns3d: Any       # (D, S_pad, G) int32 (global or compact, per x_mode)
    step_group2d: Any    # (D, T_max) int32
    step_first2d: Any    # (D, T_max) int32
    n_rows: int
    n_cols: int
    n_shards: int
    rows_per_shard: int
    cols_per_shard: int          # x entries owned per device (split mode)
    n_groups: int                # max over shards (uniform kernel out shape)
    group_size: int
    chunks_per_step: int = 1
    ordering: str = "block"
    spill_threshold: int = 0
    x_mode: str = "replicated"
    nnz: int = -1
    remote_cols: Any = None      # (D, R_max) int32 (split mode only)
    gather_idx: Any = None       # (D, rows_per_shard) int32 (adaptive)
    grouped_mask: Any = None     # (D, rows_per_shard) bool (adaptive)
    spill_values: Any = None     # (D, E_max) (adaptive + spill)
    spill_rows: Any = None       # (D, E_max) int32 local row ids
    spill_columns: Any = None    # (D, E_max) int32 (global/compact per mode)
    # true per-shard figures, pre-stacking (the ~1/D acceptance numbers)
    shard_stored_slots: Tuple[int, ...] = ()
    shard_num_steps: Tuple[int, ...] = ()
    shard_remote_cols: Tuple[int, ...] = ()

    @property
    def num_steps_max(self) -> int:
        return int(self.step_group2d.shape[1])

    @property
    def stored_slots_max(self) -> int:
        """Per-device stored slot rows after stacking (= max over shards)."""
        return int(self.values3d.shape[1])

    @property
    def n_spilled_max(self) -> int:
        return 0 if self.spill_values is None else int(
            self.spill_values.shape[1])

    @property
    def stored_elements(self) -> int:
        """True (unstacked) grouped slots × lanes + COO tails, all shards."""
        spilled = sum(self.shard_spilled_elements)
        return sum(self.shard_stored_slots) * self.group_size + spilled

    @property
    def shard_spilled_elements(self) -> Tuple[int, ...]:
        if self.spill_values is None:
            return (0,) * self.n_shards
        sv = np.asarray(self.spill_values)
        return tuple(int((sv[d] != 0).sum()) for d in range(self.n_shards))

    @property
    def padded_slot_fraction(self) -> float:
        if self.nnz < 0 or self.stored_elements == 0:
            return 0.0
        return (self.stored_elements - self.nnz) / self.stored_elements


def make_sharded_plan(sm: ShardedRgCSR, *, chunks_per_step: int = 1,
                      ordering: str = "block", spill_threshold: int = 0,
                      x_mode: str = "replicated") -> ShardedRgCSRPlan:
    """Build per-shard plans via :func:`make_plan`, then pad + stack them.

    Reuses the whole single-device plan machinery per shard — the adaptive
    length-aware permutation, per-group slot sizing, and COO spill are each
    computed inside a shard's own row block, so the autotuner's
    ``(chunks_per_step, ordering, spill_threshold)`` axes apply
    independently of the sharding.
    """
    if x_mode not in ("replicated", "split"):
        raise ValueError(
            f"x_mode must be 'replicated' or 'split', got {x_mode!r}")
    d_sh = sm.n_shards
    n_rows, n_cols = sm.shape
    g = sm.group_size
    rows_per_step = chunks_per_step * SUBLANES
    plans = [make_plan(s, chunks_per_step=chunks_per_step, ordering=ordering,
                       spill_threshold=spill_threshold) for s in sm.shards]
    adaptive = ordering == "adaptive"
    n_groups = max(p.n_groups for p in plans)
    t_max = max(p.num_steps for p in plans)
    s_pad = t_max * rows_per_step
    cstride = max(1, -(-n_cols // d_sh))

    # per-shard local/remote column split + compact remap (split mode)
    remaps, remotes = [], []
    if x_mode == "split":
        for d, shard in enumerate(sm.shards):
            lo, hi = d * cstride, min((d + 1) * cstride, n_cols)
            _, true_cols, _ = shard.to_csr_arrays()
            ref = np.unique(true_cols.astype(np.int64))
            remote = ref[(ref < lo) | (ref >= hi)]
            table = np.zeros(max(n_cols, 1), np.int32)
            if hi > lo:
                table[lo:hi] = np.arange(hi - lo, dtype=np.int32)
            table[remote] = cstride + np.arange(len(remote), dtype=np.int32)
            remaps.append(table)
            remotes.append(remote.astype(np.int32))
        r_max = max(len(r) for r in remotes)
    else:
        r_max = 0

    vals = np.zeros((d_sh, s_pad, g),
                    np.asarray(plans[0].values2d).dtype)
    cols = np.zeros((d_sh, s_pad, g), np.int32)
    sg2 = np.zeros((d_sh, t_max), np.int32)
    sf2 = np.zeros((d_sh, t_max), np.int32)
    remote_cols = np.zeros((d_sh, r_max), np.int32)
    e_max = max(p.n_spilled_elements for p in plans) if adaptive else 0
    gidx = np.zeros((d_sh, sm.rows_per_shard), np.int32)
    gmask = np.zeros((d_sh, sm.rows_per_shard), bool)
    sp_v = np.zeros((d_sh, e_max), vals.dtype)
    sp_r = np.zeros((d_sh, e_max), np.int32)
    sp_c = np.zeros((d_sh, e_max), np.int32)

    for d, p in enumerate(plans):
        s_d, t_d = p.stored_slots, p.num_steps
        vals[d, :s_d] = np.asarray(p.values2d)
        c2d = np.asarray(p.columns2d)
        if x_mode == "split":
            c2d = remaps[d][c2d]
        cols[d, :s_d] = c2d
        sg2[d, :t_d] = np.asarray(p.step_group)
        # padding steps extend the shard's own last group (step_first = 0,
        # zero values): consecutive revisit of an initialized block
        sg2[d, t_d:] = int(np.asarray(p.step_group)[-1]) if t_d else 0
        sf2[d, :t_d] = np.asarray(p.step_first)
        if x_mode == "split":
            remote_cols[d, : len(remotes[d])] = remotes[d]
        if adaptive:
            gidx[d] = np.asarray(p.gather_idx)
            gmask[d] = np.asarray(p.grouped_mask)
            e_d = p.n_spilled_elements
            if e_d:
                sp_v[d, :e_d] = np.asarray(p.spill_values)
                sp_r[d, :e_d] = np.asarray(p.spill_rows)
                sc = np.asarray(p.spill_columns)
                sp_c[d, :e_d] = remaps[d][sc] if x_mode == "split" else sc
    return ShardedRgCSRPlan(
        values3d=jnp.asarray(vals),
        columns3d=jnp.asarray(cols),
        step_group2d=jnp.asarray(sg2),
        step_first2d=jnp.asarray(sf2),
        n_rows=n_rows, n_cols=n_cols, n_shards=d_sh,
        rows_per_shard=sm.rows_per_shard, cols_per_shard=cstride,
        n_groups=n_groups, group_size=g, chunks_per_step=chunks_per_step,
        ordering=ordering, spill_threshold=int(spill_threshold),
        x_mode=x_mode, nnz=sm.nnz,
        remote_cols=jnp.asarray(remote_cols) if x_mode == "split" else None,
        gather_idx=jnp.asarray(gidx) if adaptive else None,
        grouped_mask=jnp.asarray(gmask) if adaptive else None,
        spill_values=jnp.asarray(sp_v) if adaptive else None,
        spill_rows=jnp.asarray(sp_r) if adaptive else None,
        spill_columns=jnp.asarray(sp_c) if adaptive else None,
        shard_stored_slots=tuple(p.stored_slots for p in plans),
        shard_num_steps=tuple(p.num_steps for p in plans),
        shard_remote_cols=tuple(len(r) for r in remotes) if remotes
        else (0,) * d_sh,
    )


# sharded plan memo: (id(matrix), config, x_mode) -> plan, GC-evicted like
# PLAN_CACHE (plan keys include x_mode because the stored column indices
# differ between the replicated and compact-split layouts)
_SHARDED_PLANS: "collections.OrderedDict[tuple, ShardedRgCSRPlan]" = \
    collections.OrderedDict()
_SHARDED_PLANS_MAX = 64
_SHARDED_LOCK = threading.RLock()
_SHARDED_FINALIZED: set = set()
_SHARDED_STATS = {"hits": 0, "misses": 0}


def get_sharded_plan(sm: ShardedRgCSR, *, chunks_per_step: int = 1,
                     ordering: str = "block", spill_threshold: int = 0,
                     x_mode: str = "replicated") -> ShardedRgCSRPlan:
    """Fetch (or build and memoize) the stacked sharded plan for ``sm``."""
    key = (id(sm), chunks_per_step, ordering, int(spill_threshold), x_mode)
    with _SHARDED_LOCK:
        plan = _SHARDED_PLANS.get(key)
        if plan is not None:
            _SHARDED_STATS["hits"] += 1
            _SHARDED_PLANS.move_to_end(key)
            return plan
    plan = make_sharded_plan(sm, chunks_per_step=chunks_per_step,
                             ordering=ordering,
                             spill_threshold=spill_threshold, x_mode=x_mode)
    with _SHARDED_LOCK:
        if key not in _SHARDED_PLANS:
            _SHARDED_STATS["misses"] += 1
            _SHARDED_PLANS[key] = plan
            if id(sm) not in _SHARDED_FINALIZED:
                _SHARDED_FINALIZED.add(id(sm))
                weakref.finalize(sm, _evict_sharded, id(sm))
            while len(_SHARDED_PLANS) > _SHARDED_PLANS_MAX:
                _SHARDED_PLANS.popitem(last=False)
        else:
            _SHARDED_STATS["hits"] += 1
            plan = _SHARDED_PLANS[key]
    return plan


def _evict_sharded(mid: int) -> None:
    with _SHARDED_LOCK:
        _SHARDED_FINALIZED.discard(mid)
        for key in [k for k in _SHARDED_PLANS if k[0] == mid]:
            del _SHARDED_PLANS[key]


def sharded_plan_cache_stats() -> Dict[str, int]:
    with _SHARDED_LOCK:
        return {"hits": _SHARDED_STATS["hits"],
                "misses": _SHARDED_STATS["misses"],
                "entries": len(_SHARDED_PLANS)}


# memo of jitted shard_map executables per (plan, mesh, axis, kind) — the
# shard_map wrapper must be a stable callable for jax's jit cache to hit
_SHARDED_EXEC: "collections.OrderedDict[tuple, Any]" = \
    collections.OrderedDict()
_SHARDED_EXEC_MAX = 32


def _sharded_args(plan: ShardedRgCSRPlan):
    """(args, per-arg PartitionSpec dim-count) in the inner-fn unpack order."""
    args = [plan.values3d, plan.columns3d, plan.step_group2d,
            plan.step_first2d]
    ndims = [3, 3, 2, 2]
    if plan.x_mode == "split":
        args.append(plan.remote_cols)
        ndims.append(2)
    if plan.ordering == "adaptive":
        args += [plan.gather_idx, plan.grouped_mask]
        ndims += [2, 2]
        if plan.n_spilled_max > 0:
            args += [plan.spill_values, plan.spill_rows, plan.spill_columns]
            ndims += [2, 2, 2]
    return args, ndims


def _build_sharded_exec(plan: ShardedRgCSRPlan, kind: str, mesh, axis: str,
                        interpret: bool, d_tile: int):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    split = plan.x_mode == "split"
    adaptive = plan.ordering == "adaptive"
    has_spill = adaptive and plan.n_spilled_max > 0
    rps = plan.rows_per_shard
    empty_v = jnp.zeros((0,), plan.values3d.dtype)
    empty_i = jnp.zeros((0,), jnp.int32)

    def per_shard(*a):
        it = iter(a)
        vals, cols = next(it)[0], next(it)[0]            # (S_pad, G)
        sg, sf = next(it)[0], next(it)[0]                # (T_max,)
        remote = next(it)[0] if split else None
        gi = next(it)[0] if adaptive else None
        gm = next(it)[0] if adaptive else None
        sv = next(it)[0] if has_spill else empty_v
        sr = next(it)[0] if has_spill else empty_i
        sc = next(it)[0] if has_spill else empty_i
        x_in = next(it)
        if split:
            # local/remote reconciliation: own slice stays put; the (plan-
            # time-computed, usually tiny) remote entries are gathered from
            # the all-gathered vector.  On real hardware the all_gather
            # becomes a sparse collective; the kernel working set is
            # already bounded to cols_per_shard + R_max either way.
            x_full = jax.lax.all_gather(x_in, axis, tiled=True)
            if kind == "spmv":
                x_use = jnp.concatenate(
                    [x_in, jnp.take(x_full, remote, axis=0)])
            else:
                x_use = jnp.concatenate(
                    [x_in, jnp.take(x_full, remote, axis=0)], axis=0)
        else:
            x_use = x_in
        if kind == "spmv":
            n_eff = x_use.shape[0]
            # same VMEM-bounded column tiling as the single-device wrapper:
            # single tile while x fits, masked multi-tile beyond
            xt, n_pad = _x_tile_for(_pad_to(max(n_eff, 1), LANES), None)
            x_pad = jnp.zeros((1, n_pad), x_use.dtype).at[0, :n_eff].set(
                x_use)
            y = rgcsr_spmv_pallas(
                sg, sf, vals, cols, x_pad, n_groups=plan.n_groups,
                group_size=plan.group_size,
                chunks_per_step=plan.chunks_per_step, x_tile=xt,
                interpret=interpret)
            y_flat = y.reshape(-1)
            if not adaptive:
                return y_flat[:rps]
            return _adaptive_finish_spmv(
                y_flat, x_use, gi, gm, sv, sr, sc, n_rows=rps,
                has_spill=has_spill)
        n_eff, d = x_use.shape
        n_pad = _pad_to(max(n_eff, 1), SUBLANES)
        d_pad = _pad_to(max(d, 1), d_tile)
        x_pad = jnp.zeros((n_pad, d_pad), x_use.dtype).at[
            :n_eff, :d].set(x_use)
        y = rgcsr_spmm_pallas(
            sg, sf, vals, cols, x_pad, n_groups=plan.n_groups,
            group_size=plan.group_size, d_tile=d_tile,
            chunks_per_step=plan.chunks_per_step, interpret=interpret)
        if not adaptive:
            return y[:rps, :d]
        return _adaptive_finish_spmm(
            y, x_use, gi, gm, sv, sr, sc, n_rows=rps, has_spill=has_spill)

    _, ndims = _sharded_args(plan)
    in_specs = [P(*((axis,) + (None,) * (nd - 1))) for nd in ndims]
    if kind == "spmv":
        in_specs.append(P(axis) if split else P())
        out_spec = P(axis)
    else:
        in_specs.append(P(axis, None) if split else P(None, None))
        out_spec = P(axis, None)
    return jax.jit(shard_map(per_shard, mesh=mesh,
                             in_specs=tuple(in_specs), out_specs=out_spec,
                             check_rep=False))


def _sharded_exec(plan: ShardedRgCSRPlan, kind: str, mesh, axis: str,
                  interpret: bool, d_tile: int = LANES):
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
    if mesh.shape[axis] != plan.n_shards:
        raise ValueError(
            f"plan built for {plan.n_shards} shards but mesh axis "
            f"{axis!r} has {mesh.shape[axis]} devices")
    key = (id(plan), kind, id(mesh), axis, interpret, d_tile)
    with _SHARDED_LOCK:
        fn = _SHARDED_EXEC.get(key)
        if fn is not None:
            _SHARDED_EXEC.move_to_end(key)
            return fn
    fn = _build_sharded_exec(plan, kind, mesh, axis, interpret, d_tile)
    with _SHARDED_LOCK:
        if key not in _SHARDED_EXEC:
            _SHARDED_EXEC[key] = fn
            weakref.finalize(plan, _evict_sharded_exec, id(plan))
            while len(_SHARDED_EXEC) > _SHARDED_EXEC_MAX:
                _SHARDED_EXEC.popitem(last=False)
        else:
            fn = _SHARDED_EXEC[key]
    return fn


def _evict_sharded_exec(pid: int) -> None:
    with _SHARDED_LOCK:
        for key in [k for k in _SHARDED_EXEC if k[0] == pid]:
            del _SHARDED_EXEC[key]


def sharded_rgcsr_spmv(plan: ShardedRgCSRPlan, x, *, mesh, axis: str,
                       interpret: bool | None = None):
    """y = A @ x over a 1-D mesh axis: one shard_map program, each device
    running the existing Pallas kernel on its row shard's local slice.

    ``x``: the full (n_cols,) vector; in ``'split'`` mode it is padded to
    ``n_shards · cols_per_shard`` and row-sharded over ``axis`` by GSPMD,
    in ``'replicated'`` mode it is broadcast.  Returns (n_rows,).
    """
    if interpret is None:
        interpret = default_interpret()
    fn = _sharded_exec(plan, "spmv", mesh, axis, interpret)
    args, _ = _sharded_args(plan)
    x = jnp.asarray(x)
    if plan.x_mode == "split":
        xw = plan.n_shards * plan.cols_per_shard
        x = jnp.zeros((xw,), x.dtype).at[: plan.n_cols].set(x)
    y = fn(*args, x)
    return y[: plan.n_rows]


def sharded_rgcsr_spmm(plan: ShardedRgCSRPlan, x, *, mesh, axis: str,
                       d_tile: int = LANES, interpret: bool | None = None):
    """Y = A @ X over a 1-D mesh axis (X dense (n_cols, d)) -> (n_rows, d)."""
    if interpret is None:
        interpret = default_interpret()
    fn = _sharded_exec(plan, "spmm", mesh, axis, interpret, d_tile)
    args, _ = _sharded_args(plan)
    x = jnp.asarray(x)
    if plan.x_mode == "split":
        xw = plan.n_shards * plan.cols_per_shard
        x = jnp.zeros((xw, x.shape[1]), x.dtype).at[: plan.n_cols].set(x)
    y = fn(*args, x)
    return y[: plan.n_rows, : x.shape[1]]


# ---------------------------------------------------------------------------
# Plans over SparseLinear parameter trees (serving path)
# ---------------------------------------------------------------------------

# Memo keyed on (id(columns2d), dtype, d_out, d_in, group_size) — the dims
# are part of the key so an entry built with different/misinferred dims can
# never shadow a caller's correct ones.  The stored strong reference to the
# source values array both validates the entry (values identity must match —
# a training step invalidates it) and keeps the id stable.
_PARAM_PLANS: "collections.OrderedDict[tuple, tuple]" = collections.OrderedDict()
_PARAM_PLANS_MAX = 64
_PARAM_PLANS_LOCK = threading.Lock()


def plan_from_params(params, dtype, *, d_out: int, d_in: int,
                     group_size: int) -> RgCSRPlan:
    """RgCSRPlan view over SparseLinear param arrays (no host repack —
    the params already live in the kernel's slot-major layout, cps=1).

    With concrete arrays (eager per-layer paths) the container is memoized
    so each layer's plan is built once per process (``Engine`` warms this at
    init); under jit tracing the memo is bypassed and the container is
    rebuilt per trace, which is free — the jit'd serving path never pays
    per-call host plan work by construction.
    """
    n_groups = -(-d_out // group_size)
    # either array traced means we're inside a transform (grad over values
    # closes over concrete structure buffers) — never memoize tracers
    tracing = (isinstance(params["columns2d"], jax.core.Tracer)
               or isinstance(params["values2d"], jax.core.Tracer))
    key = (id(params["columns2d"]), jnp.dtype(dtype).str, d_out, d_in,
           group_size)
    if not tracing:
        with _PARAM_PLANS_LOCK:
            entry = _PARAM_PLANS.get(key)
            if entry is not None and entry[0] is params["values2d"]:
                _PARAM_PLANS.move_to_end(key)
                return entry[1]
    values = params["values2d"]
    if values.dtype != jnp.dtype(dtype):   # avoid a same-dtype device copy
        values = values.astype(dtype)
    plan = RgCSRPlan(
        values2d=values,
        columns2d=params["columns2d"],
        step_group=params["chunk_group"],
        step_first=params["chunk_first"],
        n_rows=d_out, n_cols=d_in, n_groups=int(n_groups),
        group_size=group_size, chunks_per_step=1)
    if not tracing:
        with _PARAM_PLANS_LOCK:
            _PARAM_PLANS[key] = (params["values2d"], plan)
            while len(_PARAM_PLANS) > _PARAM_PLANS_MAX:
                _PARAM_PLANS.popitem(last=False)
    return plan


def param_plan_stats() -> Dict[str, int]:
    """Size of the SparseLinear param-plan memo (serving-path cache)."""
    with _PARAM_PLANS_LOCK:
        return {"entries": len(_PARAM_PLANS)}


def warm_plans_from_params(params, dtype=jnp.float32) -> int:
    """Pre-stage SpMM plans for every SparseLinear subtree in ``params``.

    Walks the parameter tree for the RgCSR layout signature
    (``values2d``/``columns2d``/``chunk_group``/``chunk_first``) and builds
    each layer's plan once so the first *eager* per-layer call pays no
    host-side plan work.  Scope limits, by construction:

    * the jit'd prefill/decode path assembles plan containers at trace time
      (free) and never consults this memo — warming helps eager paths only;
    * layer-stacked (3-D) sparse params are skipped — the stacked scan path
      only ever sees traced slices;
    * ``d_in``/``d_out`` are inferred from the buffers (max column + 1,
      ``n_groups·G``); an eager caller passing different exact dims simply
      misses this entry and builds its own (dims are part of the memo key —
      a misinferred warm entry can never shadow correct dims).

    Returns #plans warmed.
    """
    warmed = 0

    def visit(node) -> None:
        nonlocal warmed
        if not isinstance(node, dict):
            return
        if {"values2d", "columns2d", "chunk_group", "chunk_first"} <= set(node):
            if getattr(node["values2d"], "ndim", 0) == 2:
                g = int(node["columns2d"].shape[1])
                n_groups = int(np.asarray(node["chunk_group"])[-1]) + 1 \
                    if node["chunk_group"].shape[0] else 1
                d_in = int(np.asarray(node["columns2d"]).max()) + 1
                plan_from_params(node, dtype, d_out=n_groups * g,
                                 d_in=d_in, group_size=g)
                warmed += 1
            return
        for v in node.values():
            visit(v)

    visit(params)
    return warmed


# ---------------------------------------------------------------------------
# ELLPACK
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EllPlan:
    values2d: Any   # (K_pad, N_pad)
    columns2d: Any  # (K_pad, N_pad)
    n_rows: int
    n_cols: int


def make_ell_plan(m: ELLPACK) -> EllPlan:
    vals = np.asarray(m.values)
    cols = np.asarray(m.columns).astype(np.int32)
    k, n = vals.shape
    k_pad, n_pad = _pad_to(k, SUBLANES), _pad_to(n, LANES)
    vp = np.zeros((k_pad, n_pad), vals.dtype)
    cp = np.zeros((k_pad, n_pad), np.int32)
    vp[:k, :n] = vals
    cp[:k, :n] = cols
    return EllPlan(values2d=jnp.asarray(vp), columns2d=jnp.asarray(cp),
                   n_rows=m.shape[0], n_cols=m.shape[1])


def ell_spmv(plan: EllPlan, x, *, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    n_pad = _pad_to(max(plan.n_cols, 1), LANES)
    x_pad = jnp.zeros((1, n_pad), x.dtype).at[0, : plan.n_cols].set(x)
    y = ell_spmv_pallas(plan.values2d, plan.columns2d, x_pad,
                        interpret=interpret)
    return y[0, : plan.n_rows]
