"""Pallas TPU kernels for the paper's compute hot-spot (SpMV/SpMM).

Layout: ``rgcsr_spmv.py`` / ``rgcsr_spmm.py`` / ``ell_spmv.py`` hold the
``pl.pallas_call`` kernels with explicit BlockSpec VMEM tiling (chunk-
coarsened via ``chunks_per_step``, DESIGN.md §3); ``ops.py`` is the jit'd
public API (plans, the process-wide ``PlanCache`` + wrappers);
``autotune.py`` searches kernel configs per matrix signature; ``ref.py``
the pure-jnp oracles.
"""
from repro.kernels.ops import (  # noqa: F401
    PLAN_CACHE,
    EllPlan,
    PlanCache,
    RgCSRPlan,
    ell_spmv,
    get_plan,
    make_ell_plan,
    make_plan,
    rgcsr_spmm,
    rgcsr_spmv,
)
from repro.kernels.autotune import (  # noqa: F401
    TuneConfig,
    TuneResult,
    autotune_spmm,
    autotune_spmv,
    matrix_signature,
    spill_threshold_candidates,
    tuned_plan,
)
