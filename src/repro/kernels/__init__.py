"""Pallas TPU kernels for the paper's compute hot-spot (SpMV/SpMM).

Layout: ``rgcsr_spmv.py`` / ``rgcsr_spmm.py`` / ``ell_spmv.py`` hold the
``pl.pallas_call`` kernels with explicit BlockSpec VMEM tiling; ``ops.py`` is
the jit'd public API (plans + wrappers); ``ref.py`` the pure-jnp oracles.
"""
from repro.kernels.ops import (  # noqa: F401
    EllPlan,
    RgCSRPlan,
    ell_spmv,
    make_ell_plan,
    make_plan,
    rgcsr_spmm,
    rgcsr_spmv,
)
