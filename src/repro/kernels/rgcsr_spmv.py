"""RgCSR SpMV as a Pallas TPU kernel — the paper's CUDA kernel, TPU-native.

Mapping from the paper's CUDA kernel (§3.4) to TPU (DESIGN.md §2):

* CUDA: one *thread* per row; a thread-block of 128 threads = one group;
  per slot step, the 128 threads read 128 consecutive values/columns
  (coalesced 128-byte segments).
* TPU:  one *lane* per row; a group of ``G`` rows (G a multiple of 128) is a
  dense ``(K_g, G)`` tile in VMEM — slot ``k`` of all rows is one (or a few)
  full 128-lane vectors.  Reading slot-major tiles from HBM is the TPU
  equivalent of coalescing: contiguous, layout-aligned DMA.

The ragged group structure (K_g varies per group — the whole point of RgCSR
vs ELLPACK) is handled with a **chunk table** built at plan time:

* the flat grouped storage is reshaped to ``values2d/columns2d: (S, G)``
  where ``S = Σ_g K_g`` (each K_g padded to 8 sublanes);
* chunk ``c`` covers slot rows ``[8c, 8c+8)`` and belongs to exactly one
  group ``chunk_group[c]`` (K_g % 8 == 0 guarantees no chunk straddles);
* the grid is ``(num_chunks,)`` — *no* grid step is spent on nonexistent
  slots of short groups.  This realizes the paper's "skip meaningless
  arithmetic via rowLengths" at DMA granularity, which is what matters on a
  memory-bound op (the VPU flops on padding are free; the HBM bytes and
  grid steps are not).

``x`` is staged into VMEM whole (the paper's texture-cache remedy, made
explicit): valid while ``n * itemsize`` fits VMEM (≈4M fp32 elements).  The
per-slot gather ``x[columns]`` is an in-VMEM vector gather.  For larger
matrices, shard columns over the mesh (see repro.sharding) so each shard's
x-slice fits — the distributed extension of the paper's caching argument.

Scalar-prefetch carries ``chunk_group`` (output index map) and
``chunk_first`` (accumulator init).  The same output block is revisited only
by consecutive grid steps (chunks of a group are contiguous), which is the
Pallas TPU requirement for read-modify-write output accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES = 8
LANES = 128

__all__ = ["rgcsr_spmv_kernel", "rgcsr_spmv_pallas"]


def rgcsr_spmv_kernel(chunk_group_ref, chunk_first_ref,
                      values_ref, columns_ref, x_ref, y_ref):
    """Kernel body. Blocks: values/columns (8, G); x (1, n_pad) whole; y (1, G)."""
    c = pl.program_id(0)

    @pl.when(chunk_first_ref[c] == 1)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = values_ref[...]                          # (8, G)
    cols = columns_ref[...]                         # (8, G) int32
    x = x_ref[0, :]                                 # (n_pad,)
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
    y_ref[...] += jnp.sum(vals * gathered, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("n_groups", "group_size", "interpret"))
def rgcsr_spmv_pallas(chunk_group, chunk_first, values2d, columns2d, x_pad,
                      *, n_groups: int, group_size: int, interpret: bool = True):
    """Launch the RgCSR SpMV kernel.

    Args:
      chunk_group:  (num_chunks,) int32 — group id of each 8-slot chunk.
      chunk_first:  (num_chunks,) int32 — 1 iff first chunk of its group.
      values2d:     (S, G) slot-major values (S = total padded slots).
      columns2d:    (S, G) int32 column indices (ghost index 0 on padding).
      x_pad:        (1, n_pad) the dense vector, lane-padded.
      n_groups, group_size: static layout parameters.
      interpret:    run in interpret mode (CPU validation) or compile for TPU.

    Returns:
      (n_groups, G) per-group result rows; caller reshapes/unpads.
    """
    num_chunks = chunk_group.shape[0]
    g = group_size

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_chunks,),
        in_specs=[
            pl.BlockSpec((SUBLANES, g), lambda c, cg, cf: (c, 0)),
            pl.BlockSpec((SUBLANES, g), lambda c, cg, cf: (c, 0)),
            pl.BlockSpec((1, x_pad.shape[1]), lambda c, cg, cf: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g), lambda c, cg, cf: (cg[c], 0)),
    )
    return pl.pallas_call(
        rgcsr_spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_groups, g), values2d.dtype),
        interpret=interpret,
    )(chunk_group, chunk_first, values2d, columns2d, x_pad)
