"""RgCSR SpMV as a Pallas TPU kernel — the paper's CUDA kernel, TPU-native.

Mapping from the paper's CUDA kernel (§3.4) to TPU (DESIGN.md §2):

* CUDA: one *thread* per row; a thread-block of 128 threads = one group;
  per slot step, the 128 threads read 128 consecutive values/columns
  (coalesced 128-byte segments).
* TPU:  one *lane* per row; a group of ``G`` rows (G a multiple of 128) is a
  dense ``(K_g, G)`` tile in VMEM — slot ``k`` of all rows is one (or a few)
  full 128-lane vectors.  Reading slot-major tiles from HBM is the TPU
  equivalent of coalescing: contiguous, layout-aligned DMA.

The ragged group structure (K_g varies per group — the whole point of RgCSR
vs ELLPACK) is handled with a **step table** built at plan time
(DESIGN.md §3):

* the flat grouped storage is reshaped to ``values2d/columns2d: (S, G)``
  where ``S = Σ_g K_g`` (each K_g padded to ``8 · chunks_per_step``
  sublanes);
* grid step ``s`` covers slot rows ``[R·s, R·(s+1))`` with
  ``R = 8 · chunks_per_step`` and belongs to exactly one group
  ``step_group[s]`` (K_g % R == 0 guarantees no step straddles a group);
* the grid is ``(num_steps, x_tiles)`` — *no* grid step is spent on
  nonexistent slots of short groups.  This realizes the paper's "skip
  meaningless arithmetic via rowLengths" at DMA granularity, which is what
  matters on a memory-bound op (the VPU flops on padding are free; the HBM
  bytes and grid steps are not).

**Chunk coarsening** (``chunks_per_step`` ∈ {1, 2, 4, 8}): one grid step
processes ``chunks_per_step`` 8-slot chunks of the same group, accumulating
across the coarsened tile in-kernel.  Fewer grid steps → less per-step
launch/DMA-descriptor overhead and a larger contiguous matrix DMA per step;
the cost is padding short groups up to the coarsened tile (masked by exact
zeros placed at plan time via the chunk table).  The autotuner
(:mod:`repro.kernels.autotune`) measures this trade per matrix.

**Column-tiled x staging**: ``x`` is staged into VMEM in ``(1, XT)`` tiles
instead of whole (the paper's texture-cache remedy, bounded): the inner grid
dimension walks the tiles and per-element contributions outside the resident
tile are masked.  With a single tile (``n_pad <= XT``) the kernel is
bit-identical in structure to the uncoarsened seed kernel; with many tiles,
matrices whose ``n_cols · itemsize`` exceeds the VMEM budget no longer fall
off a cliff (previously: whole-``x`` staging failed or thrashed for
``n ≳ 4M`` fp32 elements).  For distributed runs, additionally shard columns
over the mesh (see repro.sharding).

Scalar-prefetch carries ``step_group`` (output index map) and ``step_first``
(accumulator init).  The same output block is revisited only by consecutive
grid steps (steps of a group are contiguous, and all x-tiles of one step are
consecutive inner iterations), which is the Pallas TPU requirement for
read-modify-write output accumulation.

**Permuted row space** (adaptive plans, DESIGN.md §5): the kernel is
deliberately agnostic to *which* rows a group holds — the step table is the
only output index map, and the accumulator init (``step_first``) fires on
each group's first step regardless of row identity.  An adaptive plan
exploits this: its groups hold length-sorted rows, so ``y_ref`` rows are in
the permuted space and the wrapper's fused epilogue
(:func:`repro.kernels.ops._adaptive_finish_spmv`) gathers them back to
original row order and adds the COO spill tail.  No kernel change needed —
the permutation lives entirely in plan metadata.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES = 8
LANES = 128

# Candidate coarsening factors: how many 8-slot chunks one grid step covers.
CHUNKS_PER_STEP_CHOICES = (1, 2, 4, 8)

__all__ = ["rgcsr_spmv_kernel", "rgcsr_spmv_pallas",
           "CHUNKS_PER_STEP_CHOICES", "SUBLANES", "LANES"]


def rgcsr_spmv_kernel(step_group_ref, step_first_ref,
                      values_ref, columns_ref, x_ref, y_ref,
                      *, x_tiled: bool):
    """Kernel body.

    Blocks: values/columns ``(R, G)`` with ``R = 8·chunks_per_step``;
    x ``(1, XT)`` column tile; y ``(1, G)``.

    ``x_tiled`` is static: with a single x tile the gather is unmasked
    (identical arithmetic to the seed kernel); with several tiles each
    element's contribution is masked to the resident tile.
    """
    s = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when((step_first_ref[s] == 1) & (t == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = values_ref[...]                          # (R, G)
    cols = columns_ref[...]                         # (R, G) int32
    x = x_ref[0, :]                                 # (XT,)
    if x_tiled:
        xt = x_ref.shape[1]
        local = cols - t * xt
        in_tile = (local >= 0) & (local < xt)
        safe = jnp.clip(local, 0, xt - 1)
        gathered = jnp.take(x, safe.reshape(-1), axis=0).reshape(cols.shape)
        prods = jnp.where(in_tile, vals * gathered, jnp.zeros_like(vals))
    else:
        gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
        prods = vals * gathered
    y_ref[...] += jnp.sum(prods, axis=0, keepdims=True)


@functools.partial(
    jax.jit,
    static_argnames=("n_groups", "group_size", "chunks_per_step", "x_tile",
                     "interpret"))
def rgcsr_spmv_pallas(step_group, step_first, values2d, columns2d, x_pad,
                      *, n_groups: int, group_size: int,
                      chunks_per_step: int = 1, x_tile: int | None = None,
                      interpret: bool = True):
    """Launch the RgCSR SpMV kernel.

    Args:
      step_group:   (num_steps,) int32 — group id of each coarsened step.
      step_first:   (num_steps,) int32 — 1 iff first step of its group.
      values2d:     (S, G) slot-major values (S = total padded slots; every
                    group's slot count is a multiple of 8·chunks_per_step).
      columns2d:    (S, G) int32 column indices (ghost index 0 on padding).
      x_pad:        (1, n_pad) the dense vector, padded to a multiple of
                    ``x_tile`` (or of 128 when untiled).
      n_groups, group_size, chunks_per_step: static layout parameters.
      x_tile:       x column-tile width (multiple of 128 dividing n_pad);
                    None stages x whole (seed behaviour).
      interpret:    run in interpret mode (CPU validation) or compile for TPU.

    Returns:
      (n_groups, G) per-group result rows; caller reshapes/unpads.
    """
    num_steps = step_group.shape[0]
    g = group_size
    rows_per_step = chunks_per_step * SUBLANES
    n_pad = x_pad.shape[1]
    xt = n_pad if x_tile is None else x_tile
    if n_pad % xt:
        raise ValueError(f"x_tile {xt} must divide padded x width {n_pad}")
    n_x_tiles = n_pad // xt

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_steps, n_x_tiles),
        in_specs=[
            pl.BlockSpec((rows_per_step, g), lambda s, t, sg, sf: (s, 0)),
            pl.BlockSpec((rows_per_step, g), lambda s, t, sg, sf: (s, 0)),
            pl.BlockSpec((1, xt), lambda s, t, sg, sf: (0, t)),
        ],
        out_specs=pl.BlockSpec((1, g), lambda s, t, sg, sf: (sg[s], 0)),
    )
    kernel = functools.partial(rgcsr_spmv_kernel, x_tiled=n_x_tiles > 1)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_groups, g), values2d.dtype),
        interpret=interpret,
    )(step_group, step_first, values2d, columns2d, x_pad)
