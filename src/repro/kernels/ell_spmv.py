"""ELLPACK SpMV Pallas TPU kernel (the paper's comparison format, Fig. 3).

ELLPACK is the degenerate RgCSR with a single group = the whole matrix, so
the kernel is the same slot-major FMA without any chunk table: grid
``(col_tiles, slot_tiles)`` with the slot dim innermost so each output tile
accumulates consecutively.  Used by the Hybrid format's ELL part; the COO
spill runs as a jnp segment-sum (irregular scatter has no efficient TPU
kernel — that asymmetry is itself a finding the paper's GPU Hybrid did not
have, recorded in EXPERIMENTS.md §Table3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8
LANES = 128

__all__ = ["ell_spmv_kernel", "ell_spmv_pallas"]


def ell_spmv_kernel(values_ref, columns_ref, x_ref, y_ref):
    """Blocks: values/columns (8, R); x (1, n_pad); y (1, R)."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    vals = values_ref[...]                          # (8, R)
    cols = columns_ref[...]
    x = x_ref[0, :]
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape)
    y_ref[...] += jnp.sum(vals * gathered, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def ell_spmv_pallas(values2d, columns2d, x_pad, *, row_tile: int = LANES,
                    interpret: bool = True):
    """values2d/columns2d: (K_pad, N_pad) slot-major; x_pad: (1, n_pad).
    Returns (1, N_pad)."""
    k_pad, n_rows_pad = values2d.shape
    slot_tiles = k_pad // SUBLANES
    row_tiles = n_rows_pad // row_tile

    return pl.pallas_call(
        ell_spmv_kernel,
        grid=(row_tiles, slot_tiles),
        in_specs=[
            pl.BlockSpec((SUBLANES, row_tile), lambda r, k: (k, r)),
            pl.BlockSpec((SUBLANES, row_tile), lambda r, k: (k, r)),
            pl.BlockSpec((1, x_pad.shape[1]), lambda r, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, row_tile), lambda r, k: (0, r)),
        out_shape=jax.ShapeDtypeStruct((1, n_rows_pad), values2d.dtype),
        interpret=interpret,
    )(values2d, columns2d, x_pad)
