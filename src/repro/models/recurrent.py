"""Recurrent sequence mixers: Mamba-2 (SSD) and RG-LRU (Griffin/RecurrentGemma).

Both are the *sub-quadratic* archs of the assignment: decode state is O(1) in
sequence length, which is what makes the ``long_500k`` cell natively runnable
(DESIGN.md §9).

Mamba-2 uses the SSD (state-space duality) chunked algorithm [arXiv:2405.21060]:
intra-chunk attention-like matmuls + an inter-chunk state scan — matmul-heavy
and therefore MXU-friendly, unlike the elementwise selective scan of Mamba-1.

RG-LRU follows Griffin [arXiv:2402.19427]: gated linear recurrence
``h_t = a_t h_{t-1} + sqrt(1-a_t²)(i_t ⊙ x_t)`` with input-dependent
``a_t = exp(-c·softplus(Λ)·r_t)``, computed with an associative scan over
time (log-space products are unnecessary since a_t ∈ (0,1) is well-behaved).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import P, dense, dense_spec, rmsnorm, rmsnorm_spec

__all__ = [
    "mamba2_spec", "mamba2_apply", "init_mamba2_state", "mamba2_decode",
    "rglru_spec", "rglru_apply", "init_rglru_state", "rglru_decode",
]


# ---------------------------------------------------------------------------
# causal depthwise conv1d (shared by both mixers)
# ---------------------------------------------------------------------------


def _conv_spec(channels: int, width: int):
    return {"w": P((width, channels), (None, "conv_ch"), init="fan_in"),
            "b": P((channels,), ("conv_ch",), init="zeros")}


def _causal_conv(params, x):
    """x: (B, L, C) depthwise causal conv, width = params['w'].shape[0]."""
    w = params["w"].astype(x.dtype)       # (W, C)
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(width))
    return out + params["b"].astype(x.dtype)


def _conv_step(params, state, x_t):
    """state: (B, W-1, C); x_t: (B, C) -> (y_t, new_state)."""
    w = params["w"].astype(x_t.dtype)
    hist = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", hist, w) + params["b"].astype(x_t.dtype)
    return y, hist[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-2 / SSD
# ---------------------------------------------------------------------------


def _mamba_dims(cfg):
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.head_dim
    d_xbc = d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
    return d_inner, n_heads, d_xbc


def mamba2_spec(cfg):
    d = cfg.d_model
    d_inner, n_heads, d_xbc = _mamba_dims(cfg)
    return {
        "in_proj": dense_spec(d, 2 * d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
                              + n_heads, ("embed", "mlp")),
        "conv": _conv_spec(d_xbc, cfg.ssm.d_conv),
        "dt_bias": P((n_heads,), ("ssm_heads",), init="zeros"),
        # NOTE: init uses s[-1] + broadcast so layer-stacking (leading dims
        # prepended by the pattern scan) keeps the per-head spacing.
        "a_log": P((n_heads,), ("ssm_heads",),
                   init=lambda k, s, dt: jnp.broadcast_to(
                       jnp.log(jnp.linspace(1.0, 16.0, s[-1])), s).astype(dt)),
        "d_skip": P((n_heads,), ("ssm_heads",), init="ones"),
        "out_norm": rmsnorm_spec(d_inner),
        "out_proj": dense_spec(d_inner, d, ("mlp", "embed")),
    }


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j<k<=i} x[..., k] (i>=j)."""
    t = x.shape[-1]
    x_cum = jnp.cumsum(x, axis=-1)
    diff = x_cum[..., :, None] - x_cum[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(x, dt, a, b, c, chunk: int):
    """SSD forward. x: (B,L,H,P) dt: (B,L,H) a: (H,) b,c: (B,L,G,N).

    Returns y: (B,L,H,P) and final state (B,H,P,N).
    """
    bsz, l_orig, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    # pad seq to a chunk multiple: dt=0 padding is exact (decay 1, input 0 —
    # the state passes through unchanged, so h_last is unaffected)
    pad = (-l_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l = l_orig + pad
    nc = l // chunk
    rep = h // g

    def reshape_c(t):  # (B, L, ...) -> (B, nc, chunk, ...)
        return t.reshape(bsz, nc, chunk, *t.shape[2:])

    xc, dtc = reshape_c(x), reshape_c(dt)
    bc = jnp.repeat(reshape_c(b), rep, axis=3)     # (B,nc,Q,H,N)
    cc = jnp.repeat(reshape_c(c), rep, axis=3)
    da = dtc * a[None, None, None, :]              # (B,nc,Q,H) negative
    da_cs = jnp.cumsum(da, axis=2)                 # within-chunk cumsum
    da_total = da_cs[:, :, -1, :]                  # (B,nc,H)

    # intra-chunk (quadratic inside the chunk only)
    lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))        # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc)        # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp",
                         scores * lmat, dtc, xc)

    # per-chunk input states
    decay_to_end = jnp.exp(da_total[:, :, None, :] - da_cs)  # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn",
                        bc, decay_to_end, dtc, xc)           # (B,nc,H,P,N)

    # inter-chunk recurrence over nc (sequential scan, tiny: nc steps)
    def step(h_prev, inputs):
        st, dtot = inputs
        h_new = jnp.exp(dtot)[..., None, None] * h_prev + st
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, p, n), x.dtype)
    h_last, h_prevs = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   da_total.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp",
                         cc, jnp.exp(da_cs), h_prevs)
    y = (y_intra + y_inter).reshape(bsz, l, h, p)[:, :l_orig]
    return y, h_last


def mamba2_apply(params, cfg, x, *, return_state: bool = False):
    """Full-sequence Mamba-2 mixer. x: (B, L, d) -> (B, L, d).

    With ``return_state`` also returns the end-of-sequence recurrent state
    (conv tail + SSM state) so prefill can hand off to one-token decode.
    """
    bsz, l, _ = x.shape
    d_inner, n_heads, d_xbc = _mamba_dims(cfg)
    ssm = cfg.ssm

    zxbcdt = dense(params["in_proj"], x)
    z = zxbcdt[..., :d_inner]
    xbc_raw = zxbcdt[..., d_inner: d_inner + d_xbc]
    dt_raw = zxbcdt[..., d_inner + d_xbc:]

    xbc = jax.nn.silu(_causal_conv(params["conv"], xbc_raw))
    xs = xbc[..., :d_inner].reshape(bsz, l, n_heads, ssm.head_dim)
    b = xbc[..., d_inner: d_inner + ssm.n_groups * ssm.d_state]
    c = xbc[..., d_inner + ssm.n_groups * ssm.d_state:]
    b = b.reshape(bsz, l, ssm.n_groups, ssm.d_state)
    c = c.reshape(bsz, l, ssm.n_groups, ssm.d_state)
    # shard SSD heads over TP (48 % 16 == 0 for mamba2-780m); without this
    # GSPMD replicates the whole chunked-scan compute on every model shard
    from repro.models.shardlib import constrain
    if n_heads % 8 == 0:
        xs = constrain(cfg, xs, "batch", None, "model", None)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    y, h_last = _ssd_chunked(xs.astype(jnp.float32), dt, a,
                             b.astype(jnp.float32), c.astype(jnp.float32),
                             ssm.chunk)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(bsz, l, d_inner).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    out = dense(params["out_proj"], y)
    if return_state:
        conv_tail = xbc_raw[:, -(ssm.d_conv - 1):, :].astype(
            jnp.dtype(cfg.dtype))
        return out, {"conv": conv_tail, "ssm": h_last}
    return out


def init_mamba2_state(cfg, batch: int):
    d_inner, n_heads, d_xbc = _mamba_dims(cfg)
    ssm = cfg.ssm
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, d_xbc), jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((batch, n_heads, ssm.head_dim, ssm.d_state),
                         jnp.float32),
    }


def mamba2_decode(params, cfg, state, x_t):
    """One-token step. x_t: (B, d). Returns (y_t, new_state) — O(1) in seq."""
    bsz = x_t.shape[0]
    d_inner, n_heads, d_xbc = _mamba_dims(cfg)
    ssm = cfg.ssm

    zxbcdt = dense(params["in_proj"], x_t)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: d_inner + d_xbc]
    dt_raw = zxbcdt[..., d_inner + d_xbc:]

    xbc, conv_state = _conv_step(params["conv"], state["conv"], xbc)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner].reshape(bsz, n_heads, ssm.head_dim)
    b = xbc[..., d_inner: d_inner + ssm.n_groups * ssm.d_state]
    c = xbc[..., d_inner + ssm.n_groups * ssm.d_state:]
    rep = n_heads // ssm.n_groups
    b = jnp.repeat(b.reshape(bsz, ssm.n_groups, ssm.d_state), rep, axis=1)
    c = jnp.repeat(c.reshape(bsz, ssm.n_groups, ssm.d_state), rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)                                           # (B,H)

    h = state["ssm"]
    h = da[..., None, None] * h + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, b.astype(jnp.float32), xs.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", c.astype(jnp.float32), h)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(bsz, d_inner).astype(x_t.dtype)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y), {"conv": conv_state, "ssm": h}


# ---------------------------------------------------------------------------
# RG-LRU (Griffin recurrent block)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_spec(cfg):
    d = cfg.d_model
    d_rnn = d  # RecurrentGemma: lru width == d_model
    return {
        "gate_proj": dense_spec(d, d_rnn, ("embed", "mlp")),
        "x_proj": dense_spec(d, d_rnn, ("embed", "mlp")),
        "conv": _conv_spec(d_rnn, 4),
        "rg_w": dense_spec(d_rnn, d_rnn, ("mlp", "mlp2")),   # recurrence gate
        "in_w": dense_spec(d_rnn, d_rnn, ("mlp", "mlp2")),   # input gate
        # Griffin init: a ∈ [0.9, 0.999] at r=1 → Λ = softplus⁻¹(-log a / c)
        # (uses s[-1] + broadcast: layer-stacking-safe, see a_log above)
        "lam": P((d_rnn,), ("mlp",),
                 init=lambda k, s, dt: jnp.broadcast_to(jnp.log(jnp.expm1(
                     -jnp.log(jnp.linspace(0.9, 0.999, s[-1])) / _RGLRU_C
                 )), s).astype(dt)),
        "out_proj": dense_spec(d_rnn, d, ("mlp", "embed")),
    }


def _rglru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t over axis 1, associative scan (log-depth)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_apply(params, cfg, x, *, return_state: bool = False):
    """Griffin recurrent block, full sequence. x: (B, L, d)."""
    gate = jax.nn.gelu(dense(params["gate_proj"], x))
    u_raw = dense(params["x_proj"], x)
    u = _causal_conv(params["conv"], u_raw)

    r = jax.nn.sigmoid(dense(params["rg_w"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["in_w"], u).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-6)) * (
        i * u.astype(jnp.float32))
    h = _rglru_scan(a, gated_in)
    y = (h.astype(x.dtype) * gate)
    out = dense(params["out_proj"], y)
    if return_state:
        state = {"conv": u_raw[:, -3:, :].astype(jnp.dtype(cfg.dtype)),
                 "h": h[:, -1, :]}
        return out, state
    return out


def init_rglru_state(cfg, batch: int):
    d_rnn = cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, d_rnn), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
    }


def rglru_decode(params, cfg, state, x_t):
    gate = jax.nn.gelu(dense(params["gate_proj"], x_t))
    u = dense(params["x_proj"], x_t)
    u, conv_state = _conv_step(params["conv"], state["conv"], u)

    r = jax.nn.sigmoid(dense(params["rg_w"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["in_w"], u).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    h = a * state["h"] + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-6)) * (
        i * u.astype(jnp.float32))
    y = (h.astype(x_t.dtype) * gate)
    return dense(params["out_proj"], y), {"conv": conv_state, "h": h}
