"""Block assembly + pattern-scanned layer stacks.

Layer kinds (``cfg.layer_pattern`` / ``cfg.prefix_pattern``):

=============  ==========================================================
``attn``       global attention (GQA or MLA per ``cfg.attn_kind``) + FFN
``attn_local`` sliding-window attention + FFN
``moe``        global attention + MoE FFN
``ssm``        Mamba-2 SSD mixer (no separate FFN — Mamba-2 stacks are pure)
``rec``        RG-LRU temporal block + FFN (Griffin residual pattern)
``enc_attn``   bidirectional attention + FFN (encoder)
``dec_attn``   causal self-attn + cross-attn + FFN (decoder)
=============  ==========================================================

**Pattern scan**: the layer list is ``prefix_pattern`` (unrolled) followed by
``layer_pattern`` repeated R times.  The repeated body is executed with
``jax.lax.scan`` over stacked parameters, so compiled HLO size is O(period),
not O(n_layers) — essential for 61-layer × 512-device dry-run compiles on a
single CPU core, and the production-standard layout for checkpointing.
Mixed patterns (RecurrentGemma's rec,rec,attn_local) scan over whole periods
with the period unrolled inside the body.

Remat: ``cfg.remat`` ∈ {none, full, dots} wraps the period body in
``jax.checkpoint`` with the matching policy — the activation-memory knob the
§Perf pass tunes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import recurrent as rec_mod
from repro.models.ffn import ffn_apply, ffn_spec
from repro.models.layers import rmsnorm, rmsnorm_spec
from repro.models.moe import moe_apply, moe_spec
from repro.models.spec import P

__all__ = ["block_spec", "block_apply", "stack_spec", "stack_apply",
           "init_block_cache", "stack_cache_spec"]


def _attn_spec(cfg):
    return attn_mod.mla_spec(cfg) if cfg.attn_kind == "mla" \
        else attn_mod.gqa_spec(cfg)


def _attn_apply(params, cfg, x, positions, *, mode, cache, window):
    if cfg.attn_kind == "mla":
        return attn_mod.mla_apply(params, cfg, x, positions, mode=mode,
                                  cache=cache, window=window)
    return attn_mod.gqa_apply(params, cfg, x, positions, mode=mode,
                              cache=cache, window=window)


def block_spec(cfg, kind: str):
    d = cfg.d_model
    spec: Dict[str, Any] = {"ln1": rmsnorm_spec(d)}
    if kind in ("attn", "moe", "attn_local", "enc_attn", "dec_attn"):
        spec["attn"] = _attn_spec(cfg)
    elif kind == "ssm":
        spec["mixer"] = rec_mod.mamba2_spec(cfg)
        return spec                      # no FFN in Mamba-2 stacks
    elif kind == "rec":
        spec["rec"] = rec_mod.rglru_spec(cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if kind == "dec_attn":
        spec["ln_cross"] = rmsnorm_spec(d)
        spec["cross"] = attn_mod.gqa_spec(cfg)
    spec["ln2"] = rmsnorm_spec(d)
    spec["ffn"] = moe_spec(cfg) if kind == "moe" else ffn_spec(cfg)
    return spec


def _effective_window(cfg, kind: str, shape_kind: str) -> Optional[int]:
    if kind == "attn_local":
        return cfg.window
    if shape_kind == "long_decode" and not cfg.is_subquadratic:
        # DESIGN.md §9: full-attention archs fall back to a sliding window
        # at 500k (recorded as `fallback` in every table row).
        return cfg.fallback_window
    return None


def block_apply(params, cfg, kind: str, x, positions, *, mode: str = "train",
                shape_kind: str = "train", cache=None, enc_out=None):
    """One residual block.  Returns (x, new_cache, aux)."""
    aux = {}
    h = rmsnorm(params["ln1"], x)
    window = _effective_window(cfg, kind, shape_kind)

    if kind == "ssm":
        if cache is not None and mode == "decode":
            y, new_state = rec_mod.mamba2_decode(params["mixer"], cfg,
                                                 cache, h[:, 0, :])
            return x + y[:, None, :], new_state, aux
        if cache is not None:  # prefill: hand the prompt state to decode
            y, new_state = rec_mod.mamba2_apply(params["mixer"], cfg, h,
                                                return_state=True)
            return x + y, new_state, aux
        y = rec_mod.mamba2_apply(params["mixer"], cfg, h)
        return x + y, cache, aux

    if kind == "rec":
        if cache is not None and mode == "decode":
            y, new_state = rec_mod.rglru_decode(params["rec"], cfg,
                                                cache, h[:, 0, :])
            x = x + y[:, None, :]
            new_cache = new_state
        elif cache is not None:  # prefill
            y, new_cache = rec_mod.rglru_apply(params["rec"], cfg, h,
                                               return_state=True)
            x = x + y
        else:
            x = x + rec_mod.rglru_apply(params["rec"], cfg, h)
            new_cache = cache
    else:
        attn_mode = "full" if kind == "enc_attn" else "causal"
        has_cross_cache = isinstance(cache, dict) and "ck" in cache
        self_cache = cache["self"] if has_cross_cache else cache
        y, new_self = _attn_apply(params["attn"], cfg, h, positions,
                                  mode=attn_mode, cache=self_cache,
                                  window=window)
        x = x + y
        new_cache = new_self
        if kind == "dec_attn":
            hc = rmsnorm(params["ln_cross"], x)
            if has_cross_cache:
                yc = attn_mod.cross_attend_cached(params["cross"], cfg, hc,
                                                  cache["ck"], cache["cv"])
                new_cache = {"self": new_self, "ck": cache["ck"],
                             "cv": cache["cv"]}
            else:
                yc, _ = attn_mod.gqa_apply(params["cross"], cfg, hc,
                                           positions, mode="cross",
                                           cache=None, kv_x=enc_out)
            x = x + yc

    h2 = rmsnorm(params["ln2"], x)
    if kind == "moe":
        y2, aux = moe_apply(params["ffn"], cfg, h2,
                            dropless=mode != "train")
    else:
        y2 = ffn_apply(params["ffn"], cfg, h2)
    return x + y2, new_cache, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_block_cache(cfg, kind: str, batch: int, s_max: int,
                     shape_kind: str = "decode", enc_len: int = 0,
                     paging=None):
    """``paging``: an :class:`attn_mod.PageGeometry` — full-attention KV
    caches become shared page pools addressed per slot through block
    tables.  Windowed layers keep their dense rings (already O(window)
    residency), and recurrent state is position-free, so only the
    unbounded dense slabs change layout."""
    window = _effective_window(cfg, kind, shape_kind)
    if kind == "ssm":
        return rec_mod.init_mamba2_state(cfg, batch)
    if kind == "rec":
        return rec_mod.init_rglru_state(cfg, batch)
    paged = paging is not None and not window and kind != "dec_attn"
    if cfg.attn_kind == "mla":
        if paged:
            return attn_mod.init_mla_paged_cache(cfg, batch, paging)
        return attn_mod.init_mla_cache(cfg, batch, s_max, window)
    if paged:
        return attn_mod.init_gqa_paged_cache(cfg, batch, paging)
    cache = attn_mod.init_gqa_cache(cfg, batch, s_max, window)
    if kind == "dec_attn" and enc_len:
        hkv, dh = cfg.n_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        cache = {"self": cache,
                 "ck": jnp.zeros((batch, enc_len, hkv, dh), dt),
                 "cv": jnp.zeros((batch, enc_len, hkv, dh), dt)}
    return cache


# ---------------------------------------------------------------------------
# stacked layers (prefix unrolled + body pattern-scanned)
# ---------------------------------------------------------------------------


def _stack_p(p: P, r: int) -> P:
    return P((r,) + p.shape, ("layers",) + p.axes, init=p.init,
             scale=p.scale, dtype=p.dtype)


def _stack_spec_tree(spec, r: int):
    return jax.tree_util.tree_map(lambda p: _stack_p(p, r), spec,
                                  is_leaf=lambda x: isinstance(x, P))


def stack_spec(cfg):
    """Spec for the whole layer stack."""
    r = cfg.pattern_repeats
    spec = {
        "prefix": {f"{i}_{kind}": block_spec(cfg, kind)
                   for i, kind in enumerate(cfg.prefix_pattern)},
        "body": {f"{i}_{kind}": _stack_spec_tree(block_spec(cfg, kind), r)
                 for i, kind in enumerate(cfg.layer_pattern)},
    }
    return spec


def stack_cache_spec(cfg, batch: int, s_max: int, shape_kind: str,
                     enc_len: int = 0, paging=None):
    """Concrete (zeros) caches for the stack, matching stack_apply's layout."""
    r = cfg.pattern_repeats
    prefix = {f"{i}_{kind}": init_block_cache(cfg, kind, batch, s_max,
                                              shape_kind, enc_len, paging)
              for i, kind in enumerate(cfg.prefix_pattern)}

    def stacked(kind):
        one = init_block_cache(cfg, kind, batch, s_max, shape_kind, enc_len,
                               paging)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (r,) + a.shape).copy(), one)

    body = {f"{i}_{kind}": stacked(kind)
            for i, kind in enumerate(cfg.layer_pattern)}
    return {"prefix": prefix, "body": body}


def _remat_wrap(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return fn


def stack_apply(params, cfg, x, positions, *, mode: str = "train",
                shape_kind: str = "train", caches=None, enc_out=None):
    """Run the full stack.  Returns (x, new_caches, aux_sums)."""
    aux_sum = {"load_balance": jnp.zeros((), jnp.float32),
               "router_z": jnp.zeros((), jnp.float32)}
    new_prefix = {}
    for i, kind in enumerate(cfg.prefix_pattern):
        name = f"{i}_{kind}"
        cache = caches["prefix"][name] if caches else None
        x, new_cache, aux = block_apply(
            params["prefix"][name], cfg, kind, x, positions, mode=mode,
            shape_kind=shape_kind, cache=cache, enc_out=enc_out)
        new_prefix[name] = new_cache
        for k in aux_sum:
            if k in aux:
                aux_sum[k] += aux[k]

    r = cfg.pattern_repeats

    def period_body(carry, xs):
        x, aux_c = carry
        body_params, body_caches = xs
        new_caches_step = {}
        for i, kind in enumerate(cfg.layer_pattern):
            name = f"{i}_{kind}"
            cache = body_caches[name] if body_caches is not None else None
            x, new_cache, aux = block_apply(
                body_params[name], cfg, kind, x, positions, mode=mode,
                shape_kind=shape_kind, cache=cache, enc_out=enc_out)
            new_caches_step[name] = new_cache
            for k in aux_c:
                if k in aux:
                    aux_c = dict(aux_c)
                    aux_c[k] = aux_c[k] + aux[k]
        return (x, aux_c), new_caches_step

    body_caches = caches["body"] if caches else None
    body_fn = _remat_wrap(cfg, period_body)
    if body_caches is None:
        (x, aux_sum), _ = jax.lax.scan(
            lambda c, p: (body_fn(c, (p, None))[0], None),
            (x, aux_sum), params["body"])
        new_body = None
    else:
        (x, aux_sum), new_body = jax.lax.scan(
            body_fn, (x, aux_sum), (params["body"], body_caches))
    new_caches = None
    if caches is not None:
        new_caches = {"prefix": new_prefix, "body": new_body}
    return x, new_caches, aux_sum
