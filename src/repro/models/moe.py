"""Mixture-of-Experts: top-k routing, shared experts, EP-shardable compute.

Two dispatch implementations, selected by ``cfg.moe.dispatch``:

* ``einsum``  — the GShard/Switch one-hot dispatch+combine einsums.  This is
  the *paper-faithful production baseline* on TPU (GShard, GLaM, Switch all
  shipped this way): simple, fully SPMD-shardable over the ``experts`` axis…
  and it burns ``O(T·E·C·d)`` FLOPs moving tokens.  The roofline §Perf pass
  measures exactly that overhead (MODEL_FLOPS/HLO ratio).

* ``scatter`` — the optimized path: tokens are *sorted* by expert and moved
  with flop-free gathers/scatters (MegaBlocks-style dense-to-ragged without
  the custom kernel).  Same math, ~zero dispatch FLOPs; the §Perf log
  records the measured HLO-FLOP delta on the DeepSeek-V3 cell.

DeepSeek-V3 specifics: sigmoid scoring + aux-loss-free bias (a non-learned
buffer added to scores for *selection only*), shared experts always on, and
normalized top-k combine weights [arXiv:2412.19437 §2.1.2].
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import P, dense_spec
from repro.models.ffn import ffn_apply_stacked

__all__ = ["moe_spec", "moe_apply"]


def moe_spec(cfg):
    d, m = cfg.d_model, cfg.moe
    spec = {
        "router": {"kernel": P((d, m.n_experts), ("embed", "experts"),
                               init="fan_in")},
        "experts": {
            "w_in": P((m.n_experts, d, m.d_ff_expert),
                      ("experts", "embed", "mlp"), init="fan_in"),
            "w_gate": P((m.n_experts, d, m.d_ff_expert),
                        ("experts", "embed", "mlp"), init="fan_in"),
            "w_out": P((m.n_experts, m.d_ff_expert, d),
                       ("experts", "mlp", "embed"), init="fan_in"),
        },
    }
    if m.aux_free_bias:
        # selection-bias buffer (updated outside the gradient, DeepSeek-V3)
        spec["router"]["bias"] = P((m.n_experts,), ("experts",), init="zeros")
    if m.n_shared:
        spec["shared"] = {
            "w_in": dense_spec(d, m.n_shared * m.d_ff_expert, ("embed", "mlp")),
            "w_gate": dense_spec(d, m.n_shared * m.d_ff_expert, ("embed", "mlp")),
            "w_out": dense_spec(m.n_shared * m.d_ff_expert, d, ("mlp", "embed")),
        }
    return spec


def _routing(params, cfg, x_flat):
    """Returns (expert_idx (T,k), combine_w (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"]["kernel"].astype(jnp.float32))
    if m.score_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    select = scores
    if m.aux_free_bias and "bias" in params["router"]:
        select = scores + jax.lax.stop_gradient(
            params["router"]["bias"].astype(jnp.float32))[None, :]
    _, idx = jax.lax.top_k(select, m.top_k)                       # (T, k)
    gathered = jnp.take_along_axis(scores, idx, axis=-1)          # (T, k)
    if m.score_fn == "sigmoid":
        w = gathered / (jnp.sum(gathered, axis=-1, keepdims=True) + 1e-9)
    else:
        w = gathered / (jnp.sum(gathered, axis=-1, keepdims=True) + 1e-9)

    # Switch-style load-balance aux (also reported for aux-free models as a
    # balance *metric*), + router z-loss for logit drift.
    probs_mean = jnp.mean(scores / (scores.sum(-1, keepdims=True) + 1e-9), axis=0)
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)  # (T,k,E)
    frac = jnp.mean(jnp.sum(onehot, axis=1), axis=0) / m.top_k
    lb_loss = m.n_experts * jnp.sum(frac * probs_mean)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"load_balance": lb_loss, "router_z": z_loss,
           "expert_fraction": frac}
    return idx, w.astype(x_flat.dtype), aux


# dropless einsum dispatch/combine tensors are (T, E, cap≈T); above this
# element budget (~256 MB fp32 for the pair) moe_apply reroutes to scatter
_DROPLESS_EINSUM_BUDGET = 1 << 25


def _capacity(cfg, n_tokens: int, dropless: bool = False) -> int:
    m = cfg.moe
    if dropless:
        # Each token lands on top_k *distinct* experts, so no expert can
        # receive more than n_tokens copies: cap = n_tokens drops nothing.
        return max(8, -(-n_tokens // 8) * 8)
    c = int(m.capacity_factor * m.top_k * n_tokens / m.n_experts)
    return max(8, -(-c // 8) * 8)  # sublane-align


def _dispatch_einsum(params, cfg, x_flat, idx, w, *, dropless=False):
    """GShard dense dispatch: (T,E,C) one-hot dispatch/combine tensors.

    Built with a static loop over the k routing slots — the rank-4
    ``(T,k,E,C)`` formulation is mathematically identical but its
    intermediate is k× larger and (measured) blows SPMD-partitioning
    compile time on the 256-expert cells."""
    m = cfg.moe
    t = x_flat.shape[0]
    cap = _capacity(cfg, t, dropless)
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)    # (T,k,E)
    pos_in_expert = (jnp.cumsum(onehot.reshape(t * m.top_k, m.n_experts),
                                axis=0).reshape(t, m.top_k, m.n_experts)
                     - onehot)                                    # (T,k,E)
    keep = (pos_in_expert < cap) & (onehot > 0)
    dispatch = jnp.zeros((t, m.n_experts, cap), x_flat.dtype)
    combine = jnp.zeros((t, m.n_experts, cap), x_flat.dtype)
    for kk in range(m.top_k):
        keep_k = keep[:, kk]                                      # (T,E)
        pos_oh = jax.nn.one_hot(
            jnp.where(keep_k, pos_in_expert[:, kk], cap), cap + 1,
            dtype=x_flat.dtype)[..., :cap]                        # (T,E,C)
        dispatch = dispatch + pos_oh
        combine = combine + pos_oh * w[:, kk][:, None, None]
    expert_in = jnp.einsum("tec,td->ecd", dispatch, x_flat)       # (E,C,d)
    from repro.models.shardlib import constrain
    expert_in = constrain(cfg, expert_in, "model", None, None)    # EP
    expert_out = ffn_apply_stacked(params["experts"], cfg, expert_in)
    return jnp.einsum("tec,ecd->td", combine, expert_out)


def _dispatch_scatter(params, cfg, x_flat, idx, w, *, dropless=False):
    """Sort-based ragged dispatch: flop-free token movement (optimized path).

    Tokens are ordered by target expert with a stable argsort; each expert's
    first ``cap`` tokens are gathered into a dense (E, C, d) buffer (drop-
    over-capacity, same semantics as GShard), processed, and combined back
    with a scatter-add weighted by the router weights.
    """
    m = cfg.moe
    t = x_flat.shape[0]
    cap = _capacity(cfg, t, dropless)
    flat_e = idx.reshape(-1)                                      # (T*k,)
    order = jnp.argsort(flat_e, stable=True)                      # (T*k,)
    sorted_e = flat_e[order]
    # position of each routed copy within its expert
    ones = jnp.ones_like(sorted_e)
    pos_sorted = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(m.n_experts))
    pos_within = pos_sorted - seg_start[sorted_e]
    keep = pos_within < cap
    slot = sorted_e * cap + jnp.where(keep, pos_within, 0)        # (T*k,)

    token_of_copy = order // m.top_k
    gathered = jnp.take(x_flat, token_of_copy, axis=0)            # (T*k, d)
    buf = jnp.zeros((m.n_experts * cap, x_flat.shape[1]), x_flat.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], gathered, 0))
    expert_in = buf.reshape(m.n_experts, cap, x_flat.shape[1])
    from repro.models.shardlib import constrain
    expert_in = constrain(cfg, expert_in, "model", None, None)    # EP
    expert_out = ffn_apply_stacked(params["experts"], cfg, expert_in)
    out_flat = expert_out.reshape(m.n_experts * cap, x_flat.shape[1])

    w_copy = jnp.take(w.reshape(-1), order)                       # (T*k,)
    contrib = jnp.take(out_flat, slot, axis=0) * jnp.where(
        keep, w_copy, 0.0)[:, None]
    y = jnp.zeros_like(x_flat).at[token_of_copy].add(contrib)
    return y


def moe_apply(params, cfg, x, *, dropless: bool = False
              ) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y, aux). Shared experts added on top (DeepSeek).

    ``dropless``: skip capacity-based token dropping.  Capacity drops are a
    training-time compute bound (GShard semantics); at inference they make a
    token's output depend on what else shares its batch, so eval forward,
    prefill and decode would disagree with each other.  Inference paths pass
    ``dropless=True`` (capacity = token count, which provably drops nothing).

    Scale note: dropless capacity makes the dispatch buffers O(T²·E)
    (einsum) or O(E·T·d) (scatter; what oversized einsum calls reroute to).
    That is fine at the token counts this repo executes, but truly dropless
    dispatch on production-length prefills needs ragged expert kernels
    (MegaBlocks-style) that dense one-hot/capacity formulations cannot
    express — decode (T = batch) is unaffected either way.
    """
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    t = b * s
    idx, w, aux = _routing(params, cfg, x_flat)
    use_scatter = cfg.moe.dispatch == "scatter"
    if dropless and not use_scatter:
        # Dropless capacity is O(T), so the einsum one-hot dispatch/combine
        # tensors are (T, E, ~T) — quadratic in tokens.  Past a budget,
        # reroute through the flop-free scatter dispatch (identical math,
        # O(E·T·d) buffer) instead of OOMing a long prefill.  Small token
        # counts stay on the configured path so einsum-vs-scatter tests
        # keep comparing distinct implementations.
        cap = _capacity(cfg, t, dropless=True)
        use_scatter = t * cfg.moe.n_experts * cap > _DROPLESS_EINSUM_BUDGET
    if use_scatter:
        y = _dispatch_scatter(params, cfg, x_flat, idx, w, dropless=dropless)
    else:
        y = _dispatch_einsum(params, cfg, x_flat, idx, w, dropless=dropless)
    if cfg.moe.n_shared:
        from repro.models.ffn import gated_ffn_apply
        y = y + gated_ffn_apply(params["shared"], cfg, x_flat)
    return y.reshape(b, s, d), aux
