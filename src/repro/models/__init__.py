"""Model substrate: layers, attention/recurrent mixers, MoE, stacks, LM API."""
from repro.models.model import LanguageModel  # noqa: F401
