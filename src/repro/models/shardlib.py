"""Activation-sharding constraints (GSPMD hints), resolved per cell.

Parameter sharding alone lets GSPMD *replicate* big intermediate einsums
when a dim doesn't divide the mesh axis — e.g. 8 kv-heads on 16-way TP
replicates the whole attention score computation on every model shard
(measured: ~4× per-device FLOPs on granite-3-2b train before this layer —
EXPERIMENTS.md §Perf).  The launcher resolves a strategy per (arch × mesh):

* ``heads``  — shard the kv-head dim of q/k/v (Hkv % model == 0),
* ``repeat`` — materialize repeated kv to Hq heads and shard those
               (Hq % model == 0; costs kv bytes, saves 16× compute),
* ``seq``    — context-parallel: shard the *query sequence* dim over
               `model` (always divisible; kv replicated) — the fallback for
               40-head models on 16-way TP,
* ``none``   — leave it to GSPMD (smoke tests / single device).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec

__all__ = ["constrain", "batch_axes", "shard_attn_qkv"]


def batch_axes(cfg):
    return tuple(cfg.mesh_batch_axes) if cfg.shard_batch else None


def constrain(cfg, x, *names: Optional[object]):
    """with_sharding_constraint if cfg.act_shard; names use None / 'model' /
    'batch' (resolved to the cell's batch axes)."""
    if not cfg.act_shard or x is None:
        return x
    parts = []
    for n in names:
        if n == "batch":
            parts.append(batch_axes(cfg))
        else:
            parts.append(n)
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*parts))


def shard_attn_qkv(cfg, q, k, v):
    """Apply the resolved attention TP strategy.  q: (B,S,Hq,D);
    k/v: (B,T,Hkv,D).  Returns (q, k, v) — possibly with kv repeated."""
    if not cfg.act_shard or cfg.attn_shard_mode == "none":
        return q, k, v
    mode = cfg.attn_shard_mode
    if mode == "repeat":
        g = q.shape[2] // k.shape[2]
        if g > 1:
            k = jax.numpy.repeat(k, g, axis=2)
            v = jax.numpy.repeat(v, g, axis=2)
        q = constrain(cfg, q, "batch", None, "model", None)
        k = constrain(cfg, k, "batch", None, "model", None)
        v = constrain(cfg, v, "batch", None, "model", None)
        return q, k, v
    if mode == "heads":
        q = constrain(cfg, q, "batch", None, "model", None)
        k = constrain(cfg, k, "batch", None, "model", None)
        v = constrain(cfg, v, "batch", None, "model", None)
        return q, k, v
    if mode == "seq":
        if q.shape[1] > 1:
            q = constrain(cfg, q, "batch", "model", None, None)
        k = constrain(cfg, k, "batch", None, None, None)
        v = constrain(cfg, v, "batch", None, None, None)
        return q, k, v
    raise ValueError(f"unknown attn_shard_mode {mode!r}")
