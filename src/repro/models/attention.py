"""Attention variants: GQA/MHA/MQA, MLA (DeepSeek/MiniCPM), local windows.

All variants share the cache protocol.  **Dense** layout::

    cache = {"k": (B, S_max, H_kv, Dh), "v": ..., "index": i32[B]}          # gqa
    cache = {"ckv": (B, S_max, r_kv), "krope": (B, S_max, Dr), "index": …}  # mla

**Paged** layout (DESIGN.md §6) — K/V live in a shared page pool and each
batch slot addresses its pages through a block table::

    cache = {"k": (n_pages, page_size, H_kv, Dh), "v": ...,
             "block_table": i32[B, pages_per_slot], "index": i32[B]}        # gqa
    cache = {"ckv": (n_pages, page_size, r_kv), "krope": (..., Dr),
             "block_table": ..., "index": ...}                              # mla

``index`` is a **per-slot vector**: entry ``b`` is the number of tokens
already written for slot ``b``, so slots at different positions decode in
one batch (the serve loop's continuous mixed-length batching).  Token ``t``
of slot ``b`` lives at page ``block_table[b, t // page_size]``, offset
``t % page_size``; page 0 is a reserved null page — free slots point at it
so their (ignored) decode writes never touch live pages.

Windowed layers use a ring buffer of size ``window`` (position
``index % window``) so decode-state is O(window) — this is what makes the
`long_500k` fallback and the RecurrentGemma local-attention layers bounded.
Rings are already sized to residency, so they keep the dense per-slot
layout under paging (a block table over a bounded ring buys nothing).

KV-cache quantization (``int8``) stores per-token/head absmax scales — a
beyond-paper memory optimization evaluated in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import P, dense, dense_spec, rope

__all__ = ["gqa_spec", "gqa_apply", "mla_spec", "mla_apply",
           "init_gqa_cache", "init_mla_cache", "init_gqa_paged_cache",
           "init_mla_paged_cache", "PageGeometry", "attend"]


@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """Static shape of a paged KV cache (shared by every attention layer).

    ``n_pages`` counts the *total* pool including the reserved null page 0;
    ``pages_per_slot`` is the block-table width — the most pages one slot
    can ever address (``ceil(s_max / page_size)``).
    """
    n_pages: int
    page_size: int
    pages_per_slot: int

    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1          # page 0 is the null page

    def pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_size)


# ---------------------------------------------------------------------------
# shared scaled-dot-product core
# ---------------------------------------------------------------------------


# At/above this many kv positions the direct (materialized-scores) path is
# replaced by the chunked online-softmax path — exact same math, O(chunk²)
# peak memory instead of O(S·T).  Without this, the 32k prefill cells would
# materialize multi-TB score tensors (EXPERIMENTS.md §Dry-run).
_FLASH_KV_THRESHOLD = 4096
_Q_CHUNK = 512
_K_CHUNK = 1024


@dataclasses.dataclass(frozen=True)
class MaskInfo:
    """Lazy attention-mask description — masks are *computed per block*
    inside the chunked path instead of materializing an (S, T) bool array
    (1 GB at 32k); the direct path builds the same mask from indices.

    ``q_offset`` and ``valid_len`` accept either a traced scalar (all slots
    at the same position — generate()'s batch-synchronous path) or a
    ``(B,)`` vector (per-slot positions — the serve loop's mixed-length
    continuous batching).  With a vector, masks gain a leading batch dim.
    """
    causal: bool = True
    window: Optional[int] = None    # static
    q_offset: object = 0            # traced scalar or (B,) (tokens cached)
    valid_len: object = None        # kv positions >= valid_len are masked
    kv_len: Optional[int] = None    # true kv length (for padding)

    def q_positions(self, base):
        """Absolute query positions: base (qc,) + q_offset -> (qc,) or
        (B, qc) when the offset is per-slot."""
        off = jnp.asarray(self.q_offset)
        return base + (off[:, None] if off.ndim else off)

    def block(self, q_pos, k_pos):
        """q_pos: (qc,) or (B, qc); k_pos: (kc,) ->
        bool (qc, kc) or (B, qc, kc)."""
        qp = q_pos[..., :, None]
        kp = k_pos[None, :]
        m = jnp.broadcast_to(
            jnp.ones((), bool),
            jnp.broadcast_shapes(qp.shape, kp.shape))
        if self.causal:
            m &= kp <= qp
        if self.window is not None:
            m &= kp > qp - self.window
        if self.valid_len is not None:
            vl = jnp.asarray(self.valid_len)
            m &= kp < (vl[:, None, None] if vl.ndim else vl)
        if self.kv_len is not None:
            m &= kp < self.kv_len
        return m


def attend(q, k, v, mask=None, *, mask_info: Optional[MaskInfo] = None,
           scale: Optional[float] = None):
    """q: (B,S,Hq,D)  k/v: (B,T,Hkv,D|Dv).

    Pass either an explicit (S,T) / per-slot (B,S,T) bool ``mask``
    (small/decode shapes) or a :class:`MaskInfo` (lazy; required for long
    sequences).  Grouped heads: Hq = G·Hkv — q is reshaped so each kv head
    serves G query heads without materializing repeated k/v (the GQA
    memory win).
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scale = scale if scale is not None else d ** -0.5
    if s > 1 and t >= _FLASH_KV_THRESHOLD:
        if mask_info is None:
            raise ValueError("long-sequence attend() needs a MaskInfo "
                             "(explicit masks would materialize S×T)")
        out = _flash_attend(qg, k, v, mask_info, scale)
        return out.reshape(b, s, hq, v.shape[-1])
    if mask is None:
        mask = mask_info.block(mask_info.q_positions(jnp.arange(s)),
                               jnp.arange(t))
    maskb = mask[None, None, None] if mask.ndim == 2 \
        else mask[:, None, None]                    # (B?,1,1,S,T)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(maskb, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, v.shape[-1])


def _flash_attend(qg, k, v, mi: MaskInfo, scale,
                  q_chunk=_Q_CHUNK, k_chunk=_K_CHUNK):
    """Exact chunked attention (FlashAttention recurrence in pure jnp).

    qg: (B,S,Hkv,G,D); k/v: (B,T,Hkv,D/Dv).  Sequential lax.scan over query
    chunks, inner scan over kv chunks with the online (m, l, acc) softmax
    carry — peak live buffer is (B,Hkv,G,Qc,Kc) fp32.
    """
    b, s, hkv, g, d = qg.shape
    t = k.shape[1]
    dv = v.shape[-1]
    qc, kc = min(q_chunk, s), k_chunk
    s_pad, t_pad = (-s) % qc, (-t) % kc
    if t_pad and mi.kv_len is None:
        mi = dataclasses.replace(mi, kv_len=t)
    if s_pad:
        qg = jnp.pad(qg, ((0, 0), (0, s_pad), (0, 0), (0, 0), (0, 0)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    nq, nk = (s + s_pad) // qc, (t + t_pad) // kc

    q_blocks = jnp.moveaxis(
        qg.reshape(b, nq, qc, hkv, g, d), 1, 0)            # (nq,B,qc,hkv,g,d)
    k_blocks = jnp.moveaxis(k.reshape(b, nk, kc, hkv, d), 1, 0)
    v_blocks = jnp.moveaxis(v.reshape(b, nk, kc, hkv, dv), 1, 0)

    def q_body(_, inputs):
        qi, q_blk = inputs                                  # (B,qc,hkv,g,d)
        q_pos = mi.q_positions(qi * qc + jnp.arange(qc))

        def kv_body(carry, kv_inputs):
            m, l, acc = carry
            kj, k_blk, v_blk = kv_inputs
            k_pos = kj * kc + jnp.arange(kc)
            mask_blk = mi.block(q_pos, k_pos)
            mask_b = mask_blk[None, None, None] if mask_blk.ndim == 2 \
                else mask_blk[:, None, None]
            logits = jnp.einsum("bqhgd,bkhd->bhgqk",
                                q_blk.astype(jnp.float32),
                                k_blk.astype(jnp.float32)) * scale
            logits = jnp.where(mask_b, logits, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            # guard -inf rows (fully masked so far): exp(-inf - -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(logits - m_safe[..., None])
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (jnp.full((b, hkv, g, qc), -jnp.inf, jnp.float32),
                jnp.zeros((b, hkv, g, qc), jnp.float32),
                jnp.zeros((b, hkv, g, qc, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_body, init, (jnp.arange(nk), k_blocks, v_blocks))
        out = jnp.where(l[..., None] > 0,
                        acc / jnp.maximum(l[..., None], 1e-30),
                        0.0)                                # (B,hkv,g,qc,dv)
        return None, jnp.moveaxis(out, 3, 1)                # (B,qc,hkv,g,dv)

    _, out_blocks = jax.lax.scan(q_body, None, (jnp.arange(nq), q_blocks))
    out = jnp.moveaxis(out_blocks, 0, 1).reshape(b, nq * qc, hkv, g, dv)
    return out[:, :s].astype(v.dtype)


def _ring_mask(s: int, window: int, index):
    """Decode-time mask over a ring buffer of size ``window``.

    Slot j holds absolute position p ≡ j (mod window) with p in
    (index-window, index]; valid iff it has been written (p >= 0) — geometry
    guarantees p <= index.  Query position = index (s == 1).  ``index`` is
    the per-slot (B,) vector, so each batch row gets its own ring view.
    """
    assert s == 1
    slots = jnp.arange(window)
    newest = index[:, None]   # (B,1): this step's write lands at index % window
    pos = newest - ((newest - slots) % window)
    return (pos >= 0)[:, None, :]                   # (B, 1, window)


# ---------------------------------------------------------------------------
# KV quantization helpers (beyond-paper: int8 cache)
# ---------------------------------------------------------------------------


def _quantize(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    return jnp.round(x / scale).astype(jnp.int8), scale.astype(jnp.float32)


def _maybe_store(x, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.dtype(dtype)), None


def _maybe_load(stored, scale, dtype):
    if scale is not None:
        return stored.astype(dtype) * scale.astype(dtype)
    return stored.astype(dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_spec(cfg):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "q": dense_spec(d, hq * dh, ("embed", "q_heads_x_dim"), bias=cfg.qkv_bias),
        "k": dense_spec(d, hkv * dh, ("embed", "kv_heads_x_dim"), bias=cfg.qkv_bias),
        "v": dense_spec(d, hkv * dh, ("embed", "kv_heads_x_dim"), bias=cfg.qkv_bias),
        "o": dense_spec(hq * dh, d, ("q_heads_x_dim", "embed")),
    }


def init_gqa_cache(cfg, batch: int, s_max: int, window: Optional[int] = None):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    size = min(s_max, window) if window else s_max
    kv_dtype = cfg.kv_cache_dtype
    store_dtype = jnp.int8 if kv_dtype == "int8" else jnp.dtype(kv_dtype)
    cache = {
        "k": jnp.zeros((batch, size, hkv, dh), store_dtype),
        "v": jnp.zeros((batch, size, hkv, dh), store_dtype),
        "index": jnp.zeros((batch,), jnp.int32),
    }
    if kv_dtype == "int8":
        cache["k_scale"] = jnp.zeros((batch, size, hkv, 1), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, size, hkv, 1), jnp.float32)
    return cache


def init_gqa_paged_cache(cfg, n_slots: int, geom: PageGeometry):
    """Paged GQA cache: shared page pool + per-slot block table/index."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    kv_dtype = cfg.kv_cache_dtype
    store_dtype = jnp.int8 if kv_dtype == "int8" else jnp.dtype(kv_dtype)
    cache = {
        "k": jnp.zeros((geom.n_pages, geom.page_size, hkv, dh), store_dtype),
        "v": jnp.zeros((geom.n_pages, geom.page_size, hkv, dh), store_dtype),
        "block_table": jnp.zeros((n_slots, geom.pages_per_slot), jnp.int32),
        "index": jnp.zeros((n_slots,), jnp.int32),
    }
    if kv_dtype == "int8":
        cache["k_scale"] = jnp.zeros(
            (geom.n_pages, geom.page_size, hkv, 1), jnp.float32)
        cache["v_scale"] = jnp.zeros(
            (geom.n_pages, geom.page_size, hkv, 1), jnp.float32)
    return cache


def _paged_write(pool, new, page, off):
    """Scatter this step's per-slot token into its (page, offset) cell.

    pool: (P, ps, ...); new: (B, 1, ...); page/off: (B,).  Free slots point
    at the null page 0, so their writes land there harmlessly.
    """
    return pool.at[page, off].set(new[:, 0])


def _paged_view(pool, block_table):
    """Gather a slot-major dense view (B, pages_per_slot·ps, ...) of the
    pool through the block table (B, pages_per_slot)."""
    b, p_max = block_table.shape
    v = pool[block_table]                    # (B, p_max, ps, ...)
    return v.reshape((b, p_max * pool.shape[1]) + pool.shape[2:])


def _cache_write(cache, k_new, v_new, kv_dtype: str, window: Optional[int]):
    index = cache["index"]                   # (B,)
    b, s = k_new.shape[:2]
    ks, k_scale = _maybe_store(k_new, kv_dtype)
    vs, v_scale = _maybe_store(v_new, kv_dtype)
    cache = dict(cache)
    if "block_table" in cache:
        # paged decode write (prefill goes through the dense slab + the
        # serve layer's commit_prefill — see serve/paging.py)
        assert s == 1, "paged caches are decode-only; prefill is dense"
        ps = cache["k"].shape[1]
        page = cache["block_table"][jnp.arange(b), index // ps]
        off = index % ps
        cache["k"] = _paged_write(cache["k"], ks, page, off)
        cache["v"] = _paged_write(cache["v"], vs, page, off)
        if k_scale is not None:
            cache["k_scale"] = _paged_write(cache["k_scale"], k_scale,
                                            page, off)
            cache["v_scale"] = _paged_write(cache["v_scale"], v_scale,
                                            page, off)
        cache["index"] = index + s
        return cache
    size = cache["k"].shape[1]
    if window and s >= size:
        # prefill longer than the ring: keep the last `size` tokens, rolled
        # so that absolute position p lands at slot p % size (the invariant
        # the decode-time ring mask relies on).  Prefill rows share one
        # length, so the roll shift is static.
        shift = (s - size) % size
        cache["k"] = jnp.roll(ks[:, -size:], shift, axis=1)
        cache["v"] = jnp.roll(vs[:, -size:], shift, axis=1)
        if k_scale is not None:
            cache["k_scale"] = jnp.roll(k_scale[:, -size:], shift, axis=1)
            cache["v_scale"] = jnp.roll(v_scale[:, -size:], shift, axis=1)
        cache["index"] = index + s
        return cache
    if window and s == 1:
        rows = jnp.arange(b)
        slot = index % size                  # per-slot ring position
        cache["k"] = cache["k"].at[rows, slot].set(ks[:, 0])
        cache["v"] = cache["v"].at[rows, slot].set(vs[:, 0])
        if k_scale is not None:
            cache["k_scale"] = cache["k_scale"].at[rows, slot].set(
                k_scale[:, 0])
            cache["v_scale"] = cache["v_scale"].at[rows, slot].set(
                v_scale[:, 0])
    else:
        # per-slot start positions: row b writes tokens index[b]..index[b]+s-1
        rows = jnp.arange(b)[:, None]
        pos = index[:, None] + jnp.arange(s)[None, :]
        cache["k"] = cache["k"].at[rows, pos].set(ks)
        cache["v"] = cache["v"].at[rows, pos].set(vs)
        if k_scale is not None:
            cache["k_scale"] = cache["k_scale"].at[rows, pos].set(k_scale)
            cache["v_scale"] = cache["v_scale"].at[rows, pos].set(v_scale)
    cache["index"] = index + s
    return cache


def gqa_apply(params, cfg, x, positions, *, mode: str = "causal",
              cache=None, window: Optional[int] = None, kv_x=None):
    """mode: causal | full (encoder) | cross (kv from kv_x, no cache growth).

    With ``cache`` set: writes new kv at cache["index"], attends over the
    whole (ring) buffer.  Returns (y, new_cache) — new_cache is None when no
    cache was passed.
    """
    from repro.models.shardlib import shard_attn_qkv

    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(params["q"], x).reshape(b, s, hq, dh)
    kv_src = kv_x if kv_x is not None else x
    k = dense(params["k"], kv_src).reshape(b, kv_src.shape[1], hkv, dh)
    v = dense(params["v"], kv_src).reshape(b, kv_src.shape[1], hkv, dh)

    if mode != "cross":
        q = rope(q, positions, cfg.rope_theta)
        kv_pos = positions if kv_x is None else None
        k = rope(k, kv_pos, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # cache stores the true (un-repeated) kv heads; the TP strategy
        # (shard_attn_qkv, possibly repeating kv) applies to the *loaded*
        # tensors only
        index = cache["index"]
        new_cache = _cache_write(cache, k, v, cfg.kv_cache_dtype, window)
        if window and s > 1:
            # windowed prefill: attend over the in-flight (full-length) k/v
            # with the window mask — the ring cache holds a rolled layout
            # that only the s==1 decode mask understands
            q, k, v = shard_attn_qkv(cfg, q, k, v)
            mi = MaskInfo(causal=True, window=window, q_offset=index)
            y = attend(q, k, v, mask_info=mi)
        else:
            if "block_table" in new_cache:
                # paged: gather each slot's pages into a slot-major dense
                # view; view position t IS absolute token position t, so
                # the same per-slot causal/valid masks apply unchanged
                bt = new_cache["block_table"]
                k_sc = _paged_view(new_cache["k_scale"], bt) \
                    if "k_scale" in new_cache else None
                v_sc = _paged_view(new_cache["v_scale"], bt) \
                    if "v_scale" in new_cache else None
                k = _maybe_load(_paged_view(new_cache["k"], bt), k_sc, x.dtype)
                v = _maybe_load(_paged_view(new_cache["v"], bt), v_sc, x.dtype)
            else:
                k = _maybe_load(new_cache["k"], new_cache.get("k_scale"),
                                x.dtype)
                v = _maybe_load(new_cache["v"], new_cache.get("v_scale"),
                                x.dtype)
            q, k, v = shard_attn_qkv(cfg, q, k, v)
            t = k.shape[1]
            if window and s == 1:
                y = attend(q, k, v, _ring_mask(s, t, index))
            else:
                # prefill into an empty/partial cache: causal over written
                mi = MaskInfo(causal=True, q_offset=index,
                              valid_len=index + s)
                y = attend(q, k, v, mask_info=mi)
    else:
        q, k, v = shard_attn_qkv(cfg, q, k, v)
        mi = MaskInfo(causal=mode not in ("full", "cross"), window=window)
        y = attend(q, k, v, mask_info=mi)
    y = dense(params["o"], y.reshape(b, s, hq * dh))
    return y, new_cache


def make_cross_cache(params, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output (enc-dec serving).

    Done once per request instead of per decode step — without this the
    cross K/V recompute would dominate enc-dec decode FLOPs.
    """
    b, t, _ = enc_out.shape
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    ck = dense(params["k"], enc_out).reshape(b, t, hkv, dh)
    cv = dense(params["v"], enc_out).reshape(b, t, hkv, dh)
    return ck, cv


def cross_attend_cached(params, cfg, x, ck, cv):
    """Cross-attention against precomputed encoder K/V (full visibility)."""
    b, s, _ = x.shape
    hq, dh = cfg.n_heads, cfg.head_dim
    q = dense(params["q"], x).reshape(b, s, hq, dh)
    y = attend(q, ck, cv, mask_info=MaskInfo(causal=False))
    return dense(params["o"], y.reshape(b, s, hq * dh))


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2/V3, MiniCPM3)
# ---------------------------------------------------------------------------


def mla_spec(cfg):
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    spec = {
        "kv_down": dense_spec(d, m.kv_lora_rank + m.qk_rope_head_dim,
                              ("embed", "mla_latent")),
        "kv_norm": {"scale": P((m.kv_lora_rank,), ("norm",), init="ones")},
        "k_up": dense_spec(m.kv_lora_rank, h * m.qk_nope_head_dim,
                           ("mla_latent", "q_heads_x_dim")),
        "v_up": dense_spec(m.kv_lora_rank, h * m.v_head_dim,
                           ("mla_latent", "q_heads_x_dim")),
        "o": dense_spec(h * m.v_head_dim, d, ("q_heads_x_dim", "embed")),
    }
    q_dim = h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    if m.q_lora_rank:
        spec["q_down"] = dense_spec(d, m.q_lora_rank, ("embed", "mla_latent"))
        spec["q_norm"] = {"scale": P((m.q_lora_rank,), ("norm",), init="ones")}
        spec["q_up"] = dense_spec(m.q_lora_rank, q_dim,
                                  ("mla_latent", "q_heads_x_dim"))
    else:
        spec["q_proj"] = dense_spec(d, q_dim, ("embed", "q_heads_x_dim"))
    return spec


def init_mla_cache(cfg, batch: int, s_max: int, window: Optional[int] = None):
    m = cfg.mla
    size = min(s_max, window) if window else s_max
    return {
        "ckv": jnp.zeros((batch, size, m.kv_lora_rank),
                         jnp.dtype(cfg.dtype)),
        "krope": jnp.zeros((batch, size, m.qk_rope_head_dim),
                           jnp.dtype(cfg.dtype)),
        "index": jnp.zeros((batch,), jnp.int32),
    }


def init_mla_paged_cache(cfg, n_slots: int, geom: PageGeometry):
    """Paged MLA cache: latent/rope-key page pools + block table."""
    m = cfg.mla
    dt = jnp.dtype(cfg.dtype)
    return {
        "ckv": jnp.zeros((geom.n_pages, geom.page_size, m.kv_lora_rank), dt),
        "krope": jnp.zeros(
            (geom.n_pages, geom.page_size, m.qk_rope_head_dim), dt),
        "block_table": jnp.zeros((n_slots, geom.pages_per_slot), jnp.int32),
        "index": jnp.zeros((n_slots,), jnp.int32),
    }


def mla_apply(params, cfg, x, positions, *, mode: str = "causal",
              cache=None, window: Optional[int] = None):
    """MLA: cache holds only the compressed latent (r_kv) + shared rope key —
    the format's whole point: cache bytes per token = r_kv + Dr ≪ 2·H·Dh."""
    from repro.models.layers import rmsnorm

    b, s, d = x.shape
    h, m = cfg.n_heads, cfg.mla
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    if m.q_lora_rank:
        cq = rmsnorm(params["q_norm"], dense(params["q_down"], x))
        q = dense(params["q_up"], cq).reshape(b, s, h, dn + dr)
    else:
        q = dense(params["q_proj"], x).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    down = dense(params["kv_down"], x)
    ckv, k_rope = down[..., : m.kv_lora_rank], down[..., m.kv_lora_rank:]
    ckv = rmsnorm(params["kv_norm"], ckv)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    index = jnp.zeros((), jnp.int32)
    if cache is not None:
        index = cache["index"]               # (B,)
        new_cache = dict(cache)
        ckv_st = ckv.astype(cache["ckv"].dtype)
        kr_st = k_rope.astype(cache["krope"].dtype)        # (B,S,Dr)
        if "block_table" in cache:
            assert s == 1, "paged caches are decode-only; prefill is dense"
            ps = cache["ckv"].shape[1]
            page = cache["block_table"][jnp.arange(b), index // ps]
            off = index % ps
            new_cache["ckv"] = _paged_write(cache["ckv"], ckv_st, page, off)
            new_cache["krope"] = _paged_write(cache["krope"], kr_st,
                                              page, off)
            new_cache["index"] = index + s
            bt = cache["block_table"]
            ckv = _paged_view(new_cache["ckv"], bt).astype(x.dtype)
            k_rope = _paged_view(new_cache["krope"], bt).astype(x.dtype)
        else:
            rows = jnp.arange(b)
            if window and s == 1:
                slot = index % cache["ckv"].shape[1]
                new_cache["ckv"] = cache["ckv"].at[rows, slot].set(
                    ckv_st[:, 0])
                new_cache["krope"] = cache["krope"].at[rows, slot].set(
                    kr_st[:, 0])
            else:
                pos = index[:, None] + jnp.arange(s)[None, :]
                new_cache["ckv"] = cache["ckv"].at[rows[:, None], pos].set(
                    ckv_st)
                new_cache["krope"] = cache["krope"].at[rows[:, None],
                                                       pos].set(kr_st)
            new_cache["index"] = index + s
            ckv = new_cache["ckv"].astype(x.dtype)
            k_rope = new_cache["krope"].astype(x.dtype)

    t = ckv.shape[1]
    # up-project latent to per-head keys/values (recomputed per step — the
    # MLA trade; the absorbed-matmul variant is a §Perf hillclimb change)
    k_nope = dense(params["k_up"], ckv).reshape(b, t, h, dn)
    v = dense(params["v_up"], ckv).reshape(b, t, h, dv)

    # fold the shared rope key into per-head keys so the shared exact-flash
    # attend() handles the 32k/500k shapes without materializing S×T scores
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, dr))],
        axis=-1)
    from repro.models.shardlib import shard_attn_qkv
    q_cat, k_cat, v = shard_attn_qkv(cfg, q_cat, k_cat, v)

    scale = (dn + dr) ** -0.5
    if cache is not None and window and s == 1:
        out = attend(q_cat, k_cat, v, _ring_mask(s, t, index), scale=scale)
    elif cache is not None:
        mi = MaskInfo(causal=True, window=window, q_offset=index,
                      valid_len=index + s)
        out = attend(q_cat, k_cat, v, mask_info=mi, scale=scale)
    else:
        out = attend(q_cat, k_cat, v,
                     mask_info=MaskInfo(causal=True, window=window),
                     scale=scale)
    y = dense(params["o"], out.reshape(b, s, h * dv))
    return y, new_cache
