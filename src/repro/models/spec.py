"""Parameter-spec system: one declaration → init / abstract tree / shardings.

Every layer declares its parameters as a tree of :class:`P` (shape + logical
axes + initializer).  From that single declaration we derive:

* ``init_from_spec``      — PRNG-keyed real initialization (smoke tests, examples),
* ``abstract_from_spec``  — ``jax.ShapeDtypeStruct`` tree with **no allocation**
                            (the multi-pod dry-run path),
* ``axes_from_spec``      — the logical-axes tree consumed by
                            :mod:`repro.sharding.partitioner`.

This is the t5x/flax-partitioning idea without the flax dependency, and it
guarantees the three trees can never drift structurally.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["P", "init_from_spec", "abstract_from_spec", "axes_from_spec",
           "count_params", "param_bytes"]


@dataclasses.dataclass(frozen=True)
class P:
    """Spec for one parameter tensor.

    ``axes`` are logical names, one per dim (None = never sharded), e.g.
    ``("embed", "q_heads", "head_dim")``.  ``init`` ∈ {normal, zeros, ones,
    fan_in, embed} or a callable ``(key, shape, dtype) -> array``.
    """

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: Any = "fan_in"
    scale: float = 1.0
    dtype: Any = None

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def _is_spec(x) -> bool:
    return isinstance(x, P)


def _init_one(key, p: P, dtype) -> jax.Array:
    dtype = p.dtype or dtype
    shape = p.shape
    if callable(p.init):
        return p.init(key, shape, dtype)
    if p.init == "zeros":
        return jnp.zeros(shape, dtype)
    if p.init == "ones":
        return jnp.ones(shape, dtype)
    if p.init == "normal":
        return (p.scale * jax.random.normal(key, shape)).astype(dtype)
    if p.init == "embed":
        return (p.scale * jax.random.normal(key, shape)).astype(dtype)
    if p.init == "fan_in":
        # truncated-normal with 1/sqrt(fan_in); fan_in = prod of all dims but last
        fan_in = max(1, int(np.prod(shape[:-1])))
        std = p.scale / np.sqrt(fan_in)
        return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)
    raise ValueError(f"unknown init {p.init!r}")


def init_from_spec(key, spec, dtype=jnp.float32):
    """Materialize real parameters from a spec tree."""
    leaves, treedef = jax.tree_util.tree_flatten(spec, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, p, dtype) for k, p in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_from_spec(spec, dtype=jnp.float32):
    """ShapeDtypeStruct tree — zero allocation (dry-run path)."""
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or dtype),
        spec, is_leaf=_is_spec)


def axes_from_spec(spec):
    """Logical-axes tree (same structure, tuples of names)."""
    return jax.tree_util.tree_map(lambda p: p.axes, spec, is_leaf=_is_spec)


def count_params(spec) -> int:
    leaves = jax.tree_util.tree_leaves(spec, is_leaf=_is_spec)
    return int(sum(np.prod(p.shape) for p in leaves))


def param_bytes(spec, dtype=jnp.float32) -> int:
    leaves = jax.tree_util.tree_leaves(spec, is_leaf=_is_spec)
    return int(sum(np.prod(p.shape) * jnp.dtype(p.dtype or dtype).itemsize
                   for p in leaves))
