"""LanguageModel: the public model API over the layer stack.

Families (``cfg.family``):
* decoder-only text (dense/moe/ssm/hybrid): ``batch = {tokens, labels}``
* ``vlm``  : + ``patch_embeds (B, frontend_tokens, d_frontend)`` — the ViT
             frontend is a stub per the assignment; patches are projected and
             prepended, loss masked to text positions.
* ``audio``: encoder-decoder — ``batch = {frames (B,S,d_frontend), tokens,
             labels}``; frames are the (stubbed) speech-frontend output.

API:
* ``spec()/init()/abstract_params()``  — parameter trees (real or shaped).
* ``forward(params, batch)``           — logits for a full sequence.
* ``loss(params, batch)``              — CE (+ z-loss + MoE aux + MTP).
* ``prefill(params, batch, s_max)``    — logits + filled caches.
* ``decode_step(params, cache, tokens)`` — one token, the `serve_step` the
  decode/long dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import transformer as tfm
from repro.models.layers import dense, dense_spec, embed_lookup, embed_logits, \
    embed_spec, rmsnorm, rmsnorm_spec, rope_positions
from repro.models.spec import abstract_from_spec, axes_from_spec, \
    count_params, init_from_spec

__all__ = ["LanguageModel"]

_MTP_WEIGHT = 0.3
_LB_COEF = 0.01
_Z_COEF = 1e-4


class LanguageModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.compute_dtype = jnp.dtype(cfg.dtype)
        self.param_dtype = jnp.dtype(cfg.param_dtype)

    # ------------------------------------------------------------------ spec
    def spec(self):
        cfg = self.cfg
        spec: Dict[str, Any] = {
            # 1/sqrt(d) embedding init keeps tied-head logits O(1) at step 0.
            # Rows are padded to cfg.padded_vocab so the vocab dim shards
            # evenly (logits past cfg.vocab are masked in _logits).
            "embed": embed_spec(cfg.padded_vocab, cfg.d_model,
                                scale=cfg.d_model ** -0.5),
            "final_norm": rmsnorm_spec(cfg.d_model),
            "stack": tfm.stack_spec(cfg),
        }
        if not cfg.tie_embeddings:
            spec["lm_head"] = dense_spec(cfg.d_model, cfg.padded_vocab,
                                         ("embed", "vocab"))
        if cfg.frontend == "vision":
            spec["frontend_proj"] = dense_spec(cfg.d_frontend, cfg.d_model,
                                               ("frontend", "embed"))
        if cfg.enc_dec:
            enc_cfg = dataclasses.replace(
                cfg, layer_pattern=("enc_attn",), prefix_pattern=(),
                n_layers=cfg.n_enc_layers)
            spec["encoder"] = tfm.stack_spec(enc_cfg)
            spec["enc_norm"] = rmsnorm_spec(cfg.d_model)
            spec["frontend_proj"] = dense_spec(cfg.d_frontend, cfg.d_model,
                                               ("frontend", "embed"))
        if cfg.mtp_depth:
            spec["mtp"] = {
                "proj": dense_spec(2 * cfg.d_model, cfg.d_model,
                                   ("embed", "embed2")),
                "norm_h": rmsnorm_spec(cfg.d_model),
                "norm_e": rmsnorm_spec(cfg.d_model),
                "block": tfm.block_spec(cfg, "attn"),
            }
        return spec

    def init(self, key):
        return init_from_spec(key, self.spec(), dtype=self.param_dtype)

    def abstract_params(self):
        return abstract_from_spec(self.spec(), dtype=self.param_dtype)

    def param_axes(self):
        return axes_from_spec(self.spec())

    def n_params(self) -> int:
        return count_params(self.spec())

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        cfg = self.cfg
        if not cfg.moe.n_experts:
            return self.n_params()
        total = self.n_params()
        m = cfg.moe
        per_expert = 3 * cfg.d_model * m.d_ff_expert
        n_moe_layers = sum(k == "moe" for k in cfg.layer_pattern) \
            * cfg.pattern_repeats \
            + sum(k == "moe" for k in cfg.prefix_pattern)
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total - inactive

    # ------------------------------------------------------------- embedding
    def _embed_sequence(self, params, batch):
        cfg = self.cfg
        x = embed_lookup(params["embed"], batch["tokens"]
                         ).astype(self.compute_dtype)
        if cfg.frontend == "vision":
            patches = dense(params["frontend_proj"],
                            batch["patch_embeds"].astype(self.compute_dtype))
            x = jnp.concatenate([patches, x], axis=1)
        return x

    def _encode(self, params, frames):
        cfg = self.cfg
        enc_cfg = dataclasses.replace(
            cfg, layer_pattern=("enc_attn",), prefix_pattern=(),
            n_layers=cfg.n_enc_layers)
        h = dense(params["frontend_proj"], frames.astype(self.compute_dtype))
        pos = rope_positions(h.shape[0], h.shape[1])
        h, _, _ = tfm.stack_apply(params["encoder"], enc_cfg, h, pos,
                                  mode="train", shape_kind="train")
        return rmsnorm(params["enc_norm"], h)

    def _logits(self, params, h):
        if self.cfg.tie_embeddings:
            logits = embed_logits(params["embed"], h)
        else:
            logits = dense(params["lm_head"], h)
        if self.cfg.padded_vocab != self.cfg.vocab:
            # mask padding rows out of the softmax (iota-compare: fuses and
            # stays sharded under GSPMD, unlike a slice)
            vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                                  logits.ndim - 1)
            logits = jnp.where(vocab_iota < self.cfg.vocab, logits,
                               jnp.asarray(-1e30, logits.dtype))
        return logits

    # ---------------------------------------------------------------- forward
    def forward(self, params, batch, *, shape_kind: str = "train",
                mode: str = "eval"):
        """Full-sequence forward.  ``mode='eval'`` (default) is the inference
        semantics — MoE layers run dropless, so this is the oracle that
        prefill+decode must reproduce token-exactly.  ``loss`` passes
        ``mode='train'`` to keep GShard capacity drops in the training step.
        """
        cfg = self.cfg
        enc_out = None
        if cfg.enc_dec:
            enc_out = self._encode(params, batch["frames"])
        x = self._embed_sequence(params, batch)
        pos = rope_positions(x.shape[0], x.shape[1])
        x, _, aux = tfm.stack_apply(params["stack"], cfg, x, pos,
                                    mode=mode, shape_kind=shape_kind,
                                    enc_out=enc_out)
        h = rmsnorm(params["final_norm"], x)
        return self._logits(params, h), h, aux

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, *, shape_kind: str = "train"):
        cfg = self.cfg
        logits, h, aux = self.forward(params, batch, shape_kind=shape_kind,
                                      mode="train")
        labels = batch["labels"]
        if cfg.frontend == "vision":
            # frontend positions carry no labels
            pad = -jnp.ones((labels.shape[0], cfg.frontend_tokens), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        loss = _masked_ce(logits, labels)
        metrics = {"ce": loss}
        if cfg.moe.n_experts:
            loss = loss + _LB_COEF * aux["load_balance"] \
                + _Z_COEF * aux["router_z"]
            metrics["load_balance"] = aux["load_balance"]
        if cfg.mtp_depth:
            mtp_loss = self._mtp_loss(params, h, batch)
            loss = loss + _MTP_WEIGHT * mtp_loss
            metrics["mtp"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, h, batch):
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
        [norm(h_t); norm(emb(tok_{t+1}))] through one extra block."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        if cfg.frontend == "vision":
            return jnp.zeros((), jnp.float32)
        emb_next = embed_lookup(params["embed"], tokens[:, 1:]
                                ).astype(self.compute_dtype)
        h_cur = h[:, :-1, :]
        merged = dense(params["mtp"]["proj"], jnp.concatenate(
            [rmsnorm(params["mtp"]["norm_h"], h_cur),
             rmsnorm(params["mtp"]["norm_e"], emb_next)], axis=-1))
        pos = rope_positions(merged.shape[0], merged.shape[1])
        out, _, _ = tfm.block_apply(params["mtp"]["block"], cfg, "attn",
                                    merged, pos, mode="train")
        logits = self._logits(params, rmsnorm(params["final_norm"], out))
        # target at merged position t is labels[t+1] (the t+2 token)
        return _masked_ce(logits, labels[:, 1:])

    # -------------------------------------------------------------- serving
    def init_cache(self, batch_size: int, s_max: int, *,
                   shape_kind: str = "decode", enc_len: int = 0,
                   paging=None):
        """``paging``: optional :class:`repro.models.attention.PageGeometry`
        — full-attention layers get paged (page-pool + block-table) caches
        instead of dense per-slot slabs (DESIGN.md §6)."""
        return tfm.stack_cache_spec(self.cfg, batch_size, s_max, shape_kind,
                                    enc_len, paging)

    def prefill(self, params, batch, s_max: int, *,
                shape_kind: str = "prefill"):
        """Run the prompt through the stack, filling caches."""
        cfg = self.cfg
        enc_out = None
        enc_len = 0
        if cfg.enc_dec:
            enc_out = self._encode(params, batch["frames"])
            enc_len = enc_out.shape[1]
        x = self._embed_sequence(params, batch)
        caches = self.init_cache(x.shape[0], s_max, shape_kind=shape_kind,
                                 enc_len=enc_len)
        if cfg.enc_dec:
            caches = self._fill_cross_caches(params, caches, enc_out)
        pos = rope_positions(x.shape[0], x.shape[1])
        x, caches, _ = tfm.stack_apply(params["stack"], cfg, x, pos,
                                       mode="prefill", shape_kind=shape_kind,
                                       caches=caches, enc_out=enc_out)
        h = rmsnorm(params["final_norm"], x)
        return self._logits(params, h[:, -1:, :]), caches

    def _fill_cross_caches(self, params, caches, enc_out):
        cfg = self.cfg

        def fill(name, block_params, cache, stacked):
            if "ck" not in cache:
                return cache
            if stacked:
                def one(p):
                    ck, cv = attn_mod.make_cross_cache(p["cross"], cfg, enc_out)
                    return ck, cv
                ck, cv = jax.vmap(one)(block_params)
            else:
                ck, cv = attn_mod.make_cross_cache(block_params["cross"],
                                                   cfg, enc_out)
            return {"self": cache["self"], "ck": ck, "cv": cv}

        new = {"prefix": {}, "body": {}}
        for name, cache in caches["prefix"].items():
            new["prefix"][name] = fill(
                name, params["stack"]["prefix"][name], cache, False)
        for name, cache in caches["body"].items():
            new["body"][name] = fill(
                name, params["stack"]["body"][name], cache, True)
        return new

    def decode_step(self, params, caches, tokens, *,
                    shape_kind: str = "decode"):
        """One-token serve step. tokens: (B, 1). Returns (logits, caches).

        Loop-pure contract: all state flows through ``caches`` (per-slot
        position indices included) and every array op is traceable, so
        this body runs unchanged inside the serving engine's fused
        ``lax.while_loop`` (``serve/device_loop.build_fused_decode``) —
        no host callbacks, no Python-side mutation between steps.
        """
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens).astype(self.compute_dtype)
        index = _cache_index(caches)         # (B,) per-slot positions
        if index.ndim:
            pos = jnp.broadcast_to(index[:, None], tokens.shape
                                   ).astype(jnp.int32)
        else:                                # index-free stacks (pure ssm/rec)
            pos = jnp.broadcast_to(index[None, None], tokens.shape
                                   ).astype(jnp.int32)
        x, caches, _ = tfm.stack_apply(params["stack"], cfg, x, pos,
                                       mode="decode", shape_kind=shape_kind,
                                       caches=caches)
        h = rmsnorm(params["final_norm"], x)
        return self._logits(params, h), caches


def _cache_index(caches):
    """First available `index` leaf, shape (B,) — all layers advance in
    lockstep; body-stacked leaves carry a leading (layers,) dim to strip."""
    for tree in (caches["prefix"], caches["body"]):
        for cache in tree.values():
            if isinstance(cache, dict):
                if "index" in cache:
                    idx = cache["index"]
                    return idx[0] if idx.ndim > 1 else idx
                if "self" in cache and "index" in cache["self"]:
                    idx = cache["self"]["index"]
                    return idx[0] if idx.ndim > 1 else idx
    return jnp.zeros((), jnp.int32)


def _masked_ce(logits, labels):
    """Cross-entropy over positions with label >= 0, fp32 accumulation.

    Predicts labels[t] from position t (labels are pre-shifted by the data
    pipeline: labels[t] = tokens[t+1]).

    The gold logit is selected with an iota-compare reduction rather than
    take_along_axis: under GSPMD with the vocab dim sharded over `model`,
    the compare+select fuses into the reduce and stays sharded, whereas the
    gather would all-gather the (B,S,V) logits (GBs at 128k vocab)."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == safe[..., None], logits32, 0.0),
                   axis=-1)
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
