"""Feed-forward blocks: gated (SwiGLU/GeGLU), plain, squared-ReLU — and
``SparseLinear``: the paper's RgCSR format as a first-class weight store.

``SparseLinear`` keeps a magnitude-pruned weight matrix in RgCSR layout
*inside the parameter tree* (values are trainable; the sparsity structure is
fixed at init, standard static-sparse training).  On TPU the matmul runs
through the Pallas ``rgcsr_spmm`` kernel; under SPMD dry-runs and on CPU it
uses the jnp oracle (``sparsity.impl='ref'``), which XLA shards like any
segment-sum.  This is the LM-framework integration of the paper's technique
(DESIGN.md §4).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import P, dense, dense_spec

__all__ = ["ffn_spec", "ffn_apply", "gated_ffn_apply", "ffn_apply_stacked",
           "sparse_linear_spec", "sparse_linear_init_mask",
           "sparse_linear_apply"]


def _activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                      # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def ffn_spec(cfg, d_ff: int | None = None):
    d = cfg.d_model
    d_ff = d_ff or cfg.d_ff
    spec = {
        "w_in": dense_spec(d, d_ff, ("embed", "mlp")),
    }
    if cfg.sparsity.enabled and "ffn" in cfg.sparsity.targets:
        # the paper's technique in the LM: the FFN down-projection weight
        # (d_model × d_ff) is stored in RgCSR and trained with a frozen
        # sparsity structure (DESIGN.md §4)
        spec["w_out"] = sparse_linear_spec(cfg, d_ff, d)
    else:
        spec["w_out"] = dense_spec(d_ff, d, ("mlp", "embed"))
    if cfg.gated_ffn:
        spec["w_gate"] = dense_spec(d, d_ff, ("embed", "mlp"))
    return spec


def ffn_apply(params, cfg, x):
    act = _activation(cfg.activation)
    h = dense(params["w_in"], x)
    if "w_gate" in params:
        h = act(dense(params["w_gate"], x)) * h
    else:
        h = act(h)
    if "values2d" in params["w_out"]:
        return sparse_linear_apply(params["w_out"], cfg, h, cfg.d_model)
    return dense(params["w_out"], h)


def gated_ffn_apply(params, cfg, x):
    """Shared-expert FFN on flat tokens (dict with w_in/w_gate/w_out)."""
    act = _activation(cfg.activation)
    h = act(dense(params["w_gate"], x)) * dense(params["w_in"], x)
    return dense(params["w_out"], h)


def ffn_apply_stacked(params, cfg, x):
    """Expert-stacked FFN: params (E, ..., ...), x (E, C, d) -> (E, C, d)."""
    act = _activation(cfg.activation)
    h_in = jnp.einsum("ecd,edf->ecf", x, params["w_in"].astype(x.dtype))
    h_gate = jnp.einsum("ecd,edf->ecf", x, params["w_gate"].astype(x.dtype))
    h = act(h_gate) * h_in
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(x.dtype))


# ---------------------------------------------------------------------------
# SparseLinear — RgCSR weights (the paper's technique in the LM)
# ---------------------------------------------------------------------------


def sparse_linear_spec(cfg, d_in: int, d_out: int):
    """Parameter spec for an RgCSR-stored weight matrix W ∈ (d_out, d_in).

    The stored layout is the kernel plan's slot-major 2-D tile:
    ``values2d (S, G)`` trainable, ``columns2d``/chunk tables frozen int32
    buffers (their inits build the structure deterministically from the
    PRNG key, so ``init_from_spec`` alone yields a valid sparse layer —
    including under layer-stacking, where each layer draws its own mask).
    S depends only on the *uniform-density* structured mask (every group
    gets K = density·d_in rounded to sublanes): static shapes, identical
    across hosts (an SPMD-init requirement).
    """
    g = cfg.sparsity.group_size
    n_groups = -(-d_out // g)
    k = max(8, int(round(cfg.sparsity.density * d_in)))
    k = -(-k // 8) * 8
    s_total = n_groups * k
    n_chunks = s_total // 8

    def init_columns(key, shape, dtype):
        # shape = (*lead, S, G): random sorted column sets per (group, lane)
        lead = shape[:-2]
        scores = jax.random.uniform(
            key, (*lead, n_groups, g, d_in))
        cols = jnp.argsort(scores, axis=-1)[..., :k]          # (…,ng,G,k)
        cols = jnp.sort(cols, axis=-1).astype(jnp.int32)
        cols = jnp.swapaxes(cols, -1, -2)                     # slot-major
        return cols.reshape(*lead, s_total, g)

    def init_chunk_group(key, shape, dtype):
        base = jnp.repeat(jnp.arange(n_groups, dtype=jnp.int32), k // 8)
        return jnp.broadcast_to(base, shape)

    def init_chunk_first(key, shape, dtype):
        base = jnp.zeros((n_chunks,), jnp.int32).at[
            jnp.arange(n_groups) * (k // 8)].set(1)
        return jnp.broadcast_to(base, shape)

    return {
        "values2d": P((s_total, g), (None, "sparse_rows"), init="fan_in",
                      scale=(d_in / max(1, k)) ** 0.5),  # variance-corrected
        "columns2d": P((s_total, g), (None, "sparse_rows"),
                       init=init_columns, dtype=jnp.int32),
        "chunk_group": P((n_chunks,), (None,), init=init_chunk_group,
                         dtype=jnp.int32),
        "chunk_first": P((n_chunks,), (None,), init=init_chunk_first,
                         dtype=jnp.int32),
    }


def sparse_linear_init_mask(key, cfg, d_in: int, d_out: int):
    """Build the frozen structure buffers (host-side numpy, deterministic)."""
    g = cfg.sparsity.group_size
    n_groups = -(-d_out // g)
    k = max(8, int(round(cfg.sparsity.density * d_in)))
    k = -(-k // 8) * 8
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    cols = np.stack([
        np.sort(rng.choice(d_in, size=k, replace=False)).astype(np.int32)
        for _ in range(n_groups * g)
    ])                                                    # (n_groups*g, k)
    cols = cols.reshape(n_groups, g, k).transpose(0, 2, 1)  # slot-major
    columns2d = cols.reshape(n_groups * k, g)
    chunks_per_group = k // 8
    chunk_group = np.repeat(np.arange(n_groups, dtype=np.int32), chunks_per_group)
    chunk_first = np.zeros(len(chunk_group), np.int32)
    chunk_first[np.arange(n_groups) * chunks_per_group] = 1
    return (jnp.asarray(columns2d), jnp.asarray(chunk_group),
            jnp.asarray(chunk_first))


def sparse_linear_apply(params, cfg, x, d_out: int):
    """y = x @ Wᵀ with W in RgCSR. x: (..., d_in) -> (..., d_out)."""
    g = cfg.sparsity.group_size
    lead = x.shape[:-1]
    d_in = x.shape[-1]
    xt = x.reshape(-1, d_in).T                            # (d_in, T)
    n_groups = -(-d_out // g)
    if cfg.sparsity.impl_is_kernel():
        from repro.kernels.ops import plan_from_params, rgcsr_spmm
        # memoized on the param identity (serving: built once per layer,
        # warmed by Engine.__init__); free under jit tracing
        plan = plan_from_params(params, x.dtype, d_out=d_out, d_in=d_in,
                                group_size=g)
        y = rgcsr_spmm(plan, xt)                          # (d_out, T)
    else:
        # jnp oracle: segment-sum over slot-major storage (SPMD-shardable)
        s_total = params["values2d"].shape[0]
        row_in_group = jnp.tile(jnp.arange(g), s_total)
        group_of_slotrow = jnp.repeat(params["chunk_group"], 8)
        rows = jnp.repeat(group_of_slotrow, g) * g + row_in_group
        vals = params["values2d"].astype(x.dtype).reshape(-1)
        cols = params["columns2d"].reshape(-1)
        gathered = jnp.take(xt, cols, axis=0)             # (S*G, T)
        y = jax.ops.segment_sum(vals[:, None] * gathered, rows,
                                num_segments=int(n_groups) * g)
    return y[:d_out].T.reshape(*lead, d_out)
