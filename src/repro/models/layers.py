"""Primitive layers: norms, dense projections, embeddings, RoPE.

Functional style: ``*_spec(cfg, ...)`` returns a :class:`repro.models.spec.P`
tree; ``*_apply(params, x, ...)`` consumes the matching param tree.  Logical
axis names used here (resolved to mesh axes by the partitioner):

=============  =====================================================
``vocab``      embedding rows — tensor-parallel over "model"
``embed``      d_model — FSDP-sharded over "data" for large params
``q_heads``    query heads — "model"
``kv_heads``   kv heads — "model" when divisible, else replicated
``head_dim``   per-head dim — never sharded
``mlp``        FFN hidden — "model"
``experts``    MoE expert dim — "model" (EP)
``norm``       norm scales — replicated
``ssm_*``      state-space dims
=============  =====================================================
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.spec import P

__all__ = [
    "rmsnorm_spec", "rmsnorm", "layernorm_spec", "layernorm",
    "dense_spec", "dense", "embed_spec", "embed_lookup", "embed_logits",
    "rope", "rope_positions", "make_causal_mask", "make_window_mask",
]

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int):
    return {"scale": P((d,), ("norm",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_spec(d: int):
    return {"scale": P((d,), ("norm",), init="ones"),
            "bias": P((d,), ("norm",), init="zeros")}


def layernorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------


def dense_spec(d_in: int, d_out: int, axes=("embed", "mlp"), bias: bool = False,
               scale: float = 1.0):
    spec = {"kernel": P((d_in, d_out), axes, init="fan_in", scale=scale)}
    if bias:
        spec["bias"] = P((d_out,), (axes[-1],), init="zeros")
    return spec


def dense(params, x):
    y = jnp.einsum("...i,io->...o", x, params["kernel"].astype(x.dtype))
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d: int, scale: float = 1.0):
    return {"table": P((vocab, d), ("vocab", "embed"), init="embed",
                       scale=scale)}


def embed_lookup(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def embed_logits(params, x):
    """Tied output head: logits = x @ tableᵀ."""
    return jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_positions(batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


def rope(x, positions, theta: float = 10_000.0):
    """x: (B, S, H, D) with D even; positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def make_causal_mask(q_len: int, kv_len: int, q_offset=0):
    """bool (q_len, kv_len): True = attend."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def make_window_mask(q_len: int, kv_len: int, window: int, q_offset=0):
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return (kv_pos <= q_pos) & (kv_pos > q_pos - window)
