"""Serving launcher: bring up the slot-based engine for an architecture.

Usage:
  python -m repro.launch.serve --arch granite-3-2b --smoke --requests 8
"""
import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke
    from repro.serve import Engine, Request, ServeConfig

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    eng = Engine(cfg, ServeConfig(max_seq=args.max_seq, n_slots=args.slots))
    rng = np.random.default_rng(0)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (16,)).astype(np.int32),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    done = eng.serve(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s); all done: {all(r.done for r in done)}")


if __name__ == "__main__":
    main()
