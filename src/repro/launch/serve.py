"""Serving launcher: bring up the paged continuous-batching engine.

Usage:
  python -m repro.launch.serve --arch granite-3-2b --smoke --requests 8 \
      --kv-layout paged --page-size 16 --mixed-lengths

Overload drills (DESIGN.md §6.4): shrink the pool below aggregate worst
case with --n-pages and the default prompt-pages admission policy serves
the queue via recompute preemption; --admission-policy worst_case restores
FIFO deferral; --deadline-s puts a completion deadline on every request;
--strict restores fail-stop serving (oversized requests raise).  The
overload report prints per-status counts and the preemption counters.
"""
import argparse
import time
from collections import Counter

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-layout", choices=("paged", "dense"),
                    default="paged")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page-pool size; 0 = dense capacity + null page "
                         "(size below worst case to exercise preemption)")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="cycle prompt lengths instead of a uniform 16")
    ap.add_argument("--admission-policy", choices=("prompt", "worst_case"),
                    default="prompt",
                    help="prompt: admit on resident pages, preempt on "
                         "exhaustion; worst_case: reserve the worst case "
                         "and defer admissions (PR 5 behavior)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request completion deadline in seconds from "
                         "serve() entry; 0 = none")
    ap.add_argument("--strict", action="store_true",
                    help="fail-stop: oversized requests / mid-request "
                         "faults raise out of serve() instead of failing "
                         "only that request")
    ap.add_argument("--straggler-factor", type=float, default=2.0,
                    help="watchdog: flag decode steps slower than this "
                         "factor times the EWMA step time")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke
    from repro.serve import Engine, Request, ServeConfig
    from repro.train.fault import FaultConfig

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    eng = Engine(cfg, ServeConfig(
        max_seq=args.max_seq, n_slots=args.slots, kv_layout=args.kv_layout,
        page_size=args.page_size, n_pages=args.n_pages,
        admission_policy=args.admission_policy, strict=args.strict,
        deadline_s=args.deadline_s),
        fault_cfg=FaultConfig(straggler_factor=args.straggler_factor))
    rng = np.random.default_rng(0)
    lengths = [16] * args.requests
    if args.mixed_lengths:
        mix = (8, 24, 16, 48)
        lengths = [min(mix[i % len(mix)], args.max_seq - args.max_new)
                   for i in range(args.requests)]
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ln,)).astype(np.int32),
                    max_new_tokens=args.max_new)
            for ln in lengths]
    t0 = time.time()
    done = eng.serve(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s); all done: {all(r.done for r in done)}")
    by_status = Counter(r.status for r in done)
    print("request status:", dict(sorted(by_status.items())))
    ps = eng.paging_stats
    if ps and ps.get("kv_layout") == "paged":
        print(f"paging: high-water {ps['page_high_water']} pages "
              f"({ps['paged_peak_tokens']} tokens; dense layout pins "
              f"{ps['dense_equiv_tokens']}), fragmentation at peak "
              f"{ps['frag_at_high_water']:.3f}, "
              f"{ps['admission_deferrals']} admission deferrals")
        print(f"overload: policy {ps['admission_policy']}, "
              f"{ps['preemptions']} preemptions "
              f"({ps['recompute_tokens']} recompute tokens, "
              f"{ps['pages_evicted']} pages evicted), "
              f"{ps['rejected']} rejected, {ps['failed']} failed, "
              f"{ps['timed_out']} timed out, "
              f"{ps['straggler_decode_steps']} straggler decode steps")


if __name__ == "__main__":
    main()
