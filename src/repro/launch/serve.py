"""Serving launcher: bring up the paged continuous-batching engine.

Usage:
  python -m repro.launch.serve --arch granite-3-2b --smoke --requests 8 \
      --kv-layout paged --page-size 16 --mixed-lengths
"""
import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-layout", choices=("paged", "dense"),
                    default="paged")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page-pool size; 0 = dense capacity + null page")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="cycle prompt lengths instead of a uniform 16")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke
    from repro.serve import Engine, Request, ServeConfig

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    eng = Engine(cfg, ServeConfig(
        max_seq=args.max_seq, n_slots=args.slots, kv_layout=args.kv_layout,
        page_size=args.page_size, n_pages=args.n_pages))
    rng = np.random.default_rng(0)
    lengths = [16] * args.requests
    if args.mixed_lengths:
        mix = (8, 24, 16, 48)
        lengths = [min(mix[i % len(mix)], args.max_seq - args.max_new)
                   for i in range(args.requests)]
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ln,)).astype(np.int32),
                    max_new_tokens=args.max_new)
            for ln in lengths]
    t0 = time.time()
    done = eng.serve(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s); all done: {all(r.done for r in done)}")
    ps = eng.paging_stats
    if ps and ps.get("kv_layout") == "paged":
        print(f"paging: high-water {ps['page_high_water']} pages "
              f"({ps['paged_peak_tokens']} tokens; dense layout pins "
              f"{ps['dense_equiv_tokens']}), fragmentation at peak "
              f"{ps['frag_at_high_water']:.3f}, "
              f"{ps['admission_deferrals']} admission deferrals")


if __name__ == "__main__":
    main()
