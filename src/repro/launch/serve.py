"""Serving launcher: bring up the paged continuous-batching engine, or a
fault-tolerant multi-replica router over it.

Usage:
  python -m repro.launch.serve --arch granite-3-2b --smoke --requests 8 \
      --kv-layout paged --page-size 16 --mixed-lengths

Overload drills (DESIGN.md §6.4): shrink the pool below aggregate worst
case with --n-pages and the default prompt-pages admission policy serves
the queue via recompute preemption; --admission-policy worst_case restores
FIFO deferral; --deadline-s puts a completion deadline on every request;
--strict restores fail-stop serving (oversized requests raise).  The
overload report prints per-status counts and the preemption counters.

Multi-replica drills (DESIGN.md §7):
  --replicas N      front N engine replicas (shared params, independent
                    KV pools) with the health-checked Router: failover
                    migrates in-flight requests off faulted replicas,
                    re-prefilling prompt + generated prefix on survivors.
  --router-queue K  bound the router queue at K waiting requests;
                    over-capacity arrivals are shed (status="shed")
                    instead of queueing unboundedly.  0 = unbounded.
  --retry-budget R  per-request migration budget AND per-replica restart
                    budget (FaultConfig.max_restarts).
  --drain I         drain replica I after the first scheduling round:
                    stop admitting to it, let residents finish, recycle
                    it with a fresh session (planned maintenance).
  --kill-replica I --kill-at-step K
                    inject a replica-tier fault (FaultInjector site
                    "replica") on replica I's K-th decode step — the
                    failover drill the router bench and tests run.

Crash-consistency drills (DESIGN.md §7.6):
  --snapshot-every N   write a crash-consistent snapshot (session or
                       whole-router state, train/checkpoint.py atomic
                       write + rolling retention) every N scheduling
                       rounds into --snapshot-dir.
  --restore-from DIR   start by restoring the latest snapshot under DIR
                       (the dead process's queue and in-flight requests
                       resume token-identically), then serve the new
                       requests behind them.
  --kill-process-at K  inject a ("process", K) fault: the whole process
                       dies at decode step K.  With --snapshot-every set
                       the launcher then runs the full drill in-process:
                       rebuild the fleet from params, restore the latest
                       snapshot, drain — the crash lane's CI check.
  --corrupt-page IDX   inject KV-page corruption into live page IDX at a
                       chunk boundary (--corrupt-nan: NaN poison caught
                       by the logit screen instead of silent garbage
                       caught by the checksum verify); requires
                       --kv-integrity for detection/recovery.
  --kv-integrity       arm per-page crc32 checksums + NaN/Inf logit
                       screening (detection quarantines the page and
                       recompute-preempts exactly the touched requests).

Observability (DESIGN.md §13):
  --trace-out PATH     attach a Tracer to every engine/router and export
                       the run's span timeline (request lifelines, prefill
                       and decode-chunk spans, fault/migration/restore
                       instants) as Chrome trace-event JSON at PATH —
                       loadable in Perfetto or chrome://tracing.  The
                       report also prints a span-timeline summary.
  --metrics-json PATH  write the final stats dict (merged metrics-registry
                       view, including request_timing histogram states and
                       latency percentiles) as JSON — the file CI's
                       check_trace.py cross-checks against the trace.
"""
import argparse
import sys
import time
from collections import Counter

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-layout", choices=("paged", "dense"),
                    default="paged")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="decode steps fused per on-device dispatch "
                         "(lax.while_loop chunk, DESIGN.md §7.1); 1 = "
                         "stepwise host sync every token")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page-pool size; 0 = dense capacity + null page "
                         "(size below worst case to exercise preemption)")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="cycle prompt lengths instead of a uniform 16")
    ap.add_argument("--admission-policy", choices=("prompt", "worst_case"),
                    default="prompt",
                    help="prompt: admit on resident pages, preempt on "
                         "exhaustion; worst_case: reserve the worst case "
                         "and defer admissions (PR 5 behavior)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request completion deadline in seconds from "
                         "the request's arrival; 0 = none")
    ap.add_argument("--strict", action="store_true",
                    help="fail-stop: oversized requests / mid-request "
                         "faults raise out of serve() instead of failing "
                         "only that request")
    ap.add_argument("--straggler-factor", type=float, default=2.0,
                    help="watchdog: flag decode steps slower than this "
                         "factor times the EWMA step time")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the router; 1 = single "
                         "engine, no router (DESIGN.md §7)")
    ap.add_argument("--router-queue", type=int, default=0,
                    help="router queue bound; arrivals beyond it are shed "
                         "(status=\"shed\"); 0 = unbounded")
    ap.add_argument("--retry-budget", type=int, default=3,
                    help="per-request migration / per-replica restart "
                         "budget (FaultConfig.max_restarts)")
    ap.add_argument("--drain", type=int, default=-1, metavar="REPLICA",
                    help="drain this replica index after the first round "
                         "(finish residents, recycle); -1 = off")
    ap.add_argument("--kill-replica", type=int, default=-1,
                    help="inject a replica-tier fault on this replica "
                         "index (failover drill); -1 = off")
    ap.add_argument("--kill-at-step", type=int, default=2,
                    help="decode step of the injected replica fault")
    ap.add_argument("--kv-integrity", action="store_true",
                    help="arm per-page checksums + NaN/Inf logit "
                         "screening (DESIGN.md §7.6)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="write a crash-consistent snapshot every N "
                         "scheduling rounds; 0 = off")
    ap.add_argument("--snapshot-dir", default="snapshots_serve",
                    help="directory for --snapshot-every / the crash "
                         "drill's restore point")
    ap.add_argument("--restore-from", default="",
                    help="restore the latest snapshot under this "
                         "directory before serving new requests")
    ap.add_argument("--kill-process-at", type=int, default=-1,
                    help="inject a (\"process\", K) fault at decode step "
                         "K; with --snapshot-every the launcher rebuilds "
                         "and restores in-process (crash drill); -1 = off")
    ap.add_argument("--corrupt-page", type=int, default=-1,
                    help="corrupt live KV page IDX at a chunk boundary "
                         "(page-corruption drill); -1 = off")
    ap.add_argument("--corrupt-nan", action="store_true",
                    help="NaN-poison the corrupted page (logit-screen "
                         "path) instead of silent garbage (checksum path)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="record a per-request span timeline and write it "
                         "as Chrome trace-event JSON (load in Perfetto / "
                         "chrome://tracing) to PATH (DESIGN.md §13)")
    ap.add_argument("--metrics-json", default="", metavar="PATH",
                    help="write the final stats dict (the merged metrics "
                         "registry view) as JSON to PATH")
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke
    from repro.serve import Engine, Request, Router, RouterConfig, \
        ServeConfig
    from repro.train.checkpoint import SnapshotManager, restore_snapshot
    from repro.train.fault import FaultConfig, FaultInjector, ProcessKilled

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    scfg = ServeConfig(
        max_seq=args.max_seq, n_slots=args.slots, kv_layout=args.kv_layout,
        page_size=args.page_size, n_pages=args.n_pages,
        decode_chunk=args.decode_chunk,
        admission_policy=args.admission_policy, strict=args.strict,
        deadline_s=args.deadline_s, kv_integrity=args.kv_integrity)
    fault_cfg = FaultConfig(straggler_factor=args.straggler_factor,
                            max_restarts=args.retry_budget)
    fail_at = []
    if args.kill_process_at >= 0:
        fail_at.append(("process", args.kill_process_at))
    if args.corrupt_page >= 0:
        fail_at.append(("page_nan" if args.corrupt_nan else "page",
                        args.corrupt_page))
    injector = FaultInjector(fail_at_steps=fail_at) if fail_at else None
    tracer = None
    if args.trace_out:
        from repro.obs.trace import Tracer
        tracer = Tracer()
    write_mgr = SnapshotManager(args.snapshot_dir) \
        if args.snapshot_every > 0 else None
    rng = np.random.default_rng(0)
    lengths = [16] * args.requests
    if args.mixed_lengths:
        mix = (8, 24, 16, 48)
        lengths = [min(mix[i % len(mix)], args.max_seq - args.max_new)
                   for i in range(args.requests)]
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ln,)).astype(np.int32),
                    max_new_tokens=args.max_new)
            for ln in lengths]

    restored = []
    crash_recovered = False
    snap_seq = None
    if args.replicas > 1:
        first = Engine(cfg, scfg, fault_cfg=fault_cfg)
        engines = [first] + [Engine(cfg, scfg, params=first.params,
                                    fault_cfg=fault_cfg)
                             for _ in range(args.replicas - 1)]
        if 0 <= args.kill_replica < len(engines):
            engines[args.kill_replica].fault_injector = FaultInjector(
                fail_at_steps=(("replica", args.kill_at_step),))
        if injector is not None:
            # process/page sites fire once — sharing the injector arms
            # whichever replica reaches the step first
            for e in engines:
                e.fault_injector = injector

        def build_router(es):
            # the same tracer survives the crash-drill rebuild, so the
            # exported timeline spans the whole run including recovery
            return Router(es, cfg=RouterConfig(
                n_replicas=args.replicas, queue_limit=args.router_queue),
                fault_cfg=fault_cfg, tracer=tracer)

        router = build_router(engines)
        if args.restore_from:
            restored = router.restore(restore_snapshot(args.restore_from))
        t0 = time.time()
        for r in reqs:
            router.submit(r)
        rounds = 0
        try:
            while not router.idle:
                if write_mgr and rounds % args.snapshot_every == 0:
                    write_mgr.save(router.snapshot())
                router.run_round()
                rounds += 1
                if rounds == 1 and 0 <= args.drain < len(engines):
                    router.drain_replica(args.drain)
        except ProcessKilled as exc:
            if write_mgr is None:
                raise
            # the whole-process crash drill: every replica, session, and
            # queue is gone — rebuild the fleet from params and resume
            # from the last crash-consistent snapshot
            crash_recovered = True
            print(f"process killed ({exc!r}); rebuilding the fleet and "
                  "restoring the latest snapshot")
            engines = [Engine(cfg, scfg, params=first.params,
                              fault_cfg=fault_cfg)
                       for _ in range(args.replicas)]
            router = build_router(engines)
            state, snap_seq = write_mgr.restore_latest()
            restored = router.restore(state)
            while not router.idle:
                router.run_round()
        dt = time.time() - t0
        done = [r for r in reqs if r.done] + restored
        ps = router.stats()
    else:
        eng = Engine(cfg, scfg, fault_cfg=fault_cfg,
                     fault_injector=injector)
        if tracer is not None:
            eng.tracer = tracer       # before any session is started
        t0 = time.time()
        if write_mgr is None and not args.restore_from:
            done = eng.serve(reqs)
            dt = time.time() - t0
            ps = eng.paging_stats
        else:
            sess = eng.start_session()
            if args.restore_from:
                restored = sess.restore(
                    restore_snapshot(args.restore_from))
            for r in reqs:
                sess.submit(r)
            rounds = 0
            try:
                while not sess.idle:
                    if write_mgr and rounds % args.snapshot_every == 0:
                        write_mgr.save(sess.snapshot())
                    sess.step(max(1, args.decode_chunk))
                    rounds += 1
            except ProcessKilled as exc:
                if write_mgr is None:
                    raise
                crash_recovered = True
                print(f"process killed ({exc!r}); rebuilding the engine "
                      "and restoring the latest snapshot")
                eng = Engine(cfg, scfg, params=eng.params,
                             fault_cfg=fault_cfg)
                if tracer is not None:
                    eng.tracer = tracer
                state, snap_seq = write_mgr.restore_latest()
                sess, restored = eng.restore_session(state)
                sess.drain()
            dt = time.time() - t0
            done = [r for r in reqs if r.done] + restored
            eng.paging_stats = sess.stats_snapshot()
            ps = eng.paging_stats

    total = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s); all done: {all(r.done for r in done)}")
    by_status = Counter(r.status for r in done)
    print("request status:", dict(sorted(by_status.items())))
    if ps:
        d = max(ps.get("decode_dispatches", 0), 1)
        print(f"fused decode: {ps['decode_steps']} decode steps in "
              f"{ps.get('decode_dispatches', 0)} dispatches "
              f"(chunk {args.decode_chunk}, "
              f"{ps['decode_steps'] / d:.1f} tokens/dispatch)")
    if ps and ps.get("kv_layout") == "paged":
        print(f"paging: high-water {ps['page_high_water']} pages, "
              f"{ps['admission_deferrals']} admission deferrals")
        print(f"overload: policy {ps['admission_policy']}, "
              f"{ps['preemptions']} preemptions "
              f"({ps['recompute_tokens']} recompute tokens, "
              f"{ps['pages_evicted']} pages evicted), "
              f"{ps['rejected']} rejected, {ps['failed']} failed, "
              f"{ps['timed_out']} timed out, "
              f"{ps['straggler_decode_steps']} straggler decode steps")
    if crash_recovered:
        n_ok = sum(r.ok_like for r in restored)
        print(f"crash drill: restored {len(restored)} requests from "
              f"snapshot seq {snap_seq}; {n_ok} completed ok, "
              f"{len(restored) - n_ok} not ok")
    if args.kv_integrity and ps:
        print(f"integrity: {ps.get('nonfinite_logits', 0)} non-finite "
              f"logit events, {ps.get('pages_quarantined', 0)} pages "
              f"quarantined, {ps.get('double_release', 0)} double "
              f"releases, {ps.get('restores', 0)} restores "
              f"({ps.get('restore_recompute_tokens', 0)} restore-"
              "recompute tokens)")
    if args.replicas > 1:
        print(f"router: {ps['n_replicas']} replicas "
              f"{ps['replica_states']}, per-replica page high-water "
              f"{ps.get('page_high_water_per_replica')}, "
              f"{ps['migrations']} migrations, "
              f"{ps['replica_faults']} replica faults / "
              f"{ps['replica_restarts']} restarts, "
              f"{ps['retries_exhausted']} retry-budget exhaustions, "
              f"{ps['shed']} shed, {ps['drains']} drains")
    if ps and ps.get("latency_percentiles"):
        parts = []
        for name in ("queue_s", "prefill_s", "latency_s"):
            q = ps["latency_percentiles"].get(name)
            if q:
                parts.append(f"{name} p50/p95/p99 = {q['p50'] * 1e3:.1f}/"
                             f"{q['p95'] * 1e3:.1f}/{q['p99'] * 1e3:.1f} ms")
        if parts:
            print("percentiles:", "; ".join(parts))
    if args.metrics_json:
        import json
        with open(args.metrics_json, "w") as fh:
            json.dump(ps, fh, indent=2, sort_keys=True,
                      default=lambda o: o.item() if hasattr(o, "item")
                      else str(o))
        print(f"metrics written to {args.metrics_json}")
    if tracer is not None:
        from repro.obs import export as obs_export
        obs_export.export_chrome_trace(tracer, args.trace_out)
        summ = obs_export.span_summary(tracer)
        spans = ", ".join(
            f"{name}×{s['n']} ({s['total_s']:.3f}s total, "
            f"{s['mean_s'] * 1e3:.1f}ms mean)"
            for name, s in sorted(summ["spans"].items()))
        events = ", ".join(f"{name}×{n}" for name, n
                           in sorted(summ["events"].items()))
        print(f"span timeline: {spans or 'none'}")
        print(f"trace events: {events or 'none'}")
        print(f"trace written to {args.trace_out} "
              f"({len(tracer.events)} events)")
    # chaos-lane gate (CI): a drill run must leave no request unfinished,
    # and under an injected kill or page corruption every request must end
    # in an ok-like state — anything else is a recovery bug, exit non-zero
    if not all(r.done for r in done):
        print("# FAIL: unfinished requests", file=sys.stderr)
        return 1
    drill = crash_recovered or args.corrupt_page >= 0 \
        or args.kill_process_at >= 0
    if drill and any(not r.ok_like for r in done):
        print("# FAIL: a request did not survive the fault drill",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
