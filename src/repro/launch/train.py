"""Production training launcher.

On a real TPU cluster this is the per-host entry point (``jax.distributed``
initializes from the TPU environment; the mesh spans all chips).  On CPU it
runs the same code path over however many devices exist — used by the
multi-device integration tests via the host-platform flag.

Usage:
  python -m repro.launch.train --arch granite-3-2b --steps 100 \
      [--mesh 16x16] [--smoke] [--sparse-ffn]
"""
import argparse
import dataclasses
import logging
import os

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 16x16 (data x model); default: single device")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--sparse-ffn", action="store_true")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    # multi-host: initialize the distributed runtime when launched by a
    # cluster scheduler (JAX_COORDINATOR_ADDRESS set per host)
    if os.environ.get("JAX_COORDINATOR_ADDRESS"):
        jax.distributed.initialize()

    from repro.configs import get_config, get_smoke
    from repro.configs.base import SparsityConfig
    from repro.launch.mesh import make_mesh
    from repro.sharding import Partitioner
    from repro.train import TrainConfig, Trainer
    from repro.train.optimizer import OptimizerConfig

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.sparse_ffn:
        cfg = dataclasses.replace(cfg, sparsity=SparsityConfig(
            enabled=True, density=0.25, group_size=128, impl="ref"))

    mesh = part = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[: len(shape)] if len(shape) == 2 \
            else ("pod", "data", "model")
        mesh = make_mesh(shape, axes)
        part = Partitioner(mesh, "train")
        cfg = dataclasses.replace(
            cfg, act_shard=True,
            mesh_batch_axes=("pod", "data") if len(shape) == 3 else ("data",))

    seq = args.seq or (32 if args.smoke else 4096)
    batch = args.batch or (8 if args.smoke else 256)
    tc = TrainConfig(steps=args.steps, microbatches=args.micro,
                     ckpt_dir=args.ckpt_dir,
                     opt=OptimizerConfig(name=args.optimizer,
                                         warmup_steps=max(args.steps // 20, 5),
                                         decay_steps=args.steps))
    trainer = Trainer(cfg, tc, mesh=mesh, partitioner=part)
    state = trainer.init_state(seq_len=seq, global_batch=batch)
    if mesh is not None:
        with mesh:
            state, step = trainer.run(state)
    else:
        state, step = trainer.run(state)
    print(f"done: {step} steps, final loss "
          f"{trainer.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
