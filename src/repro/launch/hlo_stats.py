"""HLO text analysis: collective traffic for the roofline's third term.

``cost_analysis()`` does not expose collective bytes, so we parse the
post-SPMD HLO (``compiled.as_text()``) and sum, per collective kind, the
per-device traffic with the standard ring-algorithm byte model:

=================== ===========================================
all-gather           (n-1)/n · result_bytes
reduce-scatter       (n-1)/n · operand_bytes (≈ n · result)
all-reduce           2 · (n-1)/n · operand_bytes  (RS + AG ring)
all-to-all           (n-1)/n · operand_bytes
collective-permute   operand_bytes
=================== ===========================================

where ``n`` is the replica-group size parsed from ``replica_groups`` (both
the explicit ``{{0,1,…},…}`` and iota ``[g,n]<=[N]`` forms).
"""
from __future__ import annotations

import re
from typing import Dict, List

import numpy as np

__all__ = ["collective_stats", "parse_shape_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# an HLO instruction line: "  %name = <shape(s)> <opcode>(...)"
_INSTR_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([a-z0-9-]+)\(")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")


def parse_shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[shape] token in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        ids = [tok for tok in re.split(r"[{,\s]+", first) if tok]
        return max(1, len(ids))
    return default


def collective_stats(hlo_text: str, n_devices: int) -> Dict:
    """Per-kind instruction counts and per-device traffic bytes."""
    stats = {k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        opcode = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if opcode == c or opcode.startswith(c + "-"):
                kind = c
                break
        if kind is None:
            continue
        shape_txt = m.group(1) or m.group(2)
        result_bytes = parse_shape_bytes(shape_txt)
        n = max(2, _group_size(line, n_devices))
        frac = (n - 1) / n
        if kind == "all-gather":
            traffic = frac * result_bytes
        elif kind == "reduce-scatter":
            traffic = frac * result_bytes * n
        elif kind == "all-reduce":
            traffic = 2.0 * frac * result_bytes
        elif kind == "all-to-all":
            traffic = frac * result_bytes
        else:  # collective-permute
            traffic = float(result_bytes)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += traffic
    stats["total_bytes"] = float(sum(v["bytes"] for k, v in stats.items()
                                     if isinstance(v, dict)))
    stats["total_count"] = int(sum(v["count"] for k, v in stats.items()
                                   if isinstance(v, dict)))
    return stats
