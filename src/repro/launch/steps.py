"""Step functions the launchers / dry-run lower: train, prefill, decode.

``make_train_step`` builds the full production step — loss, backward,
global-norm clip, optimizer update — with **microbatch gradient
accumulation** (lax.scan over microbatches): the activation-memory knob that
makes 4k-seq training of the large archs fit HBM (napkin math per cell in
EXPERIMENTS.md §Dry-run).  Gradients accumulate in the parameter dtype.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import OptimizerConfig, clip_by_global_norm, \
    make_optimizer

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "auto_microbatches"]


def auto_microbatches(cfg, global_batch: int, seq: int, n_data_shards: int,
                      budget_bytes: float = 2.0e9) -> int:
    """Pick a microbatch count so that per-device residual-stream
    checkpoints (the dominant remat-surviving activations) fit the budget:

        ceil( B_dev/µ · S · d_model · 2B · n_layers / budget )

    Clamped to divide the per-device batch evenly.
    """
    b_dev = max(1, global_batch // n_data_shards)
    per_layer = seq * cfg.d_model * 2
    total = b_dev * per_layer * cfg.n_layers
    mb = max(1, int(-(-total // budget_bytes)))
    while b_dev % mb:
        mb += 1
    return min(mb, b_dev)


def make_train_step(model, opt_cfg: OptimizerConfig, microbatches: int = 1):
    opt_init, opt_update = make_optimizer(opt_cfg)

    def loss_fn(params, mb):
        return model.loss(params, mb)

    # allow_int: frozen int32 structure buffers (RgCSR SparseLinear) ride
    # along in the param tree and receive float0 tangents, which the
    # accumulator and both optimizers skip.
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True, allow_int=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def _is_f0(g):
                return getattr(g, "dtype", None) == jax.dtypes.float0

            def body(acc, mb):
                (loss, metrics), grads = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a if _is_f0(g) else a + g, acc, grads)
                return acc, (loss, metrics["ce"])

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype)
                if jnp.issubdtype(p.dtype, jnp.floating)
                else np.zeros(p.shape, jax.dtypes.float0), params)
            grads, (losses, ces) = jax.lax.scan(body, zeros, micro)
            grads = jax.tree_util.tree_map(
                lambda g: g if _is_f0(g)
                else (g.astype(jnp.float32) / microbatches).astype(g.dtype),
                grads)
            loss = jnp.mean(losses)
            metrics = {"ce": jnp.mean(ces)}
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
        new_params, new_opt_state = opt_update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return new_params, new_opt_state, metrics

    return train_step, opt_init


def make_prefill_step(model, s_max: int, shape_kind: str = "prefill"):
    def prefill_step(params, batch):
        return model.prefill(params, batch, s_max, shape_kind=shape_kind)
    return prefill_step


def make_decode_step(model, shape_kind: str = "decode"):
    """One decode-step factory, shared with the serving engine: delegates
    to ``serve/device_loop.make_decode_step`` so the dry-run lowers the
    exact step the fused serving loop runs (imported lazily — the
    launcher must stay importable without pulling the serve stack in)."""
    from repro.serve.device_loop import make_decode_step as _make
    return _make(model, shape_kind=shape_kind)
