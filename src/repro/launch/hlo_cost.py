"""Loop-aware HLO cost model (flops + HBM traffic) from post-SPMD HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every while-loop body
**once** — with scan-over-layers, microbatch accumulation and chunked
attention all lowered to ``while`` loops, it undercounts a 61-layer model by
~two orders of magnitude (verified in tests/test_hlo_cost.py).  This module
parses ``compiled.as_text()`` and walks the call graph, multiplying each
``while`` body by its trip count (taken from XLA's
``backend_config={"known_trip_count":…}`` — all our loops are static-trip
jax scans; fallback: the compare constant in the loop condition).

Cost model (documented in EXPERIMENTS.md §Roofline):

* **flops** — MXU work only: ``dot`` = 2·prod(result)·prod(contracting),
  counted wherever it appears (incl. inside fusions), × loop multiplier.
  VPU elementwise flops are excluded, matching MFU conventions.
* **bytes** — *fusion-idealized* HBM traffic model: only instructions that
  materialize buffers on a TPU backend are counted (dot, reduce, gather/
  scatter, dynamic-(update-)slice, concatenate, convolution, sort,
  collectives, copy), bytes = result + operand sizes, × loop multiplier.
  Pure-elementwise ops and the CPU backend's tiny wrapper fusions are
  skipped — TPU XLA fuses elementwise chains into their producers/consumers,
  so counting them (measured: 90% of raw traffic on the CPU module) would
  model the wrong backend.  This still captures the buffers that dominate a
  real TPU profile: weights feeding dots, attention score blocks, KV-cache
  updates, collective payloads.
* **collectives** — ring-model per-device traffic (see table in
  launch/hlo_stats.py), × loop multiplier: a collective inside the layer
  scan runs every layer, which a single-visit parse would undercount.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# result is either a tuple shape `(…)` (may contain /*index=N*/ comments but
# never nested parens) or a single `dtype[dims]{layout}`
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s*([a-z0-9-]+)\((.*)$")
_CALLED_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose buffers materialize in HBM on a fused TPU backend
_MATERIALIZING = {
    "dot", "convolution", "reduce", "reduce-window", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "sort", "copy",
    "select-and-scatter", "pad", "cholesky", "triangular-solve", "fft",
    "custom-call",
} | set(_COLLECTIVES)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _bytes_of(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_txt: str
    args_txt: str
    operands: List[str]
    called: List[str]


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_bytes: float
    collective_counts: Dict[str, int]
    collective_bytes_by_kind: Dict[str, float]
    loops: Dict[str, int]
    dot_flops_by_shape: Dict[str, float]
    # f32 collectives sitting directly on dot outputs: on the CPU backend
    # bf16 dots are upcast to f32 and the TP all-reduce lands on the f32
    # tensor; a TPU backend reduces these in bf16.  collective_bytes minus
    # half of this bucket = the TPU-corrected collective traffic.
    collective_bytes_f32_dot: float = 0.0

    @property
    def collective_bytes_tpu(self) -> float:
        return self.collective_bytes - 0.5 * self.collective_bytes_f32_dot


def _operand_list(args_txt: str) -> List[str]:
    """Operand %names inside the instruction's argument parens (before any
    attribute list — attributes never contain bare %names except the called
    computations, which are parsed separately)."""
    depth = 1
    for i, ch in enumerate(args_txt):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _OPERAND_RE.findall(args_txt[:i])
    return _OPERAND_RE.findall(args_txt)


def _parse_computations(text: str):
    comps: Dict[str, List[_Instr]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if current is None:
            if stripped.endswith("{") and "->" in stripped:
                is_entry = stripped.startswith("ENTRY")
                name = stripped.split()[1 if is_entry else 0]
                name = name.lstrip("%").split("(")[0].rstrip(".")
                # header like `%region_0.2 (args...) -> ... {`
                name = re.match(r"[\w\.\-]+", stripped.lstrip("ENTRY ").lstrip("%")).group(0)
                comps[name] = []
                current = name
                if is_entry:
                    entry = name
            continue
        if stripped == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        iname, result_txt, opcode, rest = m.groups()
        called = _CALLED_RE.findall(rest)
        bm = _BRANCHES_RE.search(rest)
        if bm:
            called += [c.strip().lstrip("%") for c in bm.group(1).split(",")]
        comps[current].append(
            _Instr(iname, opcode, result_txt, rest, _operand_list(rest),
                   called))
    return comps, entry


def _collective_kind(opcode: str) -> Optional[str]:
    for c in _COLLECTIVES:
        if opcode == c or opcode.startswith(c + "-"):
            return c
    return None


_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")


def _group_size(args: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(args)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(args)
    if m:
        first = m.group(1).split("}")[0]
        ids = [tok for tok in re.split(r"[{,\s]+", first) if tok]
        return max(1, len(ids))
    return default


def analyze_hlo(text: str, n_devices: int) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")

    # symbol tables: instruction name -> result text, per computation
    symtab: Dict[str, Dict[str, str]] = {
        cname: {i.name: i.result_txt for i in instrs}
        for cname, instrs in comps.items()
    }

    def dot_flops(comp: str, ins: _Instr) -> float:
        result_elems = sum(_shape_elems(d) for t, d in
                           _SHAPE_RE.findall(ins.result_txt)
                           if t in _DTYPE_BYTES)
        if not ins.operands:
            return 0.0
        lhs_txt = symtab[comp].get(ins.operands[0], "")
        lhs_shapes = [d for t, d in _SHAPE_RE.findall(lhs_txt)
                      if t in _DTYPE_BYTES]
        if not lhs_shapes:
            return 0.0
        lhs_dims = lhs_shapes[0].split(",") if lhs_shapes[0] else []
        cm = _CONTRACT_RE.search(ins.args_txt)
        contract = 1
        if cm and cm.group(1):
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    contract *= int(lhs_dims[i])
        return 2.0 * result_elems * contract

    def trip_count(ins: _Instr) -> int:
        m = _TRIP_RE.search(ins.args_txt)
        if m:
            return int(m.group(1))
        cm = _COND_RE.search(ins.args_txt)
        cond = cm.group(1) if cm else None
        best = 1
        for ci in comps.get(cond, []):
            for mm in _CONST_INT_RE.finditer(ci.args_txt):
                best = max(best, int(mm.group(1)))
        return best

    def operand_bytes(comp: str, ins: _Instr) -> int:
        return sum(_bytes_of(symtab[comp].get(op, "")) for op in ins.operands)

    memo: Dict[str, Tuple] = {}
    loops: Dict[str, int] = {}
    dot_shapes: Dict[str, float] = {}

    def cost(comp: str, in_fusion: bool = False):
        key = comp + ("|f" if in_fusion else "")
        if key in memo:
            return memo[key]
        memo[key] = (0.0, 0.0, 0.0, {}, {}, 0.0)  # cycle guard
        flops = byts = coll = coll_f32dot = 0.0
        ccounts: Dict[str, int] = {}
        cbytes: Dict[str, float] = {}
        for ins in comps.get(comp, []):
            if ins.opcode == "dot":
                f = dot_flops(comp, ins)
                flops += f
                dot_shapes[ins.result_txt] = dot_shapes.get(ins.result_txt,
                                                            0.0) + f
            kind = _collective_kind(ins.opcode)
            if kind is not None:
                result_bytes = _bytes_of(ins.result_txt)
                n = max(2, _group_size(ins.args_txt, n_devices))
                frac = (n - 1) / n
                if kind == "all-gather":
                    traffic = frac * result_bytes
                elif kind == "reduce-scatter":
                    traffic = frac * result_bytes * n
                elif kind == "all-reduce":
                    traffic = 2.0 * frac * result_bytes
                elif kind == "all-to-all":
                    traffic = frac * result_bytes
                else:
                    traffic = float(result_bytes)
                coll += traffic
                ccounts[kind] = ccounts.get(kind, 0) + 1
                cbytes[kind] = cbytes.get(kind, 0.0) + traffic
                if kind == "all-reduce" and "f32[" in ins.result_txt \
                        and "dot_general" in ins.args_txt:
                    coll_f32dot += traffic
            if not in_fusion and ins.opcode in _MATERIALIZING:
                byts += _bytes_of(ins.result_txt) + operand_bytes(comp, ins)
            if ins.opcode == "while":
                bm_ = _BODY_RE.search(ins.args_txt)
                body = bm_.group(1) if bm_ else None
                trips = trip_count(ins)
                loops[f"{comp}/{ins.name}"] = trips
                if body:
                    f2, b2, c2, cc2, cb2, cf2 = cost(body)
                    flops += trips * f2
                    byts += trips * b2
                    coll += trips * c2
                    coll_f32dot += trips * cf2
                    for k, v in cc2.items():
                        ccounts[k] = ccounts.get(k, 0) + trips * v
                    for k, v in cb2.items():
                        cbytes[k] = cbytes.get(k, 0.0) + trips * v
            elif ins.called:
                # fusion / call / reduce / scatter / conditional / sort …
                inner_fusion = in_fusion or ins.opcode == "fusion" \
                    or ins.opcode not in ("call", "conditional")
                for c in ins.called:
                    f2, b2, c2, cc2, cb2, cf2 = cost(c, in_fusion=inner_fusion)
                    flops += f2
                    byts += 0.0 if inner_fusion else b2
                    coll += c2
                    coll_f32dot += cf2
                    for k, v in cc2.items():
                        ccounts[k] = ccounts.get(k, 0) + v
                    for k, v in cb2.items():
                        cbytes[k] = cbytes.get(k, 0.0) + v
        memo[key] = (flops, byts, coll, ccounts, cbytes, coll_f32dot)
        return memo[key]

    f, b, c, cc, cb, cf = cost(entry)
    return HloCost(flops=f, bytes=b, collective_bytes=c,
                   collective_counts=cc, collective_bytes_by_kind=cb,
                   loops=loops, dot_flops_by_shape=dot_shapes,
                   collective_bytes_f32_dot=cf)
