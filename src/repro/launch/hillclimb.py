"""§Perf hillclimb driver: hypothesis → change → re-lower → measure.

Three cells (selection rationale in EXPERIMENTS.md §Perf):
  H1 granite-3-2b × train_4k      — worst roofline fraction, collective-bound
  H2 deepseek-v3-671b × prefill_32k — most compute-waste, MoE dispatch
  H3 granite-3-2b × train_4k + RgCSR sparse FFN — the paper's technique

Each iteration is one `run_cell` with a config/rules override; results are
appended to results/hillclimb.jsonl with the iteration's hypothesis string,
so EXPERIMENTS.md §Perf is generated from measured records.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
import argparse
import json
import time

from repro.launch.dryrun import run_cell

H1 = [
    dict(name="h1.0-baseline",
         hypothesis="paper-faithful baseline: FSDP(embed->data)+TP, remat "
                    "full, auto microbatches=8",
         arch="granite-3-2b", shape="train_4k", kw={}),
    dict(name="h1.1-no-fsdp",
         hypothesis="2.5B params fit TP-only (0.6GB/dev params+opt): drop "
                    "FSDP -> weight re-gathers (x8 microbatches x fwd/remat/"
                    "bwd) vanish; expect collective term down ~5-10x, memory "
                    "term down (no gathered-weight writes)",
         arch="granite-3-2b", shape="train_4k",
         kw=dict(rules_override={"embed": None})),
    dict(name="h1.2-micro4",
         hypothesis="halving microbatches 8->4 halves per-step weight "
                    "re-reads; activation checkpoints double (fits after "
                    "h1.1): expect memory term down, compute unchanged",
         arch="granite-3-2b", shape="train_4k",
         kw=dict(rules_override={"embed": None}, microbatch_override=4)),
    dict(name="h1.3-remat-dots",
         hypothesis="remat 'dots' keeps matmul outputs (no fwd recompute of "
                    "dots in bwd): expect compute term down ~20-25%, memory "
                    "(activation) term up",
         arch="granite-3-2b", shape="train_4k",
         kw=dict(rules_override={"embed": None}, microbatch_override=4,
                 cfg_overrides={"remat": "dots"})),
    dict(name="h1.4-seq-attn",
         hypothesis="attention TP via 'seq' (context-parallel q) instead of "
                    "'repeat' avoids materializing repeated kv: expect "
                    "memory down slightly, collective up (kv all-gather)",
         arch="granite-3-2b", shape="train_4k",
         kw=dict(rules_override={"embed": None}, microbatch_override=4,
                 cfg_overrides={"remat": "dots", "attn_shard_mode": "seq"})),
    dict(name="h1.5-bf16-comms",
         hypothesis="the 92%-dominant f32[2,4096,2048] all-reduces are TP "
                    "output reductions on CPU-upcast bf16 dots; TPU reduces "
                    "them at bf16 -> corrected collective term ~0.55x of "
                    "h1.3 (measured via the f32-dot collective bucket)",
         arch="granite-3-2b", shape="train_4k",
         kw=dict(rules_override={"embed": None}, microbatch_override=4,
                 cfg_overrides={"remat": "dots"})),
]

H2 = [
    dict(name="h2.0-baseline",
         hypothesis="paper-faithful GShard einsum dispatch: dispatch/combine "
                    "einsums cost 2*T*E*C*d flops/layer ~ O(100x) the expert "
                    "FFN flops at T=1M tokens",
         arch="deepseek-v3-671b", shape="prefill_32k", kw={}),
    dict(name="h2.1-scatter-dispatch",
         hypothesis="sort-based scatter dispatch moves tokens with gathers "
                    "(0 flops): expect HLO flops down ~10-100x, "
                    "MODEL_FLOPS ratio toward ~0.5+, bottleneck flips to "
                    "memory/collective",
         arch="deepseek-v3-671b", shape="prefill_32k",
         kw=dict(cfg_overrides={"moe": {"dispatch": "scatter"}})),
    dict(name="h2.2-capacity-1.0",
         hypothesis="capacity factor 1.25->1.0 cuts expert buffer (E,C,d) "
                    "by 20%: expect memory term down ~10-20% on top of h2.1",
         arch="deepseek-v3-671b", shape="prefill_32k",
         kw=dict(cfg_overrides={"moe": {"dispatch": "scatter",
                                        "capacity_factor": 1.0}})),
]

H3 = [
    dict(name="h3.0-dense-ffn-ref",
         hypothesis="dense-FFN reference point for the sparse cells "
                    "(same arch/shape as h1.1)",
         arch="granite-3-2b", shape="train_4k",
         kw=dict(rules_override={"embed": None})),
    dict(name="h3.1-rgcsr-ffn-d25",
         hypothesis="RgCSR FFN down-proj at 25% density: FFN w_out dot "
                    "flops (2*T*dff*d) replaced by gather+segsum bytes; "
                    "expect compute term down ~15% (w_out is ~1/3 of FFN), "
                    "memory term up (ref-impl gather traffic)",
         arch="granite-3-2b", shape="train_4k",
         kw=dict(rules_override={"embed": None},
                 cfg_overrides={"sparsity": {"enabled": True,
                                             "density": 0.25,
                                             "impl": "ref"}})),
    dict(name="h3.2-rgcsr-ffn-d125",
         hypothesis="halving density 0.25->0.125 halves sparse bytes: "
                    "expect memory delta vs h3.1 ~2x smaller sparse term",
         arch="granite-3-2b", shape="train_4k",
         kw=dict(rules_override={"embed": None},
                 cfg_overrides={"sparsity": {"enabled": True,
                                             "density": 0.125,
                                             "impl": "ref"}})),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--series", default="h1,h2,h3")
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args(argv)
    series = {"h1": H1, "h2": H2, "h3": H3}
    todo = [s.strip() for s in args.series.split(",")]
    with open(args.out, "a") as f:
        for s in todo:
            for it in series[s]:
                t0 = time.time()
                try:
                    rec = run_cell(it["arch"], it["shape"], **it["kw"])
                    rec["status"] = "ok"
                except Exception as e:  # noqa: BLE001
                    rec = {"status": "error", "error": repr(e)}
                rec["iter"] = it["name"]
                rec["hypothesis"] = it["hypothesis"]
                rec["wall_s"] = round(time.time() - t0, 1)
                f.write(json.dumps(rec) + "\n")
                f.flush()
                rl = rec.get("roofline", {})
                print(f"[{it['name']}] {rec['status']} "
                      f"compute={rl.get('compute_s', 0):.3f}s "
                      f"mem={rl.get('memory_s', 0):.3f}s "
                      f"coll={rl.get('collective_s', 0):.3f}s "
                      f"ratio={rec.get('model_flops_ratio', 0):.3f}",
                      flush=True)


if __name__ == "__main__":
    main()
