"""Render EXPERIMENTS.md result sections from the measured JSONL records."""
from __future__ import annotations

import json
import os
import sys

from repro.launch.roofline import load_records, render_table


def perf_log_table(path: str) -> str:
    if not os.path.exists(path):
        return "_hillclimb records missing_"
    recs = [json.loads(l) for l in open(path) if l.strip()]
    # keep the last record per iteration name (reruns supersede)
    seen = {}
    for r in recs:
        seen[r["iter"]] = r
    out = ["| iter | hypothesis | compute_s | memory_s | collective_s | "
           "MODEL_FLOPs/HLO | verdict |",
           "|---|---|---|---|---|---|---|"]
    prev = {}
    for name in sorted(seen):
        r = seen[name]
        if r.get("status") != "ok":
            out.append(f"| {name} | {r['hypothesis'][:80]}… | ERROR | | | | "
                       f"{r.get('error', '')[:40]} |")
            continue
        rl = r["roofline"]
        series = name.split(".")[0]
        verdict = ""
        if series in prev:
            p = prev[series]
            deltas = []
            for k, lbl in (("compute_s", "C"), ("memory_s", "M"),
                           ("collective_s", "X")):
                if p[k] > 0:
                    d = 100.0 * (rl[k] - p[k]) / p[k]
                    if abs(d) >= 1:
                        deltas.append(f"{lbl}{d:+.0f}%")
            verdict = " ".join(deltas) or "~no change"
        prev[series] = rl
        hyp = r["hypothesis"].replace("|", "/")
        out.append(f"| {name} | {hyp} | {rl['compute_s']:.3f} | "
                   f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
                   f"{r.get('model_flops_ratio', 0):.3f} | {verdict} |")
    return "\n".join(out)


def perf_summary(path: str) -> str:
    if not os.path.exists(path):
        return "_hillclimb records missing_"
    recs = [json.loads(l) for l in open(path) if l.strip()]
    seen = {}
    for r in recs:
        seen[r["iter"]] = r
    pairs = [
        ("H1 granite-3-2b train_4k", "h1.0-baseline", "h1.5-bf16-comms"),
        ("H2 deepseek-v3 prefill_32k", "h2.0-baseline",
         "h2.2-capacity-1.0"),
        ("H3 RgCSR-FFN vs dense", "h3.0-dense-ffn-ref",
         "h3.1-rgcsr-ffn-d25"),
    ]
    out = ["| cell | variant | compute_s | memory_s | collective_s | "
           "step lower-bound (max term) | roofline fraction (compute/max) |",
           "|---|---|---|---|---|---|---|"]
    for label, base, best in pairs:
        for tag, key in (("paper-faithful baseline", base),
                         ("beyond-paper optimized", best)):
            r = seen.get(key)
            if not r or r.get("status") != "ok":
                out.append(f"| {label} | {tag} ({key}) | missing | | | | |")
                continue
            rl = r["roofline"]
            mx = max(rl.values())
            frac = rl["compute_s"] / mx if mx else 0.0
            out.append(f"| {label} | {tag} | {rl['compute_s']:.3f} | "
                       f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
                       f"{mx:.3f} | {frac:.2f} |")
    return "\n".join(out)


def main(argv=None):
    import glob
    recs = []
    for p in sorted(glob.glob("results/dryrun*.jsonl")) \
            + ["results/ds_train.jsonl"]:
        if os.path.exists(p):
            try:
                recs += load_records(p)
            except Exception:
                pass
    # dedupe (arch, shape, mesh): last wins
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    recs = list(seen.values())

    n_ok = sum(r.get("status") == "ok" for r in recs)
    header = (f"Cells compiled OK: {n_ok}/{len(recs)} "
              f"(each cell = lower+compile on the production mesh).\n\n")
    table = (header
             + "### single-pod (16×16 = 256 chips) — the §Roofline table\n\n"
             + render_table(recs, multi_pod=False)
             + "\n\n### multi-pod (2×16×16 = 512 chips)\n\n"
             + render_table(recs, multi_pod=True))

    md = open("EXPERIMENTS.md").read()
    md = md.replace("<!-- ROOFLINE_TABLE -->", table)
    md = md.replace("<!-- PERF_LOG -->",
                    perf_log_table("results/hillclimb.jsonl"))
    md = md.replace("<!-- PERF_SUMMARY -->",
                    perf_summary("results/hillclimb.jsonl"))
    open("EXPERIMENTS.md", "w").write(md)
    print(f"EXPERIMENTS.md updated: {n_ok}/{len(recs)} cells")


if __name__ == "__main__":
    main(sys.argv[1:])
