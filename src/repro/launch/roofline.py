"""Roofline table generator: dryrun.jsonl → EXPERIMENTS.md §Roofline rows.

Terms (per device, per step — seconds):
    compute    = HLO_dot_FLOPs / 197e12
    memory     = HLO_traffic_bytes / 819e9
    collective = ring-model collective bytes / 50e9

plus MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference) with N = active
params, the ratio MODEL_FLOPS/HLO_FLOPs, the dominant term, and a
one-line "what would move it" note derived from the dominant term and the
collective mix.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

__all__ = ["load_records", "roofline_row", "render_table", "main"]


def load_records(path: str) -> List[Dict]:
    recs = []
    with open(path) as f:
        first = f.read(1)
        f.seek(0)
        if first == "[":
            recs = json.load(f)
        else:
            for line in f:
                line = line.strip()
                if line:
                    recs.append(json.loads(line))
    # deduplicate on (arch, shape, multi_pod), last wins (reruns)
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    return list(seen.values())


_ADVICE = {
    "compute": "compute-bound: raise per-chip utilization (larger per-device "
               "batch, fuse small dots) or add chips",
    "memory": "memory-bound: cut HBM traffic (fused attention kernel, fewer "
              "microbatch weight re-reads, bf16 buffers)",
    "collective": "collective-bound: reduce FSDP re-gathers / switch "
                  "sharding so weights stay resident; overlap with compute",
}


def roofline_row(rec: Dict) -> Dict:
    r = dict(rec)
    rl = rec.get("roofline", {})
    total = max(rl.values()) if rl else 0.0
    r["dominant"] = rec.get("bottleneck", "?")
    r["advice"] = _ADVICE.get(r["dominant"], "")
    r["step_lower_bound_s"] = total
    return r


def render_table(recs: List[Dict], multi_pod: bool = False) -> str:
    rows = [roofline_row(r) for r in recs
            if r.get("status") == "ok" and r.get("multi_pod") == multi_pod]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | compute_s | memory_s | collective_s | "
           "bottleneck | MODEL_FLOPs/HLO | HBM GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl = r.get("roofline", {})
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0)) / 2 ** 30
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{rl.get('compute_s', 0):.4f} | {rl.get('memory_s', 0):.4f} | "
            f"{rl.get('collective_s', 0):.4f} | {r['dominant']} | "
            f"{r.get('model_flops_ratio', 0):.3f} | {hbm:.2f} |")
    failed = [r for r in recs
              if r.get("status") != "ok" and r.get("multi_pod") == multi_pod]
    for r in failed:
        out.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                   f"{r.get('error', '?')[:60]} | | | | | |")
    return "\n".join(out)


def main(argv=None):
    path = argv[0] if argv else "results/dryrun.jsonl"
    recs = load_records(path)
    print("## single-pod (16×16 = 256 chips)\n")
    print(render_table(recs, multi_pod=False))
    print("\n## multi-pod (2×16×16 = 512 chips)\n")
    print(render_table(recs, multi_pod=True))


if __name__ == "__main__":
    main(sys.argv[1:])
