import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  Do not move; do not set this flag anywhere global.  (This also
#   means no `from __future__ import annotations` in this file.)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds abstract params / optimizer state / caches (ShapeDtypeStruct —
     zero allocation; the 671B cells never materialize),
  2. resolves shardings via the logical-axis partitioner,
  3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``
     against the production mesh — (16,16)=256 chips single-pod and
     (2,16,16)=512 chips multi-pod,
  4. records ``memory_analysis()`` (proves the cell fits HBM),
     ``cost_analysis()`` (FLOPs/bytes) and the HLO collective traffic
     (launch/hlo_stats.py) into a JSON consumed by §Roofline/§Perf.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs
from repro.configs.base import ModelConfig
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.steps import auto_microbatches, make_decode_step, \
    make_prefill_step, make_train_step
from repro.models import LanguageModel
from repro.sharding import Partitioner
from repro.train.optimizer import OptimizerConfig

__all__ = ["run_cell", "main", "cell_overrides"]


def cell_overrides(arch: str, shape_kind: str) -> Dict[str, Any]:
    """Per-cell production config choices (documented in EXPERIMENTS.md):

    * deepseek-v3: Adafactor (factored stats) + bf16 params — the only
      optimizer-state layout that fits 671B on 256/512 v5e chips; every
      other arch trains AdamW/fp32-master.
    * serving cells run bf16 params (inference precision).
    """
    ov: Dict[str, Any] = {"optimizer": "adamw", "param_dtype": "float32"}
    if arch == "deepseek-v3-671b":
        ov["optimizer"] = "adafactor"
        ov["param_dtype"] = "bfloat16"
    if shape_kind != "train":
        ov["param_dtype"] = "bfloat16"
    return ov


def resolve_attn_shard_mode(cfg, model_axis: int) -> str:
    """Pick the attention TP strategy (models/shardlib.py) by divisibility."""
    if cfg.attn_kind == "mla":
        return "heads" if cfg.n_heads % model_axis == 0 else "seq"
    if cfg.n_kv_heads % model_axis == 0:
        return "heads"
    if cfg.n_heads % model_axis == 0:
        return "repeat"
    return "seq"


def _build_model(arch: str, shape_kind: str, mesh,
                 cfg_overrides: Optional[Dict[str, Any]] = None,
                 micro_hint: int = 1, global_batch: int = 1):
    cfg = get_config(arch)
    ov = cell_overrides(arch, shape_kind)
    model_axis = mesh.shape["model"]
    batch_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    batch_shards = 1
    for a in batch_axes:
        batch_shards *= mesh.shape[a]
    micro_global = max(1, global_batch // micro_hint)
    updates: Dict[str, Any] = {
        "param_dtype": ov["param_dtype"],
        "act_shard": True,
        "attn_shard_mode": resolve_attn_shard_mode(cfg, model_axis),
        "mesh_batch_axes": batch_axes,
        "shard_batch": micro_global % batch_shards == 0,
    }
    if shape_kind == "train":
        updates["remat"] = "full"
    if cfg_overrides:
        for k, v in cfg_overrides.items():
            if k == "moe" and cfg.moe.n_experts:
                updates["moe"] = dataclasses.replace(cfg.moe, **v)
            elif k == "sparsity":
                updates["sparsity"] = dataclasses.replace(cfg.sparsity, **v)
            else:
                updates[k] = v
    cfg = dataclasses.replace(cfg, **updates)
    return LanguageModel(cfg), ov


def _mem_analysis_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        val = getattr(ma, name, None)
        if val is not None:
            out[name] = float(val)
    return out


def _cost_analysis_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    keep = {}
    for k, v in ca.items():
        if k in ("flops", "bytes accessed", "optimal_seconds", "utilization"):
            keep[k] = float(v)
        elif k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             cfg_overrides: Optional[Dict[str, Any]] = None,
             microbatch_override: Optional[int] = None,
             rules_override: Optional[Dict[str, Any]] = None,
             keep_hlo: bool = False) -> Dict[str, Any]:
    """Lower + compile one cell; returns the §Dry-run/§Roofline record.

    ``rules_override``: logical-axis → mesh-axis entries merged over the
    default ShardingRules — the knob the §Perf hillclimb turns.
    """
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.devices.size
    base_cfg = get_config(arch)
    n_data0 = n_devices // mesh.shape["model"]
    micro_hint = 1
    if shape.kind == "train":
        micro_hint = microbatch_override or auto_microbatches(
            base_cfg, shape.global_batch, shape.seq_len, n_data0)
    model, ov = _build_model(arch, shape.kind, mesh, cfg_overrides,
                             micro_hint=micro_hint,
                             global_batch=shape.global_batch)
    cfg = model.cfg
    if rules_override:
        from repro.sharding.partitioner import SERVE_RULES, TRAIN_RULES, \
            ShardingRules
        base_rules = TRAIN_RULES if shape.kind == "train" else SERVE_RULES
        rules = ShardingRules(params={**base_rules.params, **rules_override},
                              batch=base_rules.batch)
        part = Partitioner(mesh, shape.kind, rules)
    else:
        part = Partitioner(mesh, shape.kind)
    record_attn_mode = cfg.attn_shard_mode

    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": list(mesh.devices.shape),
        "multi_pod": multi_pod, "n_devices": int(n_devices),
        "param_dtype": cfg.param_dtype, "optimizer": ov["optimizer"],
        "attn_shard_mode": record_attn_mode,
        "n_params": model.n_params(), "n_active_params": model.n_active_params(),
    }
    t0 = time.time()

    spec_tree = model.spec()
    params_abs = model.abstract_params()
    p_sh = part.param_shardings(spec_tree)
    batch_abs = input_specs(cfg, shape)
    b_sh = part.batch_shardings(batch_abs)

    with mesh:
        if shape.kind == "train":
            micro = micro_hint
            record["microbatches"] = micro
            opt_cfg = OptimizerConfig(name=ov["optimizer"])
            train_step, opt_init = make_train_step(model, opt_cfg, micro)
            opt_abs = jax.eval_shape(opt_init, params_abs)
            o_sh = part.opt_shardings(spec_tree, ov["optimizer"])
            fn = jax.jit(train_step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            prefill = make_prefill_step(model, shape.seq_len, shape.kind)
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         shape_kind=shape.kind,
                                         enc_len=shape.seq_len
                                         if cfg.enc_dec else 0))
            c_sh = part.cache_shardings(cache_abs)
            fn = jax.jit(prefill,
                         in_shardings=(p_sh, b_sh),
                         out_shardings=(part.logits_sharding(
                             shape.global_batch), c_sh))
            lowered = fn.lower(params_abs, batch_abs)
        else:  # decode / long_decode
            decode = make_decode_step(model, shape.kind)
            enc_len = 4096 if cfg.enc_dec else 0
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         shape_kind=shape.kind,
                                         enc_len=enc_len))
            c_sh = part.cache_shardings(cache_abs)
            fn = jax.jit(decode,
                         in_shardings=(p_sh, c_sh, b_sh["tokens"]),
                         out_shardings=(part.logits_sharding(
                             shape.global_batch), c_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params_abs, cache_abs, batch_abs["tokens"])

        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

    record["memory"] = _mem_analysis_dict(compiled)
    # XLA's naive analysis (single-visit loop bodies) kept for reference;
    # the authoritative numbers come from the loop-aware parser below.
    record["cost_xla_naive"] = _cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    record["hlo_bytes"] = len(hlo)
    cost = analyze_hlo(hlo, n_devices)
    record["cost"] = {
        "flops": cost.flops,                    # per-device, loop-corrected
        "bytes": cost.bytes,
        "collective_bytes": cost.collective_bytes,
        "collective_bytes_tpu": cost.collective_bytes_tpu,
        "collective_bytes_f32_dot": cost.collective_bytes_f32_dot,
        "collective_counts": cost.collective_counts,
        "collective_bytes_by_kind": cost.collective_bytes_by_kind,
        "n_loops": len(cost.loops),
    }
    if keep_hlo:
        record["hlo_text"] = hlo

    # roofline terms (§Roofline): per-device seconds per term.
    # collective uses the TPU-corrected bytes (bf16 dot outputs are
    # all-reduced at f32 only on the CPU backend — hlo_cost.HloCost).
    record["roofline"] = {
        "compute_s": cost.flops / HW.PEAK_FLOPS,
        "memory_s": cost.bytes / HW.HBM_BW,
        "collective_s": cost.collective_bytes_tpu / HW.ICI_BW,
    }
    dom = max(record["roofline"], key=record["roofline"].get)
    record["bottleneck"] = dom.replace("_s", "")

    # MODEL_FLOPS ratio: useful work / compiled work (per device)
    tokens = shape.global_batch * (shape.seq_len if shape.kind in
                                   ("train", "prefill") else 1)
    mf = 6.0 * model.n_active_params() * tokens
    if shape.kind == "train":
        pass                                    # 6ND already counts fwd+bwd
    else:
        mf = 2.0 * model.n_active_params() * tokens   # inference: fwd only
    record["model_flops_global"] = mf
    per_dev = mf / n_devices
    record["model_flops_ratio"] = per_dev / cost.flops if cost.flops else 0.0
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--skip", default="",
                    help="comma-separated arch:shape cells to skip")
    ap.add_argument("--only", default="",
                    help="comma-separated arch:shape cells to run")
    args = ap.parse_args(argv)
    skip = {tuple(c.split(":")) for c in args.skip.split(",") if c}
    only = {tuple(c.split(":")) for c in args.only.split(",") if c}

    archs = sorted(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    jsonl = open(args.out + "l", "a") if args.out else None
    for arch in archs:
        for shape in shapes:
            if (arch, shape) in skip or (only and (arch, shape) not in only):
                continue
            for mp in meshes:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   microbatch_override=args.micro)
                    rec["status"] = "ok"
                    print(f"[dryrun] OK   {tag}: compile={rec['compile_s']}s "
                          f"flops={rec['cost'].get('flops', 0):.3e} "
                          f"coll={rec['cost']['collective_bytes']:.3e}B "
                          f"bottleneck={rec['bottleneck']}", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    print(f"[dryrun] FAIL {tag}: {e!r}", flush=True)
                results.append(rec)
                if jsonl:
                    jsonl.write(json.dumps(rec) + "\n")
                    jsonl.flush()
    if jsonl:
        jsonl.close()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {len(results)} records to {args.out}")
    n_err = sum(r["status"] != "ok" for r in results)
    print(f"[dryrun] {len(results) - n_err}/{len(results)} cells OK")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
