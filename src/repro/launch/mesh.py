"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never initializes jax devices — required because the dry-run
must set ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before*
any jax device query (see launch/dryrun.py lines 1–2).

Meshes:
* single pod : (16, 16)            axes ("data", "model")   = 256 chips
* multi-pod  : (2, 16, 16)         axes ("pod", "data", "model") = 512 chips

The ``model`` axis maps onto the ICI torus dimension with the densest links
(TP traffic is per-layer); ``pod`` is the outermost axis — cross-pod (DCN)
traffic is only the gradient all-reduce / no serving traffic at all.
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_mesh", "HW"]


# TPU v5e target constants (system-prompt values; used by roofline + tests)
class HW:
    PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
    HBM_BW = 819e9               # bytes/s per chip
    ICI_BW = 50e9                # bytes/s per link (~per axis direction)
    HBM_BYTES = 16 * 2 ** 30     # v5e HBM capacity
    VMEM_BYTES = 128 * 2 ** 20


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Build a mesh over the first prod(shape) available devices."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} exist; "
            f"the dry-run entry point must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"any jax import-time device initialization")
    return jax.make_mesh(shape, axes, devices=devices[:n])
