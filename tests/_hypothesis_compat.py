"""Graceful degradation when ``hypothesis`` is absent.

Tier-1 must collect and run in bare containers (the seed failed at
collection with ``ModuleNotFoundError: hypothesis``).  Preferred path: the
real hypothesis (installed via ``pip install -e .[test]``, see
pyproject.toml).  Fallback: a deterministic mini-sampler implementing the
exact ``@given``/strategy subset these tests use — each property test runs
``max_examples`` (capped) fixed pseudo-random examples instead of being
skipped outright, which keeps real coverage where plain
``pytest.importorskip("hypothesis")`` would drop whole modules.

Import in tests as::

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import inspect
    import types

    import numpy as np

    _FALLBACK_MAX_EXAMPLES = 10
    _SEED = 0xC0FFEE

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    st = types.SimpleNamespace(integers=_integers, floats=_floats,
                               sampled_from=_sampled_from)

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = min(max_examples, _FALLBACK_MAX_EXAMPLES)
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _FALLBACK_MAX_EXAMPLES)
                rng = np.random.default_rng(_SEED)
                for _ in range(n):
                    fn(**{name: s.draw(rng)
                          for name, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # zero-arg signature so pytest doesn't mistake the drawn
            # parameters for fixtures
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
