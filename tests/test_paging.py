"""Paged KV-cache subsystem (DESIGN.md §6, serve/paging.py).

Covers the allocator invariants (both admission policies), paged-vs-dense
logits equivalence across every cache variant (gqa / mla / windowed /
int8) and page-boundary prompt lengths, pool-exhaustion admission deferral
(worst_case policy) and recompute preemption (prompt policy, §6.4),
per-request rejection with the strict escape hatch, deadlines, and the
stale-offset drift regression (a request slotted into a half-decoded
batch).

Determinism note (the PR 3 lesson): nothing here asserts on wall-clock —
token streams, logits, and page counts are all deterministic functions of
seeds and request mixes, and the deadline/fairness tests drive
``Engine.clock`` with a fake timer, so these tests cannot flake under
parallel tier-1 load.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import LanguageModel
from repro.serve import Engine, PageAllocator, Request, ServeConfig, paging

S_MAX = 64
PS = 4           # page size: small so short tests cross page boundaries


class FakeClock:
    """Deterministic engine clock: time advances only when told to (the
    tests attach the advance to decode steps), so deadline and ordering
    asserts cannot flake under load."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _tick_decode(eng, clock, dt=1.0, slow_at=()):
    """Wrap the engine's decode dispatches so each decode STEP advances
    the fake clock by ``dt`` (``slow_at``: step indices that take 10× —
    straggler fodder).  Serving goes through the fused chunk runner
    (``_fused_decode``, one dispatch = up to decode_chunk steps — the
    clock advances by the steps that actually ran); ``generate()`` and
    the stepwise oracle go through ``_decode`` (one step per call)."""
    orig = eng._decode
    orig_fused = eng._fused_decode
    count = [0]

    def cost():
        c = dt * (10.0 if count[0] in slow_at else 1.0)
        count[0] += 1
        return c

    def wrapped(*a):
        clock.advance(cost())
        return orig(*a)

    def wrapped_fused(*a):
        out = orig_fused(*a)
        clock.advance(sum(cost() for _ in range(int(out[1]))))
        return out

    eng._decode = wrapped
    eng._fused_decode = wrapped_fused


# ------------------------------------------------------------- allocator


def test_allocator_basic_lifecycle():
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=2, n_pages=0)
    assert geom.pages_per_slot == 8
    assert geom.n_pages == 17 and geom.usable_pages == 16   # + null page
    alloc = PageAllocator(geom, n_slots=2)
    assert alloc.admit(0, n_tokens=6, worst_pages=4)
    assert alloc.pages_in_use == 2                          # ceil(6/4)
    assert (alloc.table[0, :2] > 0).all()                   # never page 0
    assert alloc.ensure(0, 9)                               # 3rd page
    assert not alloc.ensure(0, 9)                           # idempotent
    assert alloc.pages_in_use == 3 and alloc.high_water == 3
    alloc.release(0)
    assert alloc.pages_in_use == 0 and (alloc.table == 0).all()
    assert alloc.high_water == 3                            # sticky


def test_allocator_admission_control_and_reuse():
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=3, n_pages=5)
    alloc = PageAllocator(geom, n_slots=3)                  # 4 usable pages
    assert alloc.admit(0, 8, worst_pages=2)
    assert alloc.admit(1, 8, worst_pages=2)
    assert not alloc.can_admit(2)                           # reservations full
    assert not alloc.admit(2, 8, worst_pages=2)
    alloc.release(0)
    assert alloc.admit(2, 8, worst_pages=2)                 # freed pages reused
    used = {p for pages in alloc.slot_pages for p in pages}
    assert 0 not in used and len(used) == alloc.pages_in_use


def test_allocator_reservation_invariant():
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=1, n_pages=0)
    alloc = PageAllocator(geom, n_slots=1)
    alloc.admit(0, 4, worst_pages=2)
    with pytest.raises(AssertionError, match="reservation"):
        alloc.ensure(0, 12)                                 # needs 3 > 2


def test_allocator_release_idempotent():
    """Double release must be a no-op — re-extending the free list would
    hand the same page to two slots (satellite hardening)."""
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=2, n_pages=5)
    alloc = PageAllocator(geom, n_slots=2)
    alloc.admit(0, 8, worst_pages=2)
    assert alloc.release(0) == 2
    n_free = len(alloc.free)
    assert alloc.release(0) == 0                            # idempotent
    assert len(alloc.free) == n_free                        # not re-extended
    # every page still singly owned after churn
    alloc.admit(0, 8, worst_pages=2)
    alloc.admit(1, 8, worst_pages=2)
    used = [p for pages in alloc.slot_pages for p in pages]
    assert len(used) == len(set(used)) == 4


def test_allocator_invariant_asserted_on_every_mutation():
    """sum(reserved) <= usable and free+in_use == usable are checked on
    admit/ensure/release — a corrupted free list trips immediately."""
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=2, n_pages=9)
    alloc = PageAllocator(geom, n_slots=2)
    alloc.admit(0, 8, worst_pages=4)
    alloc.free.append(alloc.slot_pages[0][0])     # simulate double ownership
    with pytest.raises(AssertionError, match="accounting"):
        alloc.admit(1, 4, worst_pages=2)


def test_allocator_prompt_policy_exhaustion_and_eviction():
    """policy='prompt': admission reserves resident pages only; ensure()
    raises PoolExhausted on a dry pool, and an eviction frees exactly the
    victim's pages (counted), after which the same ensure() succeeds."""
    geom = paging.geometry(max_seq=64, page_size=4, n_slots=2, n_pages=5)
    alloc = PageAllocator(geom, n_slots=2, policy="prompt")   # 4 usable
    assert alloc.admission_pages(8, worst_pages=4) == 2       # prompt only
    assert alloc.admit(0, 8, worst_pages=4)
    assert alloc.admit(1, 8, worst_pages=4)                   # pool now full
    assert alloc.pages_in_use == 4 and sum(alloc.reserved) == 4
    with pytest.raises(paging.PoolExhausted):
        alloc.ensure(0, 9)                                    # needs a 3rd
    victim_pages = set(alloc.slot_pages[1])
    assert alloc.release(1, evicted=True) == 2
    assert alloc.evictions == 1 and alloc.pages_evicted == 2
    assert victim_pages <= set(alloc.free)        # exactly those freed
    assert alloc.ensure(0, 9)                     # retry succeeds
    assert alloc.pages_in_use == 3 and alloc.reserved[0] == 3


def test_allocator_prompt_policy_worst_case_cap():
    """Even under prompt-pages admission a slot can never outgrow its own
    worst case (the engine's max_seq rejection guarantees the cap)."""
    geom = paging.geometry(max_seq=64, page_size=4, n_slots=1, n_pages=0)
    alloc = PageAllocator(geom, n_slots=1, policy="prompt")
    alloc.admit(0, 4, worst_pages=2)
    with pytest.raises(AssertionError, match="worst-case cap"):
        alloc.ensure(0, 12)                                   # needs 3 > 2


def test_allocator_rejects_unknown_policy():
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=1, n_pages=0)
    with pytest.raises(ValueError, match="admission policy"):
        PageAllocator(geom, n_slots=1, policy="optimism")


# -------------------------------------------- paged vs dense equivalence


def _decode_equiv(cfg, prompt_len, n_steps=4, slot=1, atol=1e-3):
    """Prefill once, then decode the same token stream through (a) the
    dense batch-1 cache and (b) a paged 2-slot cache committed at `slot`,
    asserting step-by-step logits equality."""
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(prompt_len)
    prompt = rng.integers(0, cfg.vocab, (1, prompt_len)).astype(np.int32)

    logits, cache_d = model.prefill(params, {"tokens": jnp.asarray(prompt)},
                                    S_MAX)
    geom = paging.geometry(S_MAX, PS, n_slots=2)
    alloc = PageAllocator(geom, n_slots=2)
    caches_p = model.init_cache(2, S_MAX, paging=geom)
    worst = min(alloc.pages_for(prompt_len + n_steps), geom.pages_per_slot)
    assert alloc.admit(slot, prompt_len, worst)
    caches_p = paging.commit_prefill(caches_p, cache_d, slot, prompt_len,
                                     alloc.table, PS)

    tok = int(jnp.argmax(logits[0, -1]))
    pos = prompt_len
    for _ in range(n_steps):
        if alloc.ensure(slot, pos + 1):
            caches_p = paging.sync_block_tables(caches_p, alloc.table)
        tok_d = jnp.full((1, 1), tok, jnp.int32)
        tok_p = jnp.zeros((2, 1), jnp.int32).at[slot, 0].set(tok)
        ld, cache_d = model.decode_step(params, cache_d, tok_d)
        lp, caches_p = model.decode_step(params, caches_p, tok_p)
        np.testing.assert_allclose(
            np.asarray(ld[0], np.float32), np.asarray(lp[slot], np.float32),
            atol=atol, rtol=1e-3)
        tok = int(jnp.argmax(ld[0, -1]))
        pos += 1


# page-boundary lengths: len % PS ∈ {0, 1, PS-1} (plus an interior value)
_BOUNDARY_LENS = [PS * 3, PS * 3 + 1, PS * 3 - 1, 10]


@pytest.mark.parametrize("prompt_len", _BOUNDARY_LENS)
def test_paged_matches_dense_gqa(prompt_len):
    _decode_equiv(get_smoke("granite-3-2b"), prompt_len)


@pytest.mark.parametrize("prompt_len", [PS * 3, PS * 3 + 1, PS * 3 - 1])
def test_paged_matches_dense_mla(prompt_len):
    _decode_equiv(get_smoke("minicpm3-4b"), prompt_len)


def test_paged_matches_dense_int8():
    cfg = dataclasses.replace(get_smoke("granite-3-2b"),
                              kv_cache_dtype="int8")
    _decode_equiv(cfg, PS * 2 + 1)


def test_paged_matches_dense_windowed():
    """Windowed layers keep dense rings under paging (bounded residency);
    the per-slot index must still line their masks up with the paged
    full-attention layers in the same stack."""
    _decode_equiv(get_smoke("recurrentgemma-9b"), PS * 2, n_steps=5)


def test_null_page_isolation():
    """Slot 0 stays inactive (block table row 0, index 0) while slot 1
    decodes — its writes land in the null page and must never perturb the
    active slot (checked implicitly by _decode_equiv using slot=1), and
    page 0 is never handed out."""
    cfg = get_smoke("granite-3-2b")
    geom = paging.geometry(S_MAX, PS, n_slots=2)
    alloc = PageAllocator(geom, n_slots=2)
    alloc.admit(1, 12, 6)
    assert 0 not in {p for pages in alloc.slot_pages for p in pages}
    _decode_equiv(cfg, 12, slot=1)


# --------------------------------------------------------- serve-level


def _oracle(eng, req):
    return list(eng.generate(req.tokens[None, :],
                             max_new_tokens=req.max_new_tokens)[0])


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_serve_mixed_lengths_match_oracle(layout):
    """The tentpole: mixed-length prompts in ONE live batch (the PR 3
    guard is gone), token-for-token equal to generate()."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2,
                                  kv_layout=layout, page_size=PS))
    rng = np.random.default_rng(5)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ln,)).astype(np.int32),
                    max_new_tokens=5) for ln in (10, 13, 7)]
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r)
    assert eng.paging_stats["kv_layout"] == layout


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_midstream_slotting_no_stale_offset_drift(layout):
    """Regression for the stale-offset drift the mixed-length guard used
    to mask: a SAME-length request slotted into a half-decoded batch must
    start from its own position, not the batch's advanced write head."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2,
                                  kv_layout=layout, page_size=PS))
    rng = np.random.default_rng(6)
    mk = lambda mx: Request(tokens=rng.integers(
        0, cfg.vocab, (9,)).astype(np.int32), max_new_tokens=mx)
    # req0 decodes long; req1 finishes fast and frees its slot; req2 is
    # then admitted while req0 is half-decoded (same prompt length)
    reqs = [mk(10), mk(3), mk(6)]
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r), "mid-stream slotted request drifted"


def test_serve_pool_exhaustion_defers_admission():
    """worst_case policy (PR 5 behavior, kept behind the knob): 3 slots
    but pages for only 2 concurrent requests — the third must wait for a
    completion (deferral counted, never a preemption), then finish."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=3, page_size=8,
                                  n_pages=5,                  # 4 usable
                                  admission_policy="worst_case"))
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=5) for _ in range(3)]
    eng.serve(reqs)
    assert all(r.done and len(r.out) == 5 for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r)
    st = eng.paging_stats
    assert st["admission_deferrals"] > 0
    assert st["preemptions"] == 0 and st["evictions"] == 0
    assert st["page_high_water"] <= 4                       # pool bound held
    assert st["pages_in_use"] == 0                          # all freed


# ------------------------------------------------ preemption & overload


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_serve_overload_preempts_and_matches_oracle(layout):
    """The tentpole acceptance scenario: the PR 5 deferral geometry (pool
    sized below aggregate worst case) under the default prompt-pages
    policy completes EVERY request via recompute preemption, token-for-
    token equal to generate() — in both layouts (dense has no pool, so it
    must simply complete)."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=3, page_size=8,
                                  n_pages=5, kv_layout=layout))
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=5) for _ in range(6)]
    eng.serve(reqs)
    assert all(r.ok_like and len(r.out) == 5 for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r), "preempted request drifted"
    st = eng.paging_stats
    assert st["completed"] == 6
    if layout == "paged":
        assert st["preemptions"] > 0 and st["recompute_tokens"] > 0
        assert st["evictions"] == st["preemptions"]
        assert st["page_high_water"] <= 4                   # pool bound held
        assert st["pages_in_use"] == 0 and st["reserved_pages"] == 0
        assert any(r.preemptions > 0
                   and r.status == f"preempted_{r.preemptions}"
                   for r in reqs)
    else:
        assert st["preemptions"] == 0


def test_serve_preemption_fifo_fairness_under_sustained_overload():
    """Sustained overload (8 equal requests through a pool for ~2): FIFO
    order is preserved — completion times (fake clock, advanced per decode
    step) are non-decreasing in submission order, and the earliest-admitted
    request is never the preemption victim."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=3, page_size=8,
                                  n_pages=5))
    clock = FakeClock()
    eng.clock = clock
    _tick_decode(eng, clock)
    rng = np.random.default_rng(12)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=5) for _ in range(8)]
    eng.serve(reqs)
    assert all(r.ok_like and len(r.out) == 5 for r in reqs)
    assert eng.paging_stats["preemptions"] > 0
    done_at = [r.queue_s + r.latency_s for r in reqs]   # instants from t0
    assert done_at == sorted(done_at), "overload broke FIFO completion order"
    for r in reqs:
        assert r.out == _oracle(eng, r)


def test_serve_preemption_frees_exactly_victim_pages():
    """Each eviction returns exactly the victim's pages to the pool: the
    allocator's eviction accounting ties out against the engine's
    preemption count and the pool never exceeds its bound."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=3, page_size=8,
                                  n_pages=5))
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=5) for _ in range(3)]
    eng.serve(reqs)
    st = eng.paging_stats
    assert st["preemptions"] == st["evictions"] > 0
    # every victim held exactly its resident tokens' pages when evicted:
    # pages_evicted * page_size must cover recompute_tokens at page granularity
    assert st["pages_evicted"] * st["page_size"] >= st["recompute_tokens"]
    assert st["pages_evicted"] < st["recompute_tokens"]  # pages, not tokens
    assert st["pages_in_use"] == 0 and st["reserved_pages"] == 0


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_serve_deadline_expiry_releases_slot_and_pages(layout):
    """A mid-decode deadline violation times out ONLY that request (partial
    output kept, slot + pages freed for the queue) while batchmates
    complete; a queued request whose deadline lapses before slotting never
    runs prefill."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                                  kv_layout=layout))
    clock = FakeClock()
    eng.clock = clock
    _tick_decode(eng, clock)                       # 1s per decode step
    rng = np.random.default_rng(13)
    mk = lambda mx, dl: Request(
        tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
        max_new_tokens=mx, deadline_s=dl)
    slow = mk(12, 2.5)          # times out after the 3rd decode step
    ok = mk(4, None)            # no deadline: completes
    queued = mk(4, 2.5)         # 2 slots busy at t>2.5 -> dies in queue
    late = mk(3, None)          # slots in after the timeouts free a slot
    eng.serve([slow, ok, queued, late])
    assert slow.done and slow.status == "timed_out"
    assert 1 <= len(slow.out) < 12                 # partial output kept
    assert "deadline" in slow.error
    assert ok.ok_like and len(ok.out) == 4
    assert ok.out == _oracle(eng, ok)
    assert queued.done and queued.status == "timed_out" and queued.out == []
    assert late.ok_like and len(late.out) == 3
    st = eng.paging_stats
    assert st["timed_out"] == 2 and st["completed"] == 2
    if layout == "paged":
        assert st["pages_in_use"] == 0 and st["reserved_pages"] == 0


def test_serve_straggler_decode_steps_flagged():
    """The train/fault.py Watchdog rides along: a decode step 10x slower
    than the EWMA (fake clock) lands in paging_stats.  decode_chunk=1
    keeps per-step watchdog granularity — chunked dispatches observe a
    per-step-normalized dt (see test_device_loop.py for that case)."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                                  decode_chunk=1))
    clock = FakeClock()
    eng.clock = clock
    _tick_decode(eng, clock, slow_at=(8,))
    rng = np.random.default_rng(14)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    max_new_tokens=12) for _ in range(2)]
    eng.serve(reqs)
    assert all(r.ok_like for r in reqs)
    assert eng.paging_stats["straggler_decode_steps"] == 1


# ------------------------------------- rejection (strict escape hatch)


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_serve_budget_overflowing_max_seq_rejected(layout):
    """prompt + max_new - 1 beyond max_seq fails THAT request
    (status='rejected') while batchmates finish (paged: the reservation
    would outgrow the block table and crash mid-decode; dense: writes
    would silently drop).  The exact-fit budget is fine and fills the
    last page completely."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=16, n_slots=1, kv_layout=layout,
                                  page_size=PS))
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)
    bad = Request(tokens=prompt.copy(), max_new_tokens=9)             # 17
    ok = Request(tokens=prompt.copy(), max_new_tokens=8)              # 16
    eng.serve([bad, ok])
    assert bad.done and bad.status == "rejected" and bad.out == []
    assert "max_seq" in bad.error
    assert ok.ok_like and len(ok.out) == 8
    assert ok.out == _oracle(eng, ok)


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_serve_strict_restores_max_seq_raise(layout):
    """strict=True escape hatch: the PR 5 fail-stop ValueError is back."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=16, n_slots=1, kv_layout=layout,
                                  page_size=PS, strict=True))
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        eng.serve([Request(tokens=prompt, max_new_tokens=9)])         # 17


def test_serve_request_too_big_for_pool_rejected_and_strict():
    cfg = get_smoke("granite-3-2b")
    rng = np.random.default_rng(15)
    mk_big = lambda: Request(tokens=np.arange(16, dtype=np.int32)
                             % cfg.vocab, max_new_tokens=20)  # worst 5 pages
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=8,
                                  n_pages=3))                 # 2 usable
    big = mk_big()
    ok = Request(tokens=rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                 max_new_tokens=2)
    eng.serve([big, ok])
    assert big.done and big.status == "rejected" and "pool" in big.error
    assert ok.ok_like and ok.out == _oracle(eng, ok)
    assert eng.paging_stats["rejected"] == 1
    strict = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=8,
                                     n_pages=3, strict=True),
                    params=eng.params)
    with pytest.raises(ValueError, match="pool"):
        strict.serve([mk_big()])


def test_paged_residency_bounded_by_dense():
    """Acceptance bound: paged peak KV residency <= dense (n_slots, S_max)
    and strictly lower on a mixed-length mix."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=4, page_size=PS))
    rng = np.random.default_rng(8)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ln,)).astype(np.int32),
                    max_new_tokens=4) for ln in (6, 18, 9, 30, 12)]
    eng.serve(reqs)
    st = eng.paging_stats
    assert st["paged_peak_tokens"] <= st["dense_equiv_tokens"]
    assert st["paged_peak_tokens"] < st["dense_equiv_tokens"]  # mixed mix
    assert 0.0 <= st["frag_at_high_water"] < 1.0


def test_slot_reuse_without_cache_reset():
    """More requests than slots: every completion hands its slot (and
    pages) to the next request with NO cache reset between generations —
    mixed lengths across the whole queue."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS))
    rng = np.random.default_rng(9)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab,
                                        (6 + 3 * (i % 4),)).astype(np.int32),
                    max_new_tokens=3 + i % 3) for i in range(6)]
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r)


# ------------------------------- integrity hardening (DESIGN.md §7.6)


def test_allocator_double_release_counter_and_strict():
    """Double release is survivable-but-counted by default (the counter
    is the observability hook: a nonzero value means an engine bug), and
    raises under strict — the regression guard for the release path."""
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=2, n_pages=5)
    alloc = PageAllocator(geom, n_slots=2)
    alloc.admit(0, 8, worst_pages=2)
    alloc.release(0)
    assert alloc.double_release == 0
    alloc.release(0)
    alloc.release(1)                       # never-admitted slot counts too
    assert alloc.double_release == 2
    assert alloc.stats()["double_release"] == 2
    strict = PageAllocator(geom, n_slots=2, strict=True)
    strict.admit(0, 8, worst_pages=2)
    strict.release(0)
    with pytest.raises(RuntimeError, match="double release"):
        strict.release(0)


def test_allocator_quarantine_lifecycle():
    """Free pages retire immediately; owned pages are withheld from the
    free list at release; both shrink ``usable`` for good; idempotent."""
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=2, n_pages=7)
    alloc = PageAllocator(geom, n_slots=2)
    free_page = alloc.free[0]              # deep in the free list
    assert alloc.quarantine(free_page)
    assert free_page in alloc.quarantined and free_page not in alloc.free
    assert alloc.usable == geom.usable_pages - 1
    assert not alloc.quarantine(free_page)                  # idempotent
    alloc.admit(0, 8, worst_pages=2)
    owned = alloc.slot_pages[0][0]
    assert alloc.quarantine(owned)
    assert owned not in alloc.quarantined                   # pending
    assert alloc.owner_of(owned) == 0
    assert alloc.pages_quarantined == 2
    alloc.release(0)
    assert owned in alloc.quarantined and owned not in alloc.free
    assert alloc.usable == geom.usable_pages - 2
    with pytest.raises(ValueError):
        alloc.quarantine(0)                # null page is out of the pool


def test_allocator_checksum_records_cleared_on_release():
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=1, n_pages=5)
    alloc = PageAllocator(geom, n_slots=1)
    alloc.admit(0, 8, worst_pages=2)
    page = alloc.slot_pages[0][0]
    alloc.record_checksum(page, 4, 0xDEAD)
    assert alloc.checksums[page] == (4, 0xDEAD)
    alloc.release(0)
    assert page not in alloc.checksums     # stale crc can't false-positive


def test_allocator_property_fuzz_invariants():
    """Property fuzz (satellite): random admit/ensure/release/quarantine
    interleavings — after EVERY op the allocator's own ``_check`` runs
    and no page is ever doubly owned, both free and owned, or circulating
    after quarantine.  Uses the hypothesis shim so bare containers still
    run the sweep deterministically."""
    from _hypothesis_compat import given, settings, st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000),
           policy=st.sampled_from(["worst_case", "prompt"]))
    def run(seed, policy):
        rng = np.random.default_rng(seed)
        geom = paging.geometry(max_seq=32, page_size=4, n_slots=3,
                               n_pages=12)
        alloc = PageAllocator(geom, n_slots=3, policy=policy)
        live = {}
        for _ in range(80):
            op = int(rng.integers(0, 5))
            slot = int(rng.integers(0, 3))
            if op == 0 and slot not in live:
                n_tok = int(rng.integers(1, 17))
                worst = min(alloc.pages_for(n_tok) + int(rng.integers(0, 3)),
                            geom.pages_per_slot)
                if alloc.admit(slot, n_tok, worst):
                    live[slot] = (n_tok, worst)
            elif op == 1 and slot in live:
                n_tok, worst = live[slot]
                n_tok = min(n_tok + int(rng.integers(1, 5)),
                            worst * geom.page_size)
                try:
                    alloc.ensure(slot, n_tok)
                    live[slot] = (n_tok, worst)
                except paging.PoolExhausted:
                    pass    # prompt policy, dry pool: the engine would
                    # evict a victim and retry; partial growth is kept
            elif op == 2 and slot in live:
                alloc.release(slot, evicted=bool(rng.integers(0, 2)))
                del live[slot]
            elif op == 3:
                alloc.release(slot)        # double releases counted, not fatal
                live.pop(slot, None)
            elif op == 4:
                page = int(rng.integers(1, geom.n_pages))
                # quarantining a FREE page shrinks usable immediately —
                # skip when reservations are at capacity (the engine only
                # quarantines pages it preempts the owners of, so it
                # never over-commits this way either)
                if page in alloc.free \
                        and sum(alloc.reserved) >= alloc.usable:
                    continue
                alloc.quarantine(page)
            alloc._check()
            owned = [p for pages in alloc.slot_pages for p in pages]
            assert len(owned) == len(set(owned)), "page doubly owned"
            assert not set(owned) & set(alloc.free), "page free AND owned"
            assert not (set(owned) | set(alloc.free)) & alloc.quarantined, \
                "quarantined page back in circulation"
            assert len(alloc.free) + len(owned) == alloc.usable
        assert alloc.high_water <= geom.usable_pages

    run()
