"""Paged KV-cache subsystem (DESIGN.md §6, serve/paging.py).

Covers the allocator invariants, paged-vs-dense logits equivalence across
every cache variant (gqa / mla / windowed / int8) and page-boundary prompt
lengths, pool-exhaustion admission deferral, and the stale-offset drift
regression (a request slotted into a half-decoded batch).

Determinism note (the PR 3 lesson): nothing here asserts on wall-clock —
token streams, logits, and page counts are all deterministic functions of
seeds and request mixes, so these tests cannot flake under parallel tier-1
load.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import LanguageModel
from repro.serve import Engine, PageAllocator, Request, ServeConfig, paging

S_MAX = 64
PS = 4           # page size: small so short tests cross page boundaries


# ------------------------------------------------------------- allocator


def test_allocator_basic_lifecycle():
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=2, n_pages=0)
    assert geom.pages_per_slot == 8
    assert geom.n_pages == 17 and geom.usable_pages == 16   # + null page
    alloc = PageAllocator(geom, n_slots=2)
    assert alloc.admit(0, n_tokens=6, worst_pages=4)
    assert alloc.pages_in_use == 2                          # ceil(6/4)
    assert (alloc.table[0, :2] > 0).all()                   # never page 0
    assert alloc.ensure(0, 9)                               # 3rd page
    assert not alloc.ensure(0, 9)                           # idempotent
    assert alloc.pages_in_use == 3 and alloc.high_water == 3
    alloc.release(0)
    assert alloc.pages_in_use == 0 and (alloc.table == 0).all()
    assert alloc.high_water == 3                            # sticky


def test_allocator_admission_control_and_reuse():
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=3, n_pages=5)
    alloc = PageAllocator(geom, n_slots=3)                  # 4 usable pages
    assert alloc.admit(0, 8, worst_pages=2)
    assert alloc.admit(1, 8, worst_pages=2)
    assert not alloc.can_admit(2)                           # reservations full
    assert not alloc.admit(2, 8, worst_pages=2)
    alloc.release(0)
    assert alloc.admit(2, 8, worst_pages=2)                 # freed pages reused
    used = {p for pages in alloc.slot_pages for p in pages}
    assert 0 not in used and len(used) == alloc.pages_in_use


def test_allocator_reservation_invariant():
    geom = paging.geometry(max_seq=32, page_size=4, n_slots=1, n_pages=0)
    alloc = PageAllocator(geom, n_slots=1)
    alloc.admit(0, 4, worst_pages=2)
    with pytest.raises(AssertionError, match="reservation"):
        alloc.ensure(0, 12)                                 # needs 3 > 2


# -------------------------------------------- paged vs dense equivalence


def _decode_equiv(cfg, prompt_len, n_steps=4, slot=1, atol=1e-3):
    """Prefill once, then decode the same token stream through (a) the
    dense batch-1 cache and (b) a paged 2-slot cache committed at `slot`,
    asserting step-by-step logits equality."""
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(prompt_len)
    prompt = rng.integers(0, cfg.vocab, (1, prompt_len)).astype(np.int32)

    logits, cache_d = model.prefill(params, {"tokens": jnp.asarray(prompt)},
                                    S_MAX)
    geom = paging.geometry(S_MAX, PS, n_slots=2)
    alloc = PageAllocator(geom, n_slots=2)
    caches_p = model.init_cache(2, S_MAX, paging=geom)
    worst = min(alloc.pages_for(prompt_len + n_steps), geom.pages_per_slot)
    assert alloc.admit(slot, prompt_len, worst)
    caches_p = paging.commit_prefill(caches_p, cache_d, slot, prompt_len,
                                     alloc.table, PS)

    tok = int(jnp.argmax(logits[0, -1]))
    pos = prompt_len
    for _ in range(n_steps):
        if alloc.ensure(slot, pos + 1):
            caches_p = paging.sync_block_tables(caches_p, alloc.table)
        tok_d = jnp.full((1, 1), tok, jnp.int32)
        tok_p = jnp.zeros((2, 1), jnp.int32).at[slot, 0].set(tok)
        ld, cache_d = model.decode_step(params, cache_d, tok_d)
        lp, caches_p = model.decode_step(params, caches_p, tok_p)
        np.testing.assert_allclose(
            np.asarray(ld[0], np.float32), np.asarray(lp[slot], np.float32),
            atol=atol, rtol=1e-3)
        tok = int(jnp.argmax(ld[0, -1]))
        pos += 1


# page-boundary lengths: len % PS ∈ {0, 1, PS-1} (plus an interior value)
_BOUNDARY_LENS = [PS * 3, PS * 3 + 1, PS * 3 - 1, 10]


@pytest.mark.parametrize("prompt_len", _BOUNDARY_LENS)
def test_paged_matches_dense_gqa(prompt_len):
    _decode_equiv(get_smoke("granite-3-2b"), prompt_len)


@pytest.mark.parametrize("prompt_len", [PS * 3, PS * 3 + 1, PS * 3 - 1])
def test_paged_matches_dense_mla(prompt_len):
    _decode_equiv(get_smoke("minicpm3-4b"), prompt_len)


def test_paged_matches_dense_int8():
    cfg = dataclasses.replace(get_smoke("granite-3-2b"),
                              kv_cache_dtype="int8")
    _decode_equiv(cfg, PS * 2 + 1)


def test_paged_matches_dense_windowed():
    """Windowed layers keep dense rings under paging (bounded residency);
    the per-slot index must still line their masks up with the paged
    full-attention layers in the same stack."""
    _decode_equiv(get_smoke("recurrentgemma-9b"), PS * 2, n_steps=5)


def test_null_page_isolation():
    """Slot 0 stays inactive (block table row 0, index 0) while slot 1
    decodes — its writes land in the null page and must never perturb the
    active slot (checked implicitly by _decode_equiv using slot=1), and
    page 0 is never handed out."""
    cfg = get_smoke("granite-3-2b")
    geom = paging.geometry(S_MAX, PS, n_slots=2)
    alloc = PageAllocator(geom, n_slots=2)
    alloc.admit(1, 12, 6)
    assert 0 not in {p for pages in alloc.slot_pages for p in pages}
    _decode_equiv(cfg, 12, slot=1)


# --------------------------------------------------------- serve-level


def _oracle(eng, req):
    return list(eng.generate(req.tokens[None, :],
                             max_new_tokens=req.max_new_tokens)[0])


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_serve_mixed_lengths_match_oracle(layout):
    """The tentpole: mixed-length prompts in ONE live batch (the PR 3
    guard is gone), token-for-token equal to generate()."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2,
                                  kv_layout=layout, page_size=PS))
    rng = np.random.default_rng(5)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ln,)).astype(np.int32),
                    max_new_tokens=5) for ln in (10, 13, 7)]
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r)
    assert eng.paging_stats["kv_layout"] == layout


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_midstream_slotting_no_stale_offset_drift(layout):
    """Regression for the stale-offset drift the mixed-length guard used
    to mask: a SAME-length request slotted into a half-decoded batch must
    start from its own position, not the batch's advanced write head."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2,
                                  kv_layout=layout, page_size=PS))
    rng = np.random.default_rng(6)
    mk = lambda mx: Request(tokens=rng.integers(
        0, cfg.vocab, (9,)).astype(np.int32), max_new_tokens=mx)
    # req0 decodes long; req1 finishes fast and frees its slot; req2 is
    # then admitted while req0 is half-decoded (same prompt length)
    reqs = [mk(10), mk(3), mk(6)]
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r), "mid-stream slotted request drifted"


def test_serve_pool_exhaustion_defers_admission():
    """3 slots but pages for only 2 concurrent requests: the third must
    wait for a completion (deferral counted), then finish correctly."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=3, page_size=8,
                                  n_pages=5))                 # 4 usable
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=5) for _ in range(3)]
    eng.serve(reqs)
    assert all(r.done and len(r.out) == 5 for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r)
    st = eng.paging_stats
    assert st["admission_deferrals"] > 0
    assert st["page_high_water"] <= 4                       # pool bound held
    assert st["pages_in_use"] == 0                          # all freed


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_serve_budget_overflowing_max_seq_raises(layout):
    """prompt + max_new - 1 beyond max_seq must be rejected at admission
    (paged: the reservation would outgrow the block table and crash
    mid-decode; dense: writes would silently drop).  The exact-fit budget
    is fine and fills the last page completely."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=16, n_slots=1, kv_layout=layout,
                                  page_size=PS))
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)
    with pytest.raises(ValueError, match="max_seq"):
        eng.serve([Request(tokens=prompt.copy(), max_new_tokens=9)])  # 17
    ok = Request(tokens=prompt.copy(), max_new_tokens=8)              # 16
    eng.serve([ok])
    assert ok.done and len(ok.out) == 8
    assert ok.out == _oracle(eng, ok)


def test_serve_request_too_big_for_pool_raises():
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=8,
                                  n_pages=3))                 # 2 usable
    req = Request(tokens=np.arange(16, dtype=np.int32) % cfg.vocab,
                  max_new_tokens=20)                          # worst 5 pages
    with pytest.raises(ValueError, match="pool"):
        eng.serve([req])


def test_paged_residency_bounded_by_dense():
    """Acceptance bound: paged peak KV residency <= dense (n_slots, S_max)
    and strictly lower on a mixed-length mix."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=4, page_size=PS))
    rng = np.random.default_rng(8)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ln,)).astype(np.int32),
                    max_new_tokens=4) for ln in (6, 18, 9, 30, 12)]
    eng.serve(reqs)
    st = eng.paging_stats
    assert st["paged_peak_tokens"] <= st["dense_equiv_tokens"]
    assert st["paged_peak_tokens"] < st["dense_equiv_tokens"]  # mixed mix
    assert 0.0 <= st["frag_at_high_water"] < 1.0


def test_slot_reuse_without_cache_reset():
    """More requests than slots: every completion hands its slot (and
    pages) to the next request with NO cache reset between generations —
    mixed lengths across the whole queue."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS))
    rng = np.random.default_rng(9)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab,
                                        (6 + 3 * (i % 4),)).astype(np.int32),
                    max_new_tokens=3 + i % 3) for i in range(6)]
    eng.serve(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r)
