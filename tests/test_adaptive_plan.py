"""Adaptive RgCSR plans: length-aware regrouping, pathological-row spill,
fused inverse-gather epilogue, cache keying, and the joint autotune search.

The invariant under test everywhere: an adaptive plan computes *exactly*
the same y = A @ x as the dense oracle (up to fp reassociation) — the
permutation, the per-group slot sizing, and the COO spill are all plan
metadata, never semantics.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import from_dense
from repro.core.ordering import descending_from_lengths, split_spill_rows
from repro.core.spmv import spmv
from repro.core.suite import generate
from repro.kernels import autotune
from repro.kernels.ops import (PLAN_CACHE, PlanCache, get_plan, make_plan,
                               rgcsr_spmv, rgcsr_spmm)


def _rand(seed, n, m, density):
    rng = np.random.default_rng(seed)
    a = (rng.uniform(size=(n, m)) < density).astype(np.float32)
    a *= rng.uniform(0.5, 1.5, size=(n, m)).astype(np.float32)
    return a


def _skewed(seed, n=300, m=280):
    """A few near-dense rows over a sparse background (Table 6 pathology)."""
    a = _rand(seed, n, m, 0.02)
    rng = np.random.default_rng(seed + 1)
    for r in rng.choice(n, size=3, replace=False):
        cols = rng.choice(m, size=int(0.7 * m), replace=False)
        a[r, cols] = rng.uniform(0.5, 1.5, size=len(cols)).astype(np.float32)
    return a


# ------------------------------------------------------- ordering helpers


def test_descending_from_lengths_stable():
    lens = np.array([3, 7, 3, 0, 7])
    perm = descending_from_lengths(lens)
    assert list(perm) == [1, 4, 0, 2, 3]   # ties keep original order


def test_gather_idx_is_inverse_of_perm():
    """The plan's gather map is the inverse of the row permutation: row r
    reads exactly the kernel-output lane that holds A[r]'s sum."""
    a = _skewed(0)
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat, ordering="adaptive")
    gi = np.asarray(plan.gather_idx)
    assert len(np.unique(gi)) == len(gi)           # a bijection onto lanes
    lens = (a != 0).sum(axis=1)
    # descending-length order: the lane index ordering must sort lengths
    assert (np.diff(lens[np.argsort(gi)]) <= 0).all()


def test_split_spill_rows():
    lens = np.array([1, 50, 2, 200, 3])
    grouped, spilled = split_spill_rows(lens, 10)
    assert list(grouped) == [0, 2, 4] and list(spilled) == [1, 3]
    grouped, spilled = split_spill_rows(lens, 0)   # 0 disables spilling
    assert list(grouped) == [0, 1, 2, 3, 4] and len(spilled) == 0


# ------------------------------------------- permutation round-trip vs oracle


@pytest.mark.parametrize("family", ["circuit", "powerlaw", "uniform",
                                    "banded"])
@pytest.mark.parametrize("cps", (1, 4))
def test_adaptive_matches_oracle(family, cps):
    """permute → spmv → fused inverse gather ≡ dense oracle."""
    a = generate(family, 256, seed=0)
    mat = from_dense(a, "rgcsr", group_size=128)
    x = np.random.default_rng(1).standard_normal(a.shape[1]).astype(np.float32)
    plan = make_plan(mat, chunks_per_step=cps, ordering="adaptive")
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("spill", (0, 8, 64))
def test_adaptive_spill_matches_oracle(spill):
    a = _skewed(3)
    mat = from_dense(a, "rgcsr", group_size=128)
    x = np.random.default_rng(2).standard_normal(a.shape[1]).astype(np.float32)
    plan = make_plan(mat, chunks_per_step=2, ordering="adaptive",
                     spill_threshold=spill)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)
    if spill:
        assert plan.n_spilled_elements > 0


def test_adaptive_spmm_matches_oracle():
    a = _skewed(5, n=200, m=150)
    mat = from_dense(a, "rgcsr", group_size=128)
    x = np.random.default_rng(4).standard_normal((150, 9)).astype(np.float32)
    plan = make_plan(mat, chunks_per_step=1, ordering="adaptive",
                     spill_threshold=16)
    got = np.asarray(rgcsr_spmm(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


def test_adaptive_reduces_padding_on_skewed():
    """The tentpole's point: ≥2× less padding than block on skewed rows."""
    a = generate("circuit", 256, seed=0)
    mat = from_dense(a, "rgcsr", group_size=128)
    block = make_plan(mat, chunks_per_step=1)
    spill = autotune.spill_threshold_candidates((a != 0).sum(axis=1))[-1]
    adapt = make_plan(mat, chunks_per_step=1, ordering="adaptive",
                      spill_threshold=spill)
    assert block.padded_slot_fraction >= 2 * adapt.padded_slot_fraction
    assert adapt.num_steps < block.num_steps


# ----------------------------------------------------------------- edge cases


def test_adaptive_empty_matrix():
    mat = from_dense(np.zeros((0, 40), np.float32), "rgcsr", group_size=128)
    plan = make_plan(mat, ordering="adaptive", spill_threshold=4)
    assert plan.num_steps >= 1
    y = np.asarray(rgcsr_spmv(plan, jnp.zeros(40), interpret=True))
    assert y.shape == (0,)


def test_adaptive_all_rows_spilled():
    """threshold below every row length → pure-COO execution path."""
    a = _rand(6, 100, 90, 0.2)
    a[:, 0] = 1.0                                  # every row nonempty
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat, ordering="adaptive", spill_threshold=1)
    assert not bool(np.asarray(plan.grouped_mask).any())
    assert plan.n_spilled_elements == mat.nnz
    x = np.random.default_rng(7).standard_normal(90).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


def test_adaptive_single_row():
    a = np.zeros((1, 64), np.float32)
    a[0, [3, 9, 41]] = (1.0, 2.0, 3.0)
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat, ordering="adaptive")
    x = np.random.default_rng(8).standard_normal(64).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


def test_spill_requires_adaptive():
    mat = from_dense(_rand(9, 64, 64, 0.1), "rgcsr", group_size=128)
    with pytest.raises(ValueError, match="adaptive"):
        make_plan(mat, spill_threshold=4)
    with pytest.raises(ValueError, match="ordering"):
        make_plan(mat, ordering="descending")


# ------------------------------------------------------------- cache keying


def test_plan_cache_adaptive_vs_block_no_collision():
    """Block and adaptive plans of one matrix must coexist in the cache."""
    cache = PlanCache(maxsize=8)
    mat = from_dense(_rand(10, 96, 96, 0.1), "rgcsr", group_size=128)
    p_block = cache.get(mat)
    p_adapt = cache.get(mat, ordering="adaptive")
    p_spill = cache.get(mat, ordering="adaptive", spill_threshold=8)
    assert p_block is not p_adapt and p_adapt is not p_spill
    assert p_block.ordering == "block" and p_adapt.ordering == "adaptive"
    assert cache.stats() == {"hits": 0, "misses": 3, "entries": 3}
    # repeat lookups hit the right entries
    assert cache.get(mat) is p_block
    assert cache.get(mat, ordering="adaptive") is p_adapt
    assert cache.get(mat, ordering="adaptive", spill_threshold=8) is p_spill
    assert cache.stats()["hits"] == 3


def test_spmv_dispatch_adaptive_kernel():
    mat = from_dense(_skewed(11), "rgcsr", group_size=128)
    x = np.random.default_rng(12).standard_normal(
        mat.shape[1]).astype(np.float32)
    y_ref = np.asarray(spmv(mat, jnp.asarray(x), impl="ref"))
    y_ad = np.asarray(spmv(mat, jnp.asarray(x), impl="kernel",
                           ordering="adaptive", spill_threshold=32))
    np.testing.assert_allclose(y_ad, y_ref, rtol=1e-4, atol=1e-4)
    assert get_plan(mat, ordering="adaptive", spill_threshold=32) is \
        get_plan(mat, ordering="adaptive", spill_threshold=32)


# ------------------------------------------------------------ joint autotune


def test_spill_threshold_candidates():
    lens = np.array([2] * 200 + [180, 190])
    cands = autotune.spill_threshold_candidates(lens)
    assert cands[0] == 0 and len(cands) > 1
    assert all(0 < t < 190 for t in cands[1:])
    assert autotune.spill_threshold_candidates(np.zeros(5, int)) == (0,)
    assert autotune.spill_threshold_candidates(np.array([3, 3, 3])) == (0,)


def test_autotune_searches_orderings_jointly(deterministic_autotune):
    a = _skewed(13)
    res = autotune.autotune_spmv(a, repeats=1)
    orderings = {cfg.ordering for cfg, _ in res.timings}
    assert orderings == {"block", "adaptive"}
    assert res.config.ordering in ("block", "adaptive")
    # the block cps=1 g=128 baseline was measured, so the winner can never
    # regress vs PR 1's schedule (the ≤5% acceptance bound holds trivially)
    assert res.us_per_call <= res.baseline_us


def test_autotune_prefers_adaptive_on_skewed(deterministic_autotune):
    """On a pathological matrix the regrouped/spilled plan does far less
    grid work, so the search must pick it.  Ranked by the deterministic
    fake timer (conftest): the real measured medians flaked under load."""
    a = generate("circuit", 256, seed=1)
    res = autotune.autotune_spmv(a, repeats=1)
    assert res.config.ordering == "adaptive"
    assert res.speedup >= 1.0


def test_tuned_plan_carries_winning_ordering():
    autotune.clear_memo()
    a = generate("circuit", 256, seed=2)
    plan, res = autotune.tuned_plan(a, repeats=1)
    assert plan.ordering == res.config.ordering
    assert plan.spill_threshold == res.config.spill_threshold
    x = np.random.default_rng(14).standard_normal(
        a.shape[1]).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- serving path


def test_engine_warm_spmv_plans():
    from repro.configs import get_smoke
    from repro.serve import Engine, ServeConfig
    autotune.clear_memo()
    eng = Engine(get_smoke("granite-3-2b"), ServeConfig(max_seq=32))
    mats = [generate("banded", 256, seed=4)]
    winners = eng.warm_spmv_plans(mats, repeats=1)
    assert len(winners) == 1
    assert winners[0].ordering in ("block", "adaptive")
    stats = eng.plan_cache_stats()
    assert stats["spmv_plans_warmed"] == 1
    # warmed plan is served from the cache (no rebuild for the same matrix)
    before = PLAN_CACHE.stats()["misses"]
    autotune.tuned_plan(mats[0], repeats=1)
    assert PLAN_CACHE.stats()["misses"] == before
