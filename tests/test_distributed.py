"""Distribution tests — run in subprocesses with a fake 8-device host so the
main pytest process keeps its single real CPU device (assignment
requirement: the 512-device flag must live ONLY in launch/dryrun.py).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0 and "OK" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_partitioner_rules_resolve():
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding import Partitioner
        from repro.models.spec import P as Spec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        part = Partitioner(mesh, "train")
        # divisible dims shard; non-divisible fall back to replicated
        s = part._leaf_spec(Spec((16, 8), ("embed", "mlp")))
        assert s == P("data", "model"), s
        s = part._leaf_spec(Spec((15, 9), ("embed", "mlp")))
        assert s == P(None, None), s
        # one mesh axis never used twice in a leaf
        s = part._leaf_spec(Spec((8, 8), ("mlp", "mlp2")))
        assert s[0] == "model" and s[1] is None, s
        # serve rules: whole-mesh EP with fallback
        part2 = Partitioner(mesh, "decode")
        s = part2._leaf_spec(Spec((8, 4, 4), ("experts", "embed", "mlp")))
        assert s[0] == ("data", "model"), s
        print("OK")
    """)


def test_train_step_compiles_on_mesh_and_runs():
    """End-to-end SPMD: real (tiny) train step on a (2,4) mesh, executed."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, numpy as np
        from repro.configs import get_smoke
        from repro.sharding import Partitioner
        from repro.launch.steps import make_train_step
        from repro.train.optimizer import OptimizerConfig
        from repro.models import LanguageModel
        from repro.train.data import DataConfig, make_batch

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(
            get_smoke("granite-3-2b"), act_shard=True,
            attn_shard_mode="repeat", mesh_batch_axes=("data",),
            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
        model = LanguageModel(cfg)
        part = Partitioner(mesh, "train")
        spec = model.spec()
        p_sh = part.param_shardings(spec)
        o_sh = part.opt_shardings(spec, "adamw")
        step, opt_init = make_train_step(model, OptimizerConfig(lr=1e-3), 2)
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), p_sh)
        opt = jax.device_put(opt_init(params), o_sh)
        dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
        batch = make_batch(dc, 0)
        with mesh:
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, None),
                         out_shardings=(p_sh, o_sh, None))
            params, opt, metrics = fn(params, opt, batch)
            params, opt, metrics = fn(params, opt, make_batch(dc, 1))
        assert np.isfinite(float(metrics["loss"]))
        print("OK")
    """)


def test_elastic_reshard_checkpoint():
    """Save on a (2,4) layout, restore onto (1,8) — elastic restart."""
    _run("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import save, restore_sharded
        mesh_a = jax.make_mesh((2, 4), ("data", "model"))
        mesh_b = jax.make_mesh((1, 8), ("data", "model"))
        w = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                           NamedSharding(mesh_a, P("data", "model")))
        with tempfile.TemporaryDirectory() as d:
            save(d, 1, {"w": w})
            restored, _ = restore_sharded(
                d, {"w": np.zeros((8, 8), np.float32)},
                {"w": NamedSharding(mesh_b, P("data", "model"))})
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(w))
        assert restored["w"].sharding.mesh.shape["model"] == 8
        print("OK")
    """)


def test_dryrun_single_cell_subprocess():
    """The actual dry-run entry point on the production mesh (256 fake
    devices) for one small cell — proves the documented launch path."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-moe-1b-a400m", "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560)
    assert "1/1 cells OK" in out.stdout, (out.stdout[-1500:],
                                          out.stderr[-1500:])
