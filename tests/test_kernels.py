"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle.

All kernels run in interpret mode (CPU container); the sweep covers group
sizes, ragged shapes, rectangular matrices, empty rows, bf16/fp32.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import from_dense
from repro.kernels import (ell_spmv, make_ell_plan, make_plan, rgcsr_spmm,
                           rgcsr_spmv)
from repro.kernels.ref import spmv_ref, spmm_ref


def _rand(seed, n, m, density):
    rng = np.random.default_rng(seed)
    a = (rng.uniform(size=(n, m)) < density).astype(np.float32)
    a *= rng.uniform(0.5, 1.5, size=(n, m)).astype(np.float32)
    return a


@pytest.mark.parametrize("n,m,density,g", [
    (64, 64, 0.1, 128),        # fewer rows than one group
    (128, 128, 0.05, 128),     # exactly one group
    (300, 257, 0.08, 128),     # ragged rows+cols
    (513, 300, 0.02, 256),     # larger group
    (130, 1000, 0.01, 128),    # wide
    (40, 40, 0.5, 128),        # dense-ish
])
def test_rgcsr_spmv_shapes(n, m, density, g):
    a = _rand(0, n, m, density)
    mat = from_dense(a, "rgcsr", group_size=g)
    plan = make_plan(mat)
    x = np.random.default_rng(1).standard_normal(m).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    ref = np.asarray(spmv_ref(mat, jnp.asarray(x)))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_rgcsr_spmv_dtypes(dtype, rtol):
    a = _rand(2, 200, 200, 0.05)
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat)
    plan = dataclasses.replace(plan, values2d=plan.values2d.astype(dtype))
    x = jnp.asarray(np.random.default_rng(3).standard_normal(200), dtype)
    got = np.asarray(rgcsr_spmv(plan, x, interpret=True)).astype(np.float32)
    ref = a @ np.asarray(x, np.float32)
    np.testing.assert_allclose(got, ref, rtol=rtol, atol=rtol * 10)


@pytest.mark.parametrize("d", [1, 7, 64, 129])
def test_rgcsr_spmm_widths(d):
    a = _rand(4, 150, 140, 0.07)
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat)
    x = np.random.default_rng(5).standard_normal((140, d)).astype(np.float32)
    got = np.asarray(rgcsr_spmm(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n=st.integers(8, 200),
       m=st.integers(8, 200))
def test_rgcsr_spmv_property(seed, n, m):
    a = _rand(seed, n, m, 0.08)
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat)
    x = np.random.default_rng(seed).standard_normal(m).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


def test_rgcsr_empty_rows_and_ghost_index():
    a = np.zeros((140, 90), np.float32)
    a[0, 3] = 2.0
    a[139, 89] = -1.0            # only two nonzeros; many empty rows
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat)
    x = np.random.default_rng(0).standard_normal(90).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-5, atol=1e-6)


def test_plan_rejects_non_tpu_group_size():
    a = _rand(6, 64, 64, 0.1)
    mat = from_dense(a, "rgcsr", group_size=32, slot_pad=4)
    with pytest.raises(ValueError):
        make_plan(mat)


@pytest.mark.parametrize("n,m", [(64, 64), (200, 130), (257, 511)])
def test_ell_spmv(n, m):
    a = _rand(7, n, m, 0.06)
    mat = from_dense(a, "ellpack")
    plan = make_ell_plan(mat)
    x = np.random.default_rng(8).standard_normal(m).astype(np.float32)
    got = np.asarray(ell_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)
