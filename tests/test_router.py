"""Multi-replica router fault tolerance (DESIGN.md §7, serve/router.py).

Covers the acceptance scenario — 3 replicas, one killed mid-decode, every
migrated stream token-identical to the single-engine ``generate()`` oracle
with zero failures — plus retry-budget exhaustion, backpressure shedding,
FIFO fairness across replicas under sustained overload, replica draining,
and the per-arrival deadline semantics the reentrant session enables.

Determinism note (the PR 3 lesson): nothing here asserts on wall-clock —
every engine runs a shared FakeClock advanced per decode step, the router
``sleep`` advances the same fake timer, token streams are greedy, and
the ``("replica", k)`` fault site fires on an exact decode-step count.
"""
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.serve import (Engine, Request, Router, RouterConfig, ServeConfig,
                         paging)
from repro.train.fault import FaultConfig, FaultInjector

S_MAX = 64
PS = 4


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _tick_decode(eng, clock, dt=1.0):
    """Each decode step on this engine advances the shared fake clock.
    Serving dispatches through the fused chunk runner (one call = up to
    decode_chunk steps — the clock advances by the steps that ran);
    generate()/oracle calls go through the per-step ``_decode``."""
    orig = eng._decode
    orig_fused = eng._fused_decode

    def wrapped(*a):
        clock.advance(dt)
        return orig(*a)

    def wrapped_fused(*a):
        out = orig_fused(*a)
        clock.advance(dt * int(out[1]))
        return out

    eng._decode = wrapped
    eng._fused_decode = wrapped_fused


def _fleet(n_replicas, clock=None, fault_cfg=None, router_cfg=None,
           injectors=None, **serve_kw):
    """n engine replicas sharing one set of params + a router over them.

    ``injectors``: {replica_index: FaultInjector} — attached BEFORE the
    router opens sessions (a session resolves its injector at creation).
    """
    cfg = get_smoke("granite-3-2b")
    skw = dict(max_seq=S_MAX, n_slots=2, page_size=PS)
    skw.update(serve_kw)
    scfg = ServeConfig(**skw)
    first = Engine(cfg, scfg, fault_cfg=fault_cfg)
    engines = [first] + [Engine(cfg, scfg, params=first.params,
                                fault_cfg=fault_cfg)
                         for _ in range(n_replicas - 1)]
    for idx, inj in (injectors or {}).items():
        engines[idx].fault_injector = inj
    if clock is not None:
        for e in engines:
            e.clock = clock
            _tick_decode(e, clock)
    router = Router(engines, cfg=router_cfg, fault_cfg=fault_cfg,
                    clock=clock,
                    sleep=(clock.advance if clock is not None else None))
    return cfg, engines, router


def _reqs(cfg, n, seed=5, prompt_len=8, max_new=5):
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, cfg.vocab,
                                        (prompt_len,)).astype(np.int32),
                    max_new_tokens=max_new) for _ in range(n)]


def _oracle(eng, req):
    return list(eng.generate(req.tokens[None, :],
                             max_new_tokens=req.max_new_tokens)[0])


# ----------------------------------------------------------- happy path


def test_router_serve_matches_oracle_across_replicas():
    """No faults: the router spreads requests over 2 replicas and every
    stream equals the single-engine oracle (replicas share params, so one
    oracle engine answers for all)."""
    clock = FakeClock()
    cfg, engines, router = _fleet(2, clock=clock)
    reqs = _reqs(cfg, 5)
    router.serve(reqs)
    assert all(r.ok_like for r in reqs)
    for r in reqs:
        assert r.out == _oracle(engines[0], r)
    st = router.stats()
    assert st["completed"] == 5
    assert st["migrations"] == 0 and st["shed"] == 0
    assert st["retries_exhausted"] == 0
    assert len(st["page_high_water_per_replica"]) == 2
    # work actually spread: no replica served everything alone
    assert all(hw > 0 for hw in st["page_high_water_per_replica"])


# ----------------------------------------------------- failover + migration


def test_replica_kill_mid_decode_migrates_token_identical():
    """THE acceptance scenario: 3 replicas, one killed mid-decode via the
    site-qualified injector.  Its in-flight requests migrate to survivors
    (recompute path: re-prefill prompt + generated prefix) and every
    stream — migrated or not — is token-identical to the oracle, with
    zero failed requests."""
    clock = FakeClock()
    fc = FaultConfig(max_restarts=3, backoff_s=0.5)
    # the victim's 3rd decode step dies with requests resident mid-stream
    cfg, engines, router = _fleet(
        3, clock=clock, fault_cfg=fc,
        injectors={1: FaultInjector(fail_at_steps=(("replica", 2),))})
    reqs = _reqs(cfg, 8, max_new=6)
    router.serve(reqs)
    assert all(r.ok_like for r in reqs), \
        [(r.status, r.error) for r in reqs if not r.ok_like]
    for r in reqs:
        assert r.out == _oracle(engines[0], r), "migrated stream drifted"
    st = router.stats()
    assert st["replica_faults"] == 1
    assert st["migrations"] > 0                 # someone was mid-stream
    assert st["failed"] == 0 and st["retries_exhausted"] == 0
    assert st["completed"] == 8
    migrated = [r for r in reqs if r.retries > 0]
    assert migrated and all(r.retries == 1 for r in migrated)
    # the dead replica came back after backoff (fire-once injector)
    assert st["replica_restarts"] == 1
    assert all(s == "healthy" for s in st["replica_states"])


def test_replica_restart_backoff_schedule_on_fake_clock():
    """The revived replica comes back no earlier than backoff_s × restarts
    on the injected clock — asserted exactly, zero wall-clock."""
    clock = FakeClock()
    fc = FaultConfig(max_restarts=3, backoff_s=10.0)
    cfg, engines, router = _fleet(
        1, clock=clock, fault_cfg=fc, n_slots=1,
        injectors={0: FaultInjector(fail_at_steps=(("replica", 1),))})
    reqs = _reqs(cfg, 2, max_new=4)
    for r in reqs:
        router.submit(r)
    # run until the fault lands (decode step 1 → fault at t=1.0; the same
    # round then sleeps the fleet — via the injected clock — up to the
    # scheduled revival, since nothing else can make progress)
    while router.counters["replica_faults"] == 0:
        router.run_round()
    rep = router.replicas[0]
    assert rep.state == "dead"
    assert rep.restart_at == pytest.approx(1.0 + 10.0)  # backoff_s × 1
    router.serve([])                            # revive + drain
    assert clock() >= 11.0                      # revival waited out backoff
    assert router.counters["replica_restarts"] == 1
    assert all(r.ok_like for r in reqs)
    for r in reqs:
        assert r.out == _oracle(engines[0], r)


def test_retry_budget_exhaustion_fails_requests():
    """max_restarts=0: the first replica fault exhausts both the replica's
    restart budget (permanently down) and every migrated request's retry
    budget — they fail with retries_exhausted counted, instead of
    migrating forever."""
    clock = FakeClock()
    fc = FaultConfig(max_restarts=0, backoff_s=1.0)
    cfg, engines, router = _fleet(
        1, clock=clock, fault_cfg=fc,
        injectors={0: FaultInjector(fail_at_steps=(("replica", 1),))})
    reqs = _reqs(cfg, 4, max_new=6)
    router.serve(reqs)
    assert all(r.done for r in reqs)
    assert all(r.status == "failed" for r in reqs)
    st = router.stats()
    assert st["retries_exhausted"] == 4
    assert st["replica_restarts"] == 0
    assert st["replica_states"] == ["dead"]
    # resident victims carry their partial prefixes; none were lost
    assert all(r.out is not None for r in reqs)


# ------------------------------------------------------------ backpressure


def test_backpressure_sheds_over_capacity_arrivals():
    """Bounded router queue: arrivals beyond queue_limit are refused at
    the door with status="shed" (never silently dropped, never queued
    unboundedly); every accepted request still completes."""
    clock = FakeClock()
    cfg, engines, router = _fleet(1, clock=clock,
                                  router_cfg=RouterConfig(
                                      n_replicas=1, queue_limit=2),
                                  n_slots=1)
    reqs = _reqs(cfg, 5, max_new=3)
    accepted = [router.submit(r) for r in reqs]
    assert accepted == [True, True, False, False, False]
    shed = [r for r in reqs if r.status == "shed"]
    assert len(shed) == 3 and all(r.done and r.out == [] for r in shed)
    assert router.counters["shed"] == 3
    while not router.idle:
        router.run_round()
    kept = [r for r in reqs if r.status != "shed"]
    assert all(r.ok_like for r in kept)
    for r in kept:
        assert r.out == _oracle(engines[0], r)
    # draining the queue reopens capacity: a late arrival is accepted
    late = _reqs(cfg, 1, seed=9, max_new=3)[0]
    assert router.submit(late)
    router.serve([])
    assert late.ok_like


# ----------------------------------------------------------- FIFO fairness


def test_fifo_fairness_across_replicas_under_sustained_overload():
    """Sustained overload (8 requests through 2 small replicas): requests
    are first-slotted in submission order — the global router queue is the
    one FIFO authority, and no request is starved by replica-local
    queueing (first-slot instants, fake clock, are non-decreasing)."""
    clock = FakeClock()
    cfg, engines, router = _fleet(2, clock=clock, n_slots=2, page_size=8,
                                  n_pages=5)
    reqs = _reqs(cfg, 8, seed=12, max_new=5)
    router.serve(reqs)
    assert all(r.ok_like for r in reqs)
    for r in reqs:
        assert r.out == _oracle(engines[0], r)
    slotted_at = [r.arrival_t + r.queue_s for r in reqs]
    assert slotted_at == sorted(slotted_at), \
        "a later submission was slotted before an earlier one"


# ---------------------------------------------------------------- draining


def test_drain_replica_finishes_residents_then_recycles():
    """Planned maintenance: a draining replica takes no new work, its
    residents run to completion (not migrated, not killed), and the
    replica rejoins the healthy pool with a fresh session."""
    clock = FakeClock()
    # one decode step per round (not a full fused chunk) so replica 0
    # still has a mid-stream resident when the drain order lands
    cfg, engines, router = _fleet(
        2, clock=clock, n_slots=1,
        router_cfg=RouterConfig(n_replicas=2, steps_per_round=1))
    reqs = _reqs(cfg, 4, max_new=8)
    for r in reqs:
        router.submit(r)
    router.run_round()                         # residents on both replicas
    resident = router.replicas[0].session.inflight()
    assert resident
    router.drain_replica(0)
    assert router.replicas[0].state == "draining"
    while not router.idle:
        router.run_round()
    assert all(r.ok_like for r in reqs)
    for r in reqs:
        assert r.out == _oracle(engines[0], r)
    st = router.stats()
    assert st["drains"] == 1 and st["migrations"] == 0
    assert router.replicas[0].state == "healthy"
    # the drained replica's pre-drain work still shows in fleet stats
    assert st["completed"] == 4


# -------------------------------------------------- per-arrival deadlines


def test_deadline_measured_from_arrival_not_session_start():
    """A request submitted mid-session is billed from ITS arrival, not
    the session's start: deadline_s=3 submitted at t=5 survives (old
    semantics — measured from t_start=0 — would have expired it), while
    a sibling with deadline_s=0.5 times out from its own arrival."""
    clock = FakeClock()
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=1, page_size=PS))
    eng.clock = clock
    _tick_decode(eng, clock)
    rng = np.random.default_rng(3)
    mk = lambda mx, dl=None: Request(
        tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
        max_new_tokens=mx, deadline_s=dl)
    session = eng.start_session()
    a = mk(7)                                  # occupies the only slot
    session.submit(a)
    session.step(5)                            # t = 5.0, a mid-stream
    assert clock() == pytest.approx(5.0)
    b = mk(3, dl=3.0)                          # expires at t > 8
    c = mk(3, dl=0.5)                          # expires at t > 5.5
    session.submit(b)
    session.submit(c)
    assert b.arrival_t == pytest.approx(5.0)
    session.drain()
    # a finishes at t=6 (6 decode steps total); b slots at t=6 within its
    # own window — under from-t_start accounting it would be long dead
    assert a.ok_like and b.ok_like
    assert b.queue_s == pytest.approx(1.0)
    assert c.status == "timed_out" and "in queue" in c.error
    assert session.stats["timed_out"] == 1


def test_serve_batch_deadline_semantics_unchanged():
    """Batch-submitted serve(): every request arrives at call entry, so
    from-arrival deadlines degrade to the original from-entry semantics —
    a deadline shorter than the head-of-line wait still times out."""
    clock = FakeClock()
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=1, page_size=PS))
    eng.clock = clock
    _tick_decode(eng, clock)
    rng = np.random.default_rng(4)
    long = Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                   max_new_tokens=8)
    tight = Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=4, deadline_s=2.0)
    eng.serve([long, tight])
    assert long.ok_like
    assert tight.status == "timed_out"         # queued behind 8 steps
    assert tight.arrival_t == pytest.approx(0.0)


# --------------------------------------------------------- stats plumbing


def test_merge_replica_stats_shapes():
    per = [{"requests": 3, "completed": 3, "page_high_water": 4,
            "peak_live_tokens": 20, "n_pages": 17, "kv_layout": "paged"},
           {"requests": 2, "completed": 1, "page_high_water": 7,
            "peak_live_tokens": 10, "n_pages": 17, "kv_layout": "paged"}]
    m = paging.merge_replica_stats(per)
    assert m["requests"] == 5 and m["completed"] == 4
    assert m["page_high_water"] == 7
    assert m["page_high_water_per_replica"] == [4, 7]
    assert m["peak_live_tokens"] == 20
    assert m["n_pages"] == 17 and m["kv_layout"] == "paged"
    assert paging.merge_replica_stats([]) == {}


def test_straggler_decode_steps_per_replica():
    """Satellite (§7.6 observability): per-replica straggler attribution.
    Replica 1's decode steps slow 10× after the watchdog warms up; the
    merged ``straggler_decode_steps`` stays, and the new per-replica list
    pins the slow host — [0] stays clean, [1] carries every event."""
    clock = FakeClock()
    cfg, engines, router = _fleet(2, clock=clock, decode_chunk=1)
    # re-wrap replica 1 only: uniform dt=1 until step 8, then 10×
    count = [0]
    orig = engines[1]._fused_decode

    def slow_fused(*a):
        out = orig(*a)
        for _ in range(int(out[1])):
            clock.advance(9.0 if count[0] >= 8 else 0.0)  # on top of tick
            count[0] += 1
        return out

    engines[1]._fused_decode = slow_fused
    reqs = _reqs(cfg, 6, seed=31, prompt_len=6, max_new=12)
    router.serve(reqs)
    assert all(r.ok_like for r in reqs)
    st = router.stats()
    per = st["straggler_decode_steps_per_replica"]
    assert isinstance(per, list) and len(per) == 2
    assert per[0] == 0 and per[1] > 0, \
        "straggler events must attribute to the slow replica only"
    assert sum(per) == st["straggler_decode_steps"]
