"""Serving engine: batch generate, continuous batching, sampling."""
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.serve import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke("granite-3-2b")
    return Engine(cfg, ServeConfig(max_seq=96, n_slots=2, temperature=0.0))


def test_generate_shapes(engine):
    prompts = np.random.default_rng(0).integers(
        0, engine.model.cfg.vocab, (3, 12)).astype(np.int32)
    out = engine.generate(prompts, max_new_tokens=6)
    assert out.shape == (3, 6)
    assert (out >= 0).all() and (out < engine.model.cfg.padded_vocab).all()


def test_generate_deterministic_greedy(engine):
    prompts = np.random.default_rng(1).integers(
        0, engine.model.cfg.vocab, (2, 10)).astype(np.int32)
    a = engine.generate(prompts, max_new_tokens=5)
    b = engine.generate(prompts, max_new_tokens=5)
    np.testing.assert_array_equal(a, b)


def test_continuous_batching_completes_queue(engine):
    rng = np.random.default_rng(2)
    reqs = [Request(tokens=rng.integers(0, engine.model.cfg.vocab,
                                        (10,)).astype(np.int32),
                    max_new_tokens=4 + i % 3) for i in range(5)]
    done = engine.serve(reqs)
    assert all(r.done for r in done)
    for i, r in enumerate(done):
        assert len(r.out) == 4 + i % 3


def test_serve_matches_generate_greedy(engine):
    prompts = np.random.default_rng(3).integers(
        0, engine.model.cfg.vocab, (1, 14)).astype(np.int32)
    g = engine.generate(prompts, max_new_tokens=6)[0]
    single = Engine(engine.model.cfg, ServeConfig(max_seq=96, n_slots=1))
    single.params = engine.params
    req = Request(tokens=prompts[0], max_new_tokens=6)
    single.serve([req])
    assert list(g) == req.out


def test_temperature_sampling_varies():
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=64, temperature=1.5, top_k=50))
    prompts = np.random.default_rng(4).integers(0, cfg.vocab,
                                                (1, 8)).astype(np.int32)
    a = eng.generate(prompts, max_new_tokens=12)
    b = eng.generate(prompts, max_new_tokens=12)
    assert not np.array_equal(a, b)        # rng key advances


def test_encdec_generate():
    cfg = get_smoke("seamless-m4t-medium")
    eng = Engine(cfg, ServeConfig(max_seq=64))
    rng = np.random.default_rng(5)
    batch = {"frames": rng.standard_normal((2, 12, cfg.d_frontend)
                                           ).astype(np.float32),
             "tokens": rng.integers(0, cfg.vocab, (2, 6)).astype(np.int32)}
    import jax.numpy as jnp
    logits, caches = eng._prefill(eng.params,
                                  {k: jnp.asarray(v) for k, v in batch.items()})
    tok = eng._sample(logits)[:, None]
    for _ in range(3):
        logits, caches = eng._decode(eng.params, caches, tok)
        tok = eng._sample(logits)[:, None]
    assert np.isfinite(np.asarray(logits, np.float32)).all()
