"""Serving engine: batch generate, continuous batching, sampling."""
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.serve import Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke("granite-3-2b")
    return Engine(cfg, ServeConfig(max_seq=96, n_slots=2, temperature=0.0))


def test_generate_shapes(engine):
    prompts = np.random.default_rng(0).integers(
        0, engine.model.cfg.vocab, (3, 12)).astype(np.int32)
    out = engine.generate(prompts, max_new_tokens=6)
    assert out.shape == (3, 6)
    assert (out >= 0).all() and (out < engine.model.cfg.padded_vocab).all()


def test_generate_deterministic_greedy(engine):
    prompts = np.random.default_rng(1).integers(
        0, engine.model.cfg.vocab, (2, 10)).astype(np.int32)
    a = engine.generate(prompts, max_new_tokens=5)
    b = engine.generate(prompts, max_new_tokens=5)
    np.testing.assert_array_equal(a, b)


def test_continuous_batching_completes_queue(engine):
    rng = np.random.default_rng(2)
    reqs = [Request(tokens=rng.integers(0, engine.model.cfg.vocab,
                                        (10,)).astype(np.int32),
                    max_new_tokens=4 + i % 3) for i in range(5)]
    done = engine.serve(reqs)
    assert all(r.done for r in done)
    for i, r in enumerate(done):
        assert len(r.out) == 4 + i % 3


def test_serve_matches_generate_greedy(engine):
    prompts = np.random.default_rng(3).integers(
        0, engine.model.cfg.vocab, (1, 14)).astype(np.int32)
    g = engine.generate(prompts, max_new_tokens=6)[0]
    single = Engine(engine.model.cfg, ServeConfig(max_seq=96, n_slots=1))
    single.params = engine.params
    req = Request(tokens=prompts[0], max_new_tokens=6)
    single.serve([req])
    assert list(g) == req.out


def test_temperature_sampling_varies():
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=64, temperature=1.5, top_k=50))
    prompts = np.random.default_rng(4).integers(0, cfg.vocab,
                                                (1, 8)).astype(np.int32)
    a = eng.generate(prompts, max_new_tokens=12)
    b = eng.generate(prompts, max_new_tokens=12)
    assert not np.array_equal(a, b)        # rng key advances


# --------------------------------------------------- serving-loop bugfixes


def _first_greedy_token(engine, prompt):
    return int(engine.generate(prompt[None, :], max_new_tokens=1)[0, 0])


def test_prefill_eos_ends_request_without_decode(engine):
    """EOS sampled at prefill must finish the request immediately instead
    of burning the full max_new_tokens decode budget."""
    prompt = np.random.default_rng(6).integers(
        0, engine.model.cfg.vocab, (9,)).astype(np.int32)
    eos = _first_greedy_token(engine, prompt)
    eng = Engine(engine.model.cfg, ServeConfig(max_seq=96, n_slots=2,
                                               eos_id=eos))
    eng.params = engine.params
    decode_calls = []
    orig = eng._decode
    eng._decode = lambda *a: decode_calls.append(1) or orig(*a)
    req = Request(tokens=prompt, max_new_tokens=8)
    eng.serve([req])
    assert req.done and req.out == [eos]
    assert decode_calls == []                      # no decode steps spent
    assert req.prefill_s > 0 and req.latency_s >= req.prefill_s


def test_generate_stops_at_prefill_eos(engine):
    prompt = np.random.default_rng(7).integers(
        0, engine.model.cfg.vocab, (1, 9)).astype(np.int32)
    eos = _first_greedy_token(engine, prompt[0])
    eng = Engine(engine.model.cfg, ServeConfig(max_seq=96, eos_id=eos))
    eng.params = engine.params
    decode_calls = []
    orig = eng._decode
    eng._decode = lambda *a: decode_calls.append(1) or orig(*a)
    out = eng.generate(prompt, max_new_tokens=6)
    assert out.shape == (1, 6)                     # shape contract kept
    assert (out == eos).all()                      # EOS-filled after stop
    assert decode_calls == []


def test_serve_single_token_budget(engine):
    """max_new_tokens=1 must emit exactly one token (was: two)."""
    prompt = np.random.default_rng(8).integers(
        0, engine.model.cfg.vocab, (7,)).astype(np.int32)
    req = Request(tokens=prompt, max_new_tokens=1)
    engine.serve([req])
    assert req.done and len(req.out) == 1


def test_serve_accepts_mixed_length_prompts(engine):
    """Mixed-length prompts share one live batch (PR 5: the per-slot KV
    position index replaced the scalar that used to force a ValueError)."""
    rng = np.random.default_rng(9)
    reqs = [Request(tokens=rng.integers(0, engine.model.cfg.vocab,
                                        (ln,)).astype(np.int32),
                    max_new_tokens=4) for ln in (10, 12)]
    done = engine.serve(reqs)                      # n_slots=2: concurrent
    assert all(r.done and len(r.out) == 4 for r in done)
    for r in done:
        g = engine.generate(r.tokens[None, :], max_new_tokens=4)[0]
        assert list(g) == r.out                    # token-for-token oracle


def test_serve_mixed_lengths_single_slot(engine):
    """Sequential slot reuse across different prompt lengths — no cache
    reset between generations (per-slot index, paged pages recycled)."""
    eng = Engine(engine.model.cfg, ServeConfig(max_seq=96, n_slots=1))
    eng.params = engine.params
    rng = np.random.default_rng(10)
    reqs = [Request(tokens=rng.integers(0, engine.model.cfg.vocab,
                                        (ln,)).astype(np.int32),
                    max_new_tokens=3) for ln in (10, 14)]
    done = eng.serve(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) == 3 for r in done)


def test_serve_latency_accounting(engine):
    """latency_s is per-request (from its own slotting), not from the
    start of the whole serve call; queue_s + latency_s bounds elapsed."""
    import time as _time
    eng = Engine(engine.model.cfg, ServeConfig(max_seq=96, n_slots=1))
    eng.params = engine.params
    rng = np.random.default_rng(11)
    reqs = [Request(tokens=rng.integers(0, engine.model.cfg.vocab,
                                        (8,)).astype(np.int32),
                    max_new_tokens=3) for _ in range(3)]
    t0 = _time.time()
    eng.serve(reqs)
    elapsed = _time.time() - t0
    assert all(r.prefill_s > 0 for r in reqs)
    assert all(r.latency_s >= r.prefill_s for r in reqs)
    # FIFO single slot: later requests wait longer
    assert reqs[0].queue_s <= reqs[1].queue_s <= reqs[2].queue_s
    # the regression: a late request's latency no longer includes the
    # earlier requests' work (old code: latency_s ~= elapsed for the last)
    for r in reqs:
        assert r.queue_s + r.latency_s <= elapsed + 0.05


def test_top_k_clamped_to_vocab():
    cfg = get_smoke("granite-3-2b")
    big_k = cfg.padded_vocab + 123
    eng = Engine(cfg, ServeConfig(max_seq=64, temperature=1.0, top_k=big_k))
    prompts = np.random.default_rng(12).integers(0, cfg.vocab,
                                                 (2, 6)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=4)  # was: IndexError
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.padded_vocab).all()
    # exact-vocab k is a no-op filter, not an error
    eng2 = Engine(cfg, ServeConfig(max_seq=64, temperature=1.0,
                                   top_k=cfg.padded_vocab))
    eng2.params = eng.params
    out2 = eng2.generate(prompts, max_new_tokens=3)
    assert out2.shape == (2, 3)


# ------------------------------------- per-request fault isolation (§6.4)


def test_prefill_fault_fails_only_that_request(engine):
    """An exception during the 2nd prefill of the serve call kills that
    request alone: its slot goes to the next queued request and everyone
    else matches the oracle."""
    from repro.train.fault import FaultInjector
    eng = Engine(engine.model.cfg, ServeConfig(max_seq=96, n_slots=2),
                 params=engine.params)
    rng = np.random.default_rng(20)
    reqs = [Request(tokens=rng.integers(0, eng.model.cfg.vocab,
                                        (8,)).astype(np.int32),
                    max_new_tokens=4) for _ in range(3)]
    inj = FaultInjector(fail_at_steps=(("prefill", 1),))
    eng.serve(reqs, fault_injector=inj)
    assert inj.fired == [("prefill", 1)]
    bad = reqs[1]
    assert bad.done and bad.status == "failed" and bad.out == []
    assert "injected fault at prefill 1" in bad.error
    for r in (reqs[0], reqs[2]):
        assert r.ok_like and len(r.out) == 4
        g = eng.generate(r.tokens[None, :], max_new_tokens=4)[0]
        assert list(g) == r.out
    assert eng.paging_stats["failed"] == 1
    assert eng.paging_stats["completed"] == 2
    assert eng.paging_stats["pages_in_use"] == 0     # failed slot freed


def test_decode_fault_fails_only_that_request(engine):
    """A per-request decode fault ("committing the 3rd generated token")
    hits exactly one request — the entry fires once, so its batchmate
    passes the same step count unharmed."""
    from repro.train.fault import FaultInjector
    eng = Engine(engine.model.cfg, ServeConfig(max_seq=96, n_slots=2),
                 params=engine.params)
    rng = np.random.default_rng(21)
    reqs = [Request(tokens=rng.integers(0, eng.model.cfg.vocab,
                                        (8,)).astype(np.int32),
                    max_new_tokens=5) for _ in range(3)]
    inj = FaultInjector(fail_at_steps=(("decode", 2),))
    eng.serve(reqs, fault_injector=inj)
    assert inj.fired == [("decode", 2)]
    bad = reqs[0]                  # slot 0 reaches len(out) == 2 first
    assert bad.done and bad.status == "failed"
    assert len(bad.out) == 2       # partial output kept
    assert "injected fault at decode 2" in bad.error
    for r in (reqs[1], reqs[2]):
        assert r.ok_like and len(r.out) == 5
        g = eng.generate(r.tokens[None, :], max_new_tokens=5)[0]
        assert list(g) == r.out
    assert eng.paging_stats["failed"] == 1
    assert eng.paging_stats["pages_in_use"] == 0


def test_strict_propagates_injected_fault(engine):
    """strict=True restores fail-stop: the injected fault raises out of
    serve() instead of being contained."""
    from repro.train.fault import FaultInjector
    eng = Engine(engine.model.cfg,
                 ServeConfig(max_seq=96, n_slots=2, strict=True),
                 params=engine.params,
                 fault_injector=FaultInjector(fail_at_steps=(("prefill",
                                                             0),)))
    rng = np.random.default_rng(22)
    req = Request(tokens=rng.integers(0, eng.model.cfg.vocab,
                                      (8,)).astype(np.int32),
                  max_new_tokens=3)
    with pytest.raises(RuntimeError, match="injected fault"):
        eng.serve([req])


def test_encdec_generate():
    cfg = get_smoke("seamless-m4t-medium")
    eng = Engine(cfg, ServeConfig(max_seq=64))
    rng = np.random.default_rng(5)
    batch = {"frames": rng.standard_normal((2, 12, cfg.d_frontend)
                                           ).astype(np.float32),
             "tokens": rng.integers(0, cfg.vocab, (2, 6)).astype(np.int32)}
    import jax.numpy as jnp
    logits, caches = eng._prefill(eng.params,
                                  {k: jnp.asarray(v) for k, v in batch.items()})
    tok = eng._sample(logits)[:, None]
    for _ in range(3):
        logits, caches = eng._decode(eng.params, caches, tok)
        tok = eng._sample(logits)[:, None]
    assert np.isfinite(np.asarray(logits, np.float32)).all()
