"""Fused on-device decode loop (DESIGN.md §7.1, serve/device_loop.py).

The serving core dispatches decode in chunks: one jitted ``lax.while_loop``
runs up to ``decode_chunk`` decode+sample+mask steps on device (KV caches
donated, PRNG key threaded through the carry) and returns a ``(k, n_slots)``
token block the host commits in a single pass.  These tests pin the cadence
contract:

- token streams are IDENTICAL to the stepwise ``generate()`` oracle for any
  chunk size, both KV layouts — chunking is an execution detail, never a
  semantics change;
- per-slot EOS/budget masks make finished slots decode harmlessly until the
  host commit truncates them, and the early-exit predicate stops the loop
  once every slot is done;
- host-authority events (deadline sweeps, recompute preemption, admissions)
  land at chunk boundaries without changing any committed token;
- replica faults split the chunk so the fault fires at its exact stepwise
  decode-step index, with the pre-fault rows already committed (a partially
  committed chunk migrates);
- the watchdog observes per-step-normalized dt, so an 8-step dispatch is
  not 8x "slower" than a 1-step one.

Determinism note: greedy streams everywhere (temperature=0 consumes no PRNG
key, so cadence cannot perturb sampling), fake clocks for anything timed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.serve import Engine, Request, Router, RouterConfig, ServeConfig
from repro.serve import device_loop
from repro.train.fault import FaultConfig, FaultInjector

S_MAX = 64
PS = 4


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _tick_decode(eng, clock, dt=1.0):
    orig = eng._decode
    orig_fused = eng._fused_decode

    def wrapped(*a):
        clock.advance(dt)
        return orig(*a)

    def wrapped_fused(*a):
        out = orig_fused(*a)
        clock.advance(dt * int(out[1]))
        return out

    eng._decode = wrapped
    eng._fused_decode = wrapped_fused


def _oracle(eng, req):
    return list(eng.generate(req.tokens[None, :],
                             max_new_tokens=req.max_new_tokens)[0])


# ------------------------------------------------- cadence-invariance oracle


@pytest.mark.parametrize("layout", ["paged", "dense"])
@pytest.mark.parametrize("chunk", [1, 2, 7, 32])
def test_fused_serve_matches_oracle_any_chunk(chunk, layout):
    """Token-for-token generate() equality across chunk sizes that
    undershoot (1, 2), straddle (7) and overshoot (32) the 5-token budgets
    — mixed-length prompts in one live batch, both KV layouts.  chunk=1
    degenerates to the stepwise cadence; chunk=32 proves the early-exit
    predicate and per-slot budget masks (no slot may run past remaining)."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, kv_layout=layout,
                                  page_size=PS, decode_chunk=chunk))
    rng = np.random.default_rng(5)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ln,)).astype(np.int32),
                    max_new_tokens=5) for ln in (10, 13, 7)]
    eng.serve(reqs)
    assert all(r.ok_like for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r), f"chunk={chunk} drifted"
    st = eng.paging_stats
    assert st["decode_dispatches"] > 0
    if chunk == 1:
        assert st["decode_dispatches"] == st["decode_steps"]
    else:
        # amortization is real: strictly fewer dispatches than steps
        assert st["decode_dispatches"] < st["decode_steps"]


def test_fused_dispatch_count_amortized():
    """The acceptance ratio at bench scale, in miniature: a uniform
    2-slot wave of 8-token budgets under chunk=8 is ONE dispatch per
    wave — >=4x fewer dispatches than tokens."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                                  decode_chunk=8))
    rng = np.random.default_rng(8)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (9,)).astype(np.int32),
                    max_new_tokens=8) for _ in range(2)]
    eng.serve(reqs)
    st = eng.paging_stats
    assert all(r.ok_like and len(r.out) == 8 for r in reqs)
    # prefill emits token 1; the remaining 7 decode steps fuse into 1 chunk
    assert st["decode_dispatches"] == 1
    assert st["decode_steps"] / st["decode_dispatches"] >= 4.0


# ----------------------------------------------------------- EOS mid-chunk


def test_eos_mid_chunk_truncates_stream_batchmate_unaffected():
    """EOS landing inside a chunk: the device keeps decoding the finished
    slot harmlessly (budget mask holds it), the host commit truncates the
    stream at the EOS token, and the batchmate's stream is untouched."""
    cfg = get_smoke("granite-3-2b")
    probe = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS))
    found = None
    for seed in range(16, 48):        # greedy smoke streams often repeat a
        rng = np.random.default_rng(seed)          # token — scan for a seed
        pa = rng.integers(0, cfg.vocab, (9,)).astype(np.int32)   # whose EOS
        pb = rng.integers(0, cfg.vocab, (11,)).astype(np.int32)  # is clean
        ga = _oracle(probe, Request(tokens=pa, max_new_tokens=8))
        gb = _oracle(probe, Request(tokens=pb, max_new_tokens=8))
        # first mid-chunk position whose token is NEW to both streams' heads
        for idx in range(2, 7):
            eos = ga[idx]
            if eos not in ga[:idx] and eos not in gb:
                found = (pa, pb, ga, gb, idx, int(eos))
                break
        if found:
            break
    assert found, "no seed produced a clean mid-chunk EOS geometry"
    pa, pb, ga, gb, idx, eos = found
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                                  decode_chunk=8, eos_id=eos),
                 params=probe.params)
    ra = Request(tokens=pa, max_new_tokens=8)
    rb = Request(tokens=pb, max_new_tokens=8)
    eng.serve([ra, rb])
    assert ra.ok_like and ra.out == ga[:idx + 1]  # truncated AT the EOS
    assert rb.ok_like and rb.out == gb            # batchmate unaffected
    assert eng.paging_stats["pages_in_use"] == 0  # early finisher freed


# ------------------------------------------- host events at chunk boundaries


def test_deadline_expiry_at_chunk_boundary():
    """The deadline sweep runs once per chunk: a request whose deadline
    lapses mid-chunk is timed out at the NEXT boundary with its partial
    chunk committed, while its batchmate completes against the oracle."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                                  decode_chunk=4))
    clock = FakeClock()
    eng.clock = clock
    _tick_decode(eng, clock)                      # 1s per decode step
    rng = np.random.default_rng(13)
    mk = lambda mx, dl: Request(
        tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
        max_new_tokens=mx, deadline_s=dl)
    slow = mk(12, 2.5)            # lapses inside the first 4-step chunk
    ok = mk(6, None)
    eng.serve([slow, ok])
    assert slow.done and slow.status == "timed_out"
    assert "deadline" in slow.error
    # the whole in-flight chunk commits before the boundary sweep: prefill
    # token + one full 4-step chunk (t=4 > 2.5), never a mid-chunk cut
    assert len(slow.out) == 5
    assert ok.ok_like and ok.out == _oracle(eng, ok)
    st = eng.paging_stats
    assert st["timed_out"] == 1 and st["completed"] == 1
    assert st["pages_in_use"] == 0


def test_preemption_at_chunk_boundary_matches_oracle():
    """The §6.4 overload geometry under chunk=4: recompute preemption is
    decided at chunk boundaries (_ensure_pages horizon grows to the chunk,
    capped by free pages), every stream still completes token-identical,
    and the pool bound holds."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=3, page_size=8,
                                  n_pages=5, decode_chunk=4))
    rng = np.random.default_rng(7)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=5) for _ in range(6)]
    eng.serve(reqs)
    assert all(r.ok_like and len(r.out) == 5 for r in reqs)
    for r in reqs:
        assert r.out == _oracle(eng, r), "preempted stream drifted"
    st = eng.paging_stats
    assert st["preemptions"] > 0 and st["recompute_tokens"] > 0
    assert st["page_high_water"] <= 4
    assert st["pages_in_use"] == 0 and st["reserved_pages"] == 0


# -------------------------------------------------- replica fault mid-chunk


def test_replica_kill_mid_chunk_migrates_partial_commit():
    """A ("replica", 2) fault under chunk=8: the session splits the chunk
    so the fault fires at exactly decode step 2 — the 2 pre-fault steps
    are already committed when the replica dies, and the migrated requests
    (re-prefilled prompt + partial prefix on a survivor) finish
    token-identical to the oracle."""
    clock = FakeClock()
    fc = FaultConfig(max_restarts=3, backoff_s=0.5)
    cfg = get_smoke("granite-3-2b")
    scfg = ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                       decode_chunk=8)
    first = Engine(cfg, scfg, fault_cfg=fc)
    engines = [first] + [Engine(cfg, scfg, params=first.params,
                                fault_cfg=fc) for _ in range(2)]
    engines[1].fault_injector = FaultInjector(
        fail_at_steps=(("replica", 2),))
    for e in engines:
        e.clock = clock
        _tick_decode(e, clock)
    router = Router(engines, cfg=RouterConfig(n_replicas=3), fault_cfg=fc,
                    clock=clock, sleep=clock.advance)
    rng = np.random.default_rng(5)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=6) for _ in range(8)]
    router.serve(reqs)
    assert all(r.ok_like for r in reqs), \
        [(r.status, r.error) for r in reqs if not r.ok_like]
    for r in reqs:
        assert r.out == _oracle(engines[0], r), "migrated stream drifted"
    st = router.stats()
    assert st["replica_faults"] == 1 and st["migrations"] > 0
    assert st["failed"] == 0 and st["completed"] == 8
    # the chunk was split at the armed step: the dead session retired with
    # exactly 2 decode steps committed (not 0 — partial commit migrated;
    # not 8 — the fault did not wait for the chunk boundary)
    dead = router.replicas[1].retired_stats[0]
    assert dead["decode_steps"] == 2
    migrated = [r for r in reqs if r.retries > 0]
    assert migrated and any(len(r.out) for r in migrated)


# ------------------------------------------------- watchdog normalization


def test_watchdog_normalizes_dt_per_step_in_chunk():
    """A fused dispatch reports dt / steps_ran to the watchdog: warming
    the EWMA with chunk-of-1 dispatches (1s per step) and then running an
    8-step chunk (8s total, still 1s per step) must flag NO straggler —
    pre-normalization it looked 8x slow and always fired."""
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                                  decode_chunk=8),
                 fault_cfg=FaultConfig(straggler_factor=2.0))
    clock = FakeClock()
    eng.clock = clock
    _tick_decode(eng, clock)                      # 1s per decode STEP
    rng = np.random.default_rng(14)
    session = eng.start_session()
    session.submit(Request(tokens=rng.integers(0, cfg.vocab,
                                               (6,)).astype(np.int32),
                           max_new_tokens=13))
    for _ in range(5):                            # EWMA warmup, 1 step each
        session.step(1)
    session.step(8)                               # one fused 8-step dispatch
    session.drain()
    snap = session.stats_snapshot()
    assert snap["straggler_decode_steps"] == 0
    assert snap["decode_dispatches"] >= 6


# ------------------------------------------------------- sampling kernel


def test_sample_tokens_greedy_and_top_k():
    """The shared sampler: temperature<=0 is pure argmax (no key consumed,
    None accepted); top-k masks everything below the kth logit so sampled
    ids always come from the top-k set; top_k=0 disables the filter."""
    logits = jnp.asarray(
        np.random.default_rng(0).standard_normal((3, 1, 17)),
        jnp.float32)
    greedy = device_loop.sample_tokens(logits, None, 0.0, 0)
    np.testing.assert_array_equal(
        np.asarray(greedy), np.argmax(np.asarray(logits)[:, -1], axis=-1))
    top = set(np.argsort(np.asarray(logits)[0, -1])[-4:].tolist())
    for seed in range(6):
        t = device_loop.sample_tokens(logits, jax.random.PRNGKey(seed),
                                      1.3, 4)
        assert int(t[0]) in top, "sampled outside the top-k set"
    full = device_loop.sample_tokens(logits, jax.random.PRNGKey(0), 1.0, 0)
    assert full.shape == (3,) and full.dtype == jnp.int32


def test_launch_decode_step_is_device_loop_factory():
    """launch/steps.py delegates its decode-step builder to the serving
    core's single factory — one decode path, no drift between the
    launcher and the fused loop."""
    from repro.launch import steps as launch_steps
    cfg = get_smoke("granite-3-2b")
    from repro.models import LanguageModel
    model = LanguageModel(cfg)
    a = launch_steps.make_decode_step(model)
    b = device_loop.make_decode_step(model)
    assert a.__code__ is b.__code__ or a.__qualname__ == b.__qualname__
