"""Row-sharded multi-device RgCSR SpMV/SpMM (DESIGN.md §11/§12).

Two layers of coverage:

* in-process tests validate the host-side machinery on the single real CPU
  device — ShardedRgCSR construction, stacked-plan invariants, the §12
  sparse-exchange schedule (send_idx/edge_counts reconstruct x[remote]
  exactly; per-device exchange volume == plan-time remote count), its edge
  cases (empty remote set, all-remote shard, single-device degrade),
  per-shard-config stacking at the gcd kernel cps, and plan-cache keying
  on (x_mode, per-shard configs, shard count — the resized-mesh guard);
* subprocess tests run the actual ``shard_map`` execution path on 8 fake
  host devices (``--xla_force_host_platform_device_count=8`` must live only
  in the child, mirroring tests/test_distributed.py) and assert oracle
  equivalence for ragged, empty-shard, powerlaw and spill-bearing matrices
  × {replicated, split} × uniform/per-shard configs, the ~1/D per-shard
  stored-slots/grid-steps shrink, and the exchange-volume bound on the
  live all_to_all path.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import from_dense
from repro.core.formats import ShardedRgCSR
from repro.core.spmv import spmv
from repro.kernels import ops as kops
from repro.kernels.rgcsr_spmv import rgcsr_spmv_pallas

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rand(seed, n, m, density):
    rng = np.random.default_rng(seed)
    a = (rng.uniform(size=(n, m)) < density).astype(np.float32)
    a *= rng.uniform(0.5, 1.5, size=(n, m)).astype(np.float32)
    return a


# ------------------------------------------------------------- construction


def test_sharded_rgcsr_construction_covers_rows():
    a = _rand(0, 300, 280, 0.05)                   # 300/8 → ragged last shard
    sm = ShardedRgCSR.from_dense(a, n_shards=8)
    assert sm.n_shards == 8 and sm.rows_per_shard == 38
    assert sm.nnz == int((a != 0).sum())
    assert all(s.shape == (38, 280) for s in sm.shards)
    np.testing.assert_array_equal(sm.to_dense(), a)
    lo, hi = sm.shard_rows(7)
    assert (lo, hi) == (266, 300)                  # unpadded true range


def test_sharded_rgcsr_empty_trailing_shard():
    a = _rand(1, 20, 64, 0.2)
    sm = ShardedRgCSR.from_dense(a, n_shards=8)    # rps=3: shard 7 is empty
    assert sm.rows_per_shard == 3
    lo, hi = sm.shard_rows(7)
    assert hi <= lo                                # owns no real rows
    assert sm.shards[7].nnz == 0
    np.testing.assert_array_equal(sm.to_dense(), a)


def test_sharded_rgcsr_rejects_bad_shards():
    with pytest.raises(ValueError):
        ShardedRgCSR.from_dense(_rand(2, 16, 16, 0.2), n_shards=0)


# ------------------------------------------------------------ plan stacking


def test_sharded_plan_uniform_stacking():
    a = _rand(3, 300, 280, 0.05)
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, chunks_per_step=2)
    d, s_pad, g = plan.values3d.shape
    assert (d, g) == (4, 128)
    assert s_pad == plan.num_steps_max * 2 * 8     # S_pad = T_max·R
    assert plan.step_group2d.shape == (4, plan.num_steps_max)
    assert len(plan.shard_stored_slots) == 4
    # true per-shard slots never exceed the stacked (padded) slot count
    assert max(plan.shard_stored_slots) <= s_pad
    # per-shard padding steps carry no accumulator-init flags
    sf = np.asarray(plan.step_first2d)
    for i, t in enumerate(plan.shard_num_steps):
        assert (sf[i, t:] == 0).all()


def test_sharded_plan_split_remote_cols_disjoint_from_local():
    a = _rand(4, 256, 256, 0.04)
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, x_mode="split")
    assert plan.cols_per_shard == 64
    rc = np.asarray(plan.remote_cols)
    for d in range(4):
        lo, hi = d * 64, (d + 1) * 64
        real = rc[d, : plan.shard_remote_cols[d]]
        assert ((real < lo) | (real >= hi)).all()  # remote = not owned
        assert len(np.unique(real)) == len(real)
    # grouped storage is local-only: the kernel's x working set is exactly
    # this device's slice — remote entries live in the rem_* exchange tail
    assert int(np.asarray(plan.columns3d).max()) < plan.cols_per_shard


def test_exchange_schedule_matches_remote_sets():
    """The tentpole bound: the plan-time send schedule moves exactly each
    shard's remote column set — per-device exchange volume == remote count
    — and the schedule's (src, dst) edges reconstruct x[remote] verbatim."""
    a = _rand(11, 256, 256, 0.04)
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, x_mode="split")
    assert plan.has_exchange
    ec = np.asarray(plan.edge_counts)
    # received entries per dst == that shard's plan-time remote count
    assert plan.shard_exchange_recv_cols == plan.shard_remote_cols
    assert tuple(ec.sum(axis=0)) == plan.shard_remote_cols
    assert int(ec.max()) <= plan.e_max
    # the schedule delivers exactly x[remote] to every dst: edge (s → d)
    # holds d's remote columns owned by s in sorted order, and send_idx
    # addresses them inside s's own slice
    cstride = plan.cols_per_shard
    x = np.random.default_rng(12).standard_normal(
        plan.n_shards * cstride).astype(np.float32)
    sidx = np.asarray(plan.send_idx)
    for d in range(plan.n_shards):
        remote = np.asarray(plan.remote_cols)[d, : plan.shard_remote_cols[d]]
        for s in range(plan.n_shards):
            edge = remote[(remote >= s * cstride)
                          & (remote < (s + 1) * cstride)]
            local_idx = sidx[s, d, : len(edge)]
            assert (local_idx < cstride).all()
            np.testing.assert_array_equal(
                x[s * cstride: (s + 1) * cstride][local_idx], x[edge])


def _emulate_shard(plan, d, x):
    """Run one device's slice of the stacked plan directly (no shard_map):
    local kernel over the owned x slice, plus the emulated sparse-exchange
    remote tail in split mode."""
    cstride = plan.cols_per_shard
    if plan.x_mode == "split":
        xw = plan.n_shards * cstride
        x_glob = np.zeros(xw, np.float32)
        x_glob[: plan.n_cols] = x
        x_use = x_glob[d * cstride: (d + 1) * cstride]
    else:
        x_use = x
    n_pad = -(-len(x_use) // 128) * 128
    x_pad = jnp.zeros((1, n_pad), jnp.float32).at[0, : len(x_use)].set(
        jnp.asarray(x_use))
    y = rgcsr_spmv_pallas(
        plan.step_group2d[d], plan.step_first2d[d], plan.values3d[d],
        plan.columns3d[d], x_pad, n_groups=plan.n_groups,
        group_size=plan.group_size, chunks_per_step=plan.chunks_per_step,
        interpret=True)
    y = np.asarray(y).reshape(-1)[: plan.rows_per_shard].copy()
    if plan.x_mode == "split" and plan.has_exchange:
        # emulate the all_to_all: recv[s·e_max + e] = x_src[send_idx[s, d, e]]
        recv = np.zeros(plan.n_shards * plan.e_max, np.float32)
        sidx = np.asarray(plan.send_idx)
        for s in range(plan.n_shards):
            recv[s * plan.e_max: (s + 1) * plan.e_max] = \
                x_glob[s * cstride: (s + 1) * cstride][sidx[s, d]]
        rv = np.asarray(plan.rem_values)[d]
        rr = np.asarray(plan.rem_rows)[d]
        rx = np.asarray(plan.rem_xidx)[d]
        np.add.at(y, rr, rv * recv[rx])
    return y


@pytest.mark.parametrize("x_mode", ["replicated", "split"])
def test_sharded_plan_per_device_slices_match_blocks(x_mode):
    """Each device's stacked slice × its compact x equals the dense row
    block — the remap/local-remote split is exercised without any mesh."""
    a = _rand(5, 200, 190, 0.06)
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, chunks_per_step=2, x_mode=x_mode)
    x = np.random.default_rng(6).standard_normal(190).astype(np.float32)
    for d in range(4):
        lo, hi = sm.shard_rows(d)
        y_d = _emulate_shard(plan, d, x)
        np.testing.assert_allclose(y_d[: hi - lo], a[lo:hi] @ x,
                                   rtol=1e-4, atol=1e-4)


def test_split_empty_remote_set_skips_exchange():
    """Block-diagonal matrix: every shard references only its own columns,
    so the plan carries no exchange at all and still matches the oracle."""
    a = np.zeros((256, 256), np.float32)
    for d in range(4):
        a[d * 64: (d + 1) * 64, d * 64: (d + 1) * 64] = \
            _rand(20 + d, 64, 64, 0.2)
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, x_mode="split")
    assert plan.e_max == 0 and not plan.has_exchange
    assert plan.send_idx is None and plan.rem_values is None
    assert plan.shard_remote_cols == (0, 0, 0, 0)
    assert plan.shard_exchange_bytes == (0, 0, 0, 0)
    x = np.random.default_rng(21).standard_normal(256).astype(np.float32)
    for d in range(4):
        np.testing.assert_allclose(
            _emulate_shard(plan, d, x), a[d * 64: (d + 1) * 64] @ x,
            rtol=1e-4, atol=1e-4)


def test_split_all_remote_shard():
    """A shard whose every referenced column is owned elsewhere: its local
    grouped plan is empty and the remote tail carries the whole row block."""
    a = _rand(22, 128, 128, 0.06)
    a[:32, :32] = 0.0                  # shard 0 owns cols [0, 32): zero them
    a[:32, 100] = 1.5                  # …but keep remote references
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, x_mode="split")
    assert plan.shard_remote_cols[0] > 0
    assert np.asarray(plan.values3d)[0, :, :].max() == 0  # no local entries
    x = np.random.default_rng(23).standard_normal(128).astype(np.float32)
    for d in range(4):
        lo, hi = sm.shard_rows(d)
        np.testing.assert_allclose(_emulate_shard(plan, d, x),
                                   a[lo:hi] @ x, rtol=1e-4, atol=1e-4)


def test_split_single_device_degrades_to_local_only():
    """n_shards=1: the shard owns every column, split mode has no exchange,
    and the real shard_map path runs on the one physical CPU device."""
    import jax
    a = _rand(24, 128, 96, 0.08)
    sm = ShardedRgCSR.from_dense(a, n_shards=1)
    plan = kops.get_sharded_plan(sm, x_mode="split")
    assert plan.n_shards == 1 and not plan.has_exchange
    assert plan.shard_remote_cols == (0,)
    mesh = jax.make_mesh((1,), ("model",))
    x = np.random.default_rng(25).standard_normal(96).astype(np.float32)
    y = np.asarray(spmv(sm, jnp.asarray(x), mesh=mesh, mesh_axis="model",
                        x_mode="split"))
    np.testing.assert_allclose(y, a @ x, rtol=1e-4, atol=1e-4)


def test_per_shard_configs_stack_at_gcd_cps():
    """Mixed per-shard winners: each shard keeps its own padding
    granularity/ordering/spill, step tables expand to the gcd kernel cps,
    and every device slice still reproduces its dense row block."""
    a = _rand(26, 200, 190, 0.06)
    a[7, :150] = 1.0                               # heavy row in shard 0
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    cfgs = [(1, "adaptive", 8), (4, "block", 0), (2, "block", 0),
            (2, "adaptive", 0)]
    plan = kops.make_sharded_plan(sm, x_mode="split", shard_configs=cfgs)
    assert plan.chunks_per_step == 1               # gcd of {1, 4, 2, 2}
    assert plan.shard_configs == ((1, "adaptive", 8), (4, "block", 0),
                                  (2, "block", 0), (2, "adaptive", 0))
    assert plan.ordering == "adaptive"             # any shard adaptive
    assert sum(plan.shard_spilled_elements) > 0    # shard 0 spilled
    # emulation needs the adaptive gather; go through the real shard_map
    # path on a 1-D mesh only in the subprocess tests — here verify the
    # block shards' slices directly and the table expansion invariants
    sf = np.asarray(plan.step_first2d)
    sg = np.asarray(plan.step_group2d)
    for d, (cps_d, _, _) in enumerate(cfgs):
        t_d = plan.shard_num_steps[d]
        f = cps_d // plan.chunks_per_step
        # init flags only ever sit on coarse-step boundaries, so the
        # expanded fine steps of one coarse step accumulate consecutively
        assert all(j % f == 0 for j in np.flatnonzero(sf[d, :t_d]))
        assert (np.diff(sg[d, :t_d]) >= 0).all()   # groups stay ordered
        assert (sf[d, t_d:] == 0).all()            # padding steps never init


def test_sharded_plan_cache_keys_on_x_mode_config_and_shards():
    sm = ShardedRgCSR.from_dense(_rand(7, 128, 128, 0.05), n_shards=4)
    p1 = kops.get_sharded_plan(sm)
    p2 = kops.get_sharded_plan(sm, x_mode="split")
    p3 = kops.get_sharded_plan(sm, ordering="adaptive", spill_threshold=8)
    per_shard = [(2, "block", 0), (1, "adaptive", 8), (1, "block", 0),
                 (2, "adaptive", 0)]
    p4 = kops.get_sharded_plan(sm, x_mode="split", shard_configs=per_shard)
    assert p1 is not p2 and p2 is not p3 and p3 is not p4
    assert kops.get_sharded_plan(sm) is p1                 # repeat: hit
    assert kops.get_sharded_plan(sm, x_mode="split") is p2
    assert kops.get_sharded_plan(sm, x_mode="split",
                                 shard_configs=per_shard) is p4
    # a uniform shard_configs list is the same key as the broadcast args
    assert kops.get_sharded_plan(
        sm, shard_configs=[(1, "block", 0)] * 4) is p1
    stats = kops.sharded_plan_cache_stats()
    assert stats["hits"] >= 3 and stats["misses"] >= 4


def test_harmonize_shard_winners_respects_bottleneck():
    """The stacked pick is structural-first: grid steps at the candidate
    kernel cps (a deterministic plan property) outrank measured µs, so a
    light shard's marginal cps=1 µs win cannot drag the kernel cps down,
    and host jitter between near-tie candidates cannot flip the heavy
    shard's spill win between runs."""
    from repro.kernels.autotune import (TuneConfig, TuneResult,
                                        harmonize_shard_winners)

    def res(rows):
        timings = tuple((cfg, us) for cfg, us, _ in rows)
        return TuneResult(config=min(timings, key=lambda t: t[1])[0],
                          us_per_call=min(us for _, us in timings),
                          timings=timings, signature=(),
                          plan_stats=tuple(s for _, _, s in rows))

    # rows: (config, measured µs, (stored_slots, stored_elements, spilled))
    light = res([(TuneConfig(1, 128, 128, "block", 0), 100.0,
                  (16, 2048, 0)),
                 (TuneConfig(4, 128, 128, "block", 0), 101.0,
                  (32, 4096, 0)),
                 (TuneConfig(8, 128, 128, "block", 0), 150.0,
                  (64, 8192, 0))])
    heavy = res([(TuneConfig(1, 128, 128, "block", 0), 900.0,
                  (96, 12288, 0)),
                 # µs noise puts block cps4 marginally AHEAD of the spill
                 # config; the spill config's smaller grid must still win
                 (TuneConfig(4, 128, 128, "block", 0), 310.0,
                  (96, 12288, 0)),
                 (TuneConfig(4, 128, 128, "adaptive", 8), 315.0,
                  (32, 4500, 400))])
    picks = harmonize_shard_winners([light, heavy, light])
    # heavy keeps the structurally smaller spill plan despite the µs tie
    assert picks[1] == TuneConfig(4, 128, 128, "adaptive", 8)
    assert all(p.chunks_per_step >= 4 for p in picks)
    # all-identical shards degenerate to the plain independent winners
    same = harmonize_shard_winners([light, light])
    assert all(p.ordering == "block" for p in same)
    # deterministic: re-running with the same tables gives the same picks
    assert harmonize_shard_winners([light, heavy, light]) == picks


def test_engine_warm_sharded_replaces_rewarm_keeps_distinct(
        deterministic_autotune):
    """The engine's warm-plan retention is keyed on exact matrix content:
    re-warming the same matrix replaces its entry (no unbounded growth),
    while two distinct matrices sharing a coarse tuner-signature bucket
    both stay warmed."""
    import jax
    from repro.configs import get_smoke
    from repro.serve import Engine, ServeConfig
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = Engine(get_smoke("granite-3-2b"), ServeConfig(max_seq=32))
    a = _rand(40, 256, 256, 0.05)
    b = _rand(41, 256, 256, 0.05)      # same log2 signature bucket as a
    eng.warm_spmv_plans([a, b], repeats=1, mesh=mesh, x_mode="split")
    assert len(eng._warm_sharded) == 2
    eng.warm_spmv_plans([a], repeats=1, mesh=mesh, x_mode="split")
    assert len(eng._warm_sharded) == 2
    assert eng.sharded_spmv_plans_warmed == 3


def test_sharded_exec_memo_evicts_on_plan_gc():
    """The cached shard_map executable must not pin its plan: the closure
    captures hoisted scalars only, so when the plan dies its exec entries
    are evicted by the finalizer instead of lingering until LRU turnover
    (each would otherwise hold the full stacked device arrays)."""
    import gc
    import jax
    sm = ShardedRgCSR.from_dense(_rand(30, 64, 64, 0.1), n_shards=1)
    plan = kops.make_sharded_plan(sm, x_mode="split")
    mesh = jax.make_mesh((1,), ("model",))
    kops._sharded_exec(plan, "spmv", mesh, "model", True)
    pid = id(plan)
    with kops._SHARDED_LOCK:
        assert any(k[0] == pid for k in kops._SHARDED_EXEC)
    del plan
    gc.collect()
    with kops._SHARDED_LOCK:
        assert not any(k[0] == pid for k in kops._SHARDED_EXEC)


def test_sharded_plan_cache_keys_on_shard_count():
    """Resized-mesh safety: plans for the same dense matrix at different
    shard counts are distinct entries — a re-warm on a resized mesh can
    never be answered with the stale stacked plan."""
    a = _rand(9, 128, 128, 0.05)
    sm4 = ShardedRgCSR.from_dense(a, n_shards=4)
    sm2 = ShardedRgCSR.from_dense(a, n_shards=2)
    p4 = kops.get_sharded_plan(sm4, x_mode="split")
    p2 = kops.get_sharded_plan(sm2, x_mode="split")
    assert p4 is not p2
    assert p4.n_shards == 4 and p2.n_shards == 2
    # the key carries the shard count explicitly, not just matrix identity
    with kops._SHARDED_LOCK:
        keys = [k for k in kops._SHARDED_PLANS
                if k[0] in (id(sm4), id(sm2))]
    assert all(len(k) == 4 and k[1] in (2, 4) for k in keys)


def test_sharded_spmv_requires_mesh():
    sm = ShardedRgCSR.from_dense(_rand(8, 64, 64, 0.1), n_shards=2)
    with pytest.raises(ValueError, match="mesh"):
        spmv(sm, jnp.zeros(64))


def test_partitioner_resolves_sparse_rows_axis():
    import jax
    from repro.sharding import Partitioner
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for kind in ("train", "decode"):
        part = Partitioner(mesh, kind)
        assert part.spmv_shard_axis() == "model"
        assert part.spmv_shard_count() == 1


# ---------------------------------------------- shard_map on 8 fake devices


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0 and "OK" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_sharded_spmv_matches_oracle_on_8_devices():
    """The acceptance sweep: ragged, empty-shard, powerlaw and
    spill-bearing matrices × {replicated, split} × {block, adaptive},
    SpMV and SpMM, all equal to the jnp oracle up to fp reassociation —
    plus the ~1/D per-shard stored-slots / grid-steps shrink."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np, jax.numpy as jnp
        from repro.core.formats import RgCSR, ShardedRgCSR
        from repro.core.spmv import spmv, spmm
        from repro.core.suite import generate
        from repro.kernels import ops as kops

        mesh = jax.make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)

        def check(a, **kw):
            sm = ShardedRgCSR.from_dense(a, n_shards=8)
            x = rng.standard_normal(a.shape[1]).astype(np.float32)
            y = np.asarray(spmv(sm, jnp.asarray(x), mesh=mesh, **kw))
            np.testing.assert_allclose(y, a @ x, rtol=1e-4, atol=1e-4)

        def rand(seed, n, m, density):
            r = np.random.default_rng(seed)
            a = (r.uniform(size=(n, m)) < density).astype(np.float32)
            return a * r.uniform(0.5, 1.5, (n, m)).astype(np.float32)

        ragged = rand(1, 300, 280, 0.05)           # 300 = 7·38 + 34
        tiny = rand(2, 20, 64, 0.2)                # shard 7 empty
        power = generate("powerlaw", 256, seed=0)
        skew = rand(3, 256, 240, 0.02)
        for r in np.random.default_rng(4).choice(256, 3, replace=False):
            skew[r, :200] = 1.0                    # spill-bearing rows
        for a in (ragged, tiny, power, skew):
            for x_mode in ("replicated", "split"):
                check(a, x_mode=x_mode)
                check(a, x_mode=x_mode, ordering="adaptive")
        # split mode groups only each shard's LOCAL entries (the remote
        # ones ride the exchange tail), so per-row local lengths deflate
        # by ~1/D — the spill threshold must sit below them to fire
        check(skew, ordering="adaptive", spill_threshold=8, x_mode="split")
        sm = ShardedRgCSR.from_dense(skew, n_shards=8)
        plan = kops.get_sharded_plan(sm, ordering="adaptive",
                                     spill_threshold=8, x_mode="split")
        assert sum(plan.shard_spilled_elements) > 0

        # SpMM on the same sharded plans
        X = rng.standard_normal((280, 9)).astype(np.float32)
        smr = ShardedRgCSR.from_dense(ragged, n_shards=8)
        for x_mode in ("replicated", "split"):
            Y = np.asarray(spmm(smr, jnp.asarray(X), mesh=mesh,
                                mesh_axis="model", x_mode=x_mode,
                                ordering="adaptive"))
            np.testing.assert_allclose(Y, ragged @ X, rtol=1e-4, atol=1e-4)

        # ~1/D: per-shard stored slots and grid steps vs the single-device
        # plan of the same matrix/config (uniform profile: no padding floor)
        big = rand(5, 1024, 512, 0.05)
        single = kops.make_plan(RgCSR.from_dense(big), chunks_per_step=2)
        sm8 = ShardedRgCSR.from_dense(big, n_shards=8)
        p8 = kops.get_sharded_plan(sm8, chunks_per_step=2)
        assert max(p8.shard_stored_slots) <= single.stored_slots / 8 * 1.5
        assert max(p8.shard_num_steps) <= single.num_steps / 8 * 1.5
        x = rng.standard_normal(512).astype(np.float32)
        y = np.asarray(kops.sharded_rgcsr_spmv(p8, jnp.asarray(x),
                                               mesh=mesh, axis="model"))
        np.testing.assert_allclose(y, big @ x, rtol=1e-4, atol=1e-4)

        # §12 sparse collective: per-device exchange volume equals the
        # shard's plan-time remote column count (the acceptance bound),
        # and is far below the all_gather's n_cols-per-device traffic
        psplit = kops.get_sharded_plan(sm8, chunks_per_step=2,
                                       x_mode="split")
        assert psplit.shard_exchange_recv_cols == psplit.shard_remote_cols
        assert max(psplit.shard_exchange_recv_cols) < psplit.n_cols
        y2 = np.asarray(kops.sharded_rgcsr_spmv(psplit, jnp.asarray(x),
                                                mesh=mesh, axis="model"))
        np.testing.assert_allclose(y2, big @ x, rtol=1e-4, atol=1e-4)

        # per-shard winners that differ across shards: split == replicated
        # == oracle under a mixed (cps, ordering, spill) assignment
        cfgs = [(4, "block", 0) if d % 2 else (1, "adaptive", 8)
                for d in range(8)]
        for xm in ("replicated", "split"):
            ym = np.asarray(spmv(sm8, jnp.asarray(x), mesh=mesh,
                                 x_mode=xm, shard_configs=cfgs))
            np.testing.assert_allclose(ym, big @ x, rtol=1e-4, atol=1e-4)
        print("OK")
    """)


def test_sharded_engine_warmup_and_partitioner_routing_on_8_devices():
    """Engine.warm_spmv_plans with a mesh: autotuned winner config applied
    per shard, sharded plan staged + stats recorded; core.spmv resolves the
    mesh axis through the partitioner's sparse_rows rule."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.core.formats import ShardedRgCSR
        from repro.core.spmv import spmv
        from repro.core.suite import generate
        from repro.serve import Engine, ServeConfig
        from repro.sharding import Partitioner

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        part = Partitioner(mesh, "decode")
        assert part.spmv_shard_axis() == "model"
        assert part.spmv_shard_count() == 4

        eng = Engine(get_smoke("granite-3-2b"), ServeConfig(max_seq=32))
        mats = [generate("banded", 256, seed=4)]
        winners = eng.warm_spmv_plans(mats, repeats=1, mesh=mesh,
                                      x_mode="split")
        assert len(winners) == 1
        stats = eng.plan_cache_stats()
        assert stats["sharded_spmv_plans_warmed"] == 1
        assert stats["sharded_plan_cache"]["entries"] >= 1
        shard_stats = eng.sharded_spmv_shard_stats[0]
        assert shard_stats["n_shards"] == 4
        assert len(shard_stats["stored_slots"]) == 4
        # per-shard tuning + §12 exchange accounting in the warm stats
        assert len(shard_stats["shard_winners"]) == 4
        assert all(len(w) == 3 for w in shard_stats["shard_winners"])
        assert shard_stats["exchange_recv_cols"] == \
            shard_stats["remote_cols"]
        assert len(shard_stats["exchange_bytes"]) == 4
        assert shard_stats["kernel_chunks_per_step"] >= 1

        # re-warming on a RESIZED mesh must build a fresh stacked plan
        # (plan-cache keys carry the shard count), never reuse the stale one
        mesh8 = jax.make_mesh((1, 8), ("data", "model"))
        eng.warm_spmv_plans(mats, repeats=1, mesh=mesh8, x_mode="split")
        assert eng.sharded_spmv_shard_stats[1]["n_shards"] == 8
        assert eng.plan_cache_stats()["sharded_plan_cache"]["entries"] >= 2
        assert eng.sharded_spmv_shard_stats[0]["mesh"] != \
            eng.sharded_spmv_shard_stats[1]["mesh"]

        # dispatch: mesh_axis defaults to the sparse_rows rule ('model')
        a = generate("uniform", 256, seed=1)
        sm = ShardedRgCSR.from_dense(a, n_shards=4)
        x = np.random.default_rng(2).standard_normal(
            a.shape[1]).astype(np.float32)
        y = np.asarray(spmv(sm, jnp.asarray(x), mesh=mesh))
        np.testing.assert_allclose(y, a @ x, rtol=1e-4, atol=1e-4)
        print("OK")
    """)
