"""Row-sharded multi-device RgCSR SpMV/SpMM (DESIGN.md §10).

Two layers of coverage:

* in-process tests validate the host-side machinery on the single real CPU
  device — ShardedRgCSR construction, stacked-plan invariants, the
  local/remote column split + compact remap (by emulating one device's
  kernel call directly), and plan-cache keying;
* subprocess tests run the actual ``shard_map`` execution path on 8 fake
  host devices (``--xla_force_host_platform_device_count=8`` must live only
  in the child, mirroring tests/test_distributed.py) and assert oracle
  equivalence for ragged, empty-shard, powerlaw and spill-bearing matrices
  plus the ~1/D per-shard stored-slots/grid-steps shrink.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import from_dense
from repro.core.formats import ShardedRgCSR
from repro.core.spmv import spmv
from repro.kernels import ops as kops
from repro.kernels.rgcsr_spmv import rgcsr_spmv_pallas

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rand(seed, n, m, density):
    rng = np.random.default_rng(seed)
    a = (rng.uniform(size=(n, m)) < density).astype(np.float32)
    a *= rng.uniform(0.5, 1.5, size=(n, m)).astype(np.float32)
    return a


# ------------------------------------------------------------- construction


def test_sharded_rgcsr_construction_covers_rows():
    a = _rand(0, 300, 280, 0.05)                   # 300/8 → ragged last shard
    sm = ShardedRgCSR.from_dense(a, n_shards=8)
    assert sm.n_shards == 8 and sm.rows_per_shard == 38
    assert sm.nnz == int((a != 0).sum())
    assert all(s.shape == (38, 280) for s in sm.shards)
    np.testing.assert_array_equal(sm.to_dense(), a)
    lo, hi = sm.shard_rows(7)
    assert (lo, hi) == (266, 300)                  # unpadded true range


def test_sharded_rgcsr_empty_trailing_shard():
    a = _rand(1, 20, 64, 0.2)
    sm = ShardedRgCSR.from_dense(a, n_shards=8)    # rps=3: shard 7 is empty
    assert sm.rows_per_shard == 3
    lo, hi = sm.shard_rows(7)
    assert hi <= lo                                # owns no real rows
    assert sm.shards[7].nnz == 0
    np.testing.assert_array_equal(sm.to_dense(), a)


def test_sharded_rgcsr_rejects_bad_shards():
    with pytest.raises(ValueError):
        ShardedRgCSR.from_dense(_rand(2, 16, 16, 0.2), n_shards=0)


# ------------------------------------------------------------ plan stacking


def test_sharded_plan_uniform_stacking():
    a = _rand(3, 300, 280, 0.05)
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, chunks_per_step=2)
    d, s_pad, g = plan.values3d.shape
    assert (d, g) == (4, 128)
    assert s_pad == plan.num_steps_max * 2 * 8     # S_pad = T_max·R
    assert plan.step_group2d.shape == (4, plan.num_steps_max)
    assert len(plan.shard_stored_slots) == 4
    # true per-shard slots never exceed the stacked (padded) slot count
    assert max(plan.shard_stored_slots) <= s_pad
    # per-shard padding steps carry no accumulator-init flags
    sf = np.asarray(plan.step_first2d)
    for i, t in enumerate(plan.shard_num_steps):
        assert (sf[i, t:] == 0).all()


def test_sharded_plan_split_remote_cols_disjoint_from_local():
    a = _rand(4, 256, 256, 0.04)
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, x_mode="split")
    assert plan.cols_per_shard == 64
    rc = np.asarray(plan.remote_cols)
    for d in range(4):
        lo, hi = d * 64, (d + 1) * 64
        real = rc[d, : plan.shard_remote_cols[d]]
        assert ((real < lo) | (real >= hi)).all()  # remote = not owned
        assert len(np.unique(real)) == len(real)
    # compact indices stay inside the per-device x working set
    assert int(np.asarray(plan.columns3d).max()) < \
        plan.cols_per_shard + rc.shape[1]


def _emulate_shard(plan, d, x):
    """Run one device's slice of the stacked plan directly (no shard_map)."""
    cstride = plan.cols_per_shard
    if plan.x_mode == "split":
        xw = plan.n_shards * cstride
        x_glob = np.zeros(xw, np.float32)
        x_glob[: plan.n_cols] = x
        remote = np.asarray(plan.remote_cols)[d]
        x_use = np.concatenate([x_glob[d * cstride: (d + 1) * cstride],
                                x_glob[remote]])
    else:
        x_use = x
    n_pad = -(-len(x_use) // 128) * 128
    x_pad = jnp.zeros((1, n_pad), jnp.float32).at[0, : len(x_use)].set(
        jnp.asarray(x_use))
    y = rgcsr_spmv_pallas(
        plan.step_group2d[d], plan.step_first2d[d], plan.values3d[d],
        plan.columns3d[d], x_pad, n_groups=plan.n_groups,
        group_size=plan.group_size, chunks_per_step=plan.chunks_per_step,
        interpret=True)
    return np.asarray(y).reshape(-1)[: plan.rows_per_shard]


@pytest.mark.parametrize("x_mode", ["replicated", "split"])
def test_sharded_plan_per_device_slices_match_blocks(x_mode):
    """Each device's stacked slice × its compact x equals the dense row
    block — the remap/local-remote split is exercised without any mesh."""
    a = _rand(5, 200, 190, 0.06)
    sm = ShardedRgCSR.from_dense(a, n_shards=4)
    plan = kops.make_sharded_plan(sm, chunks_per_step=2, x_mode=x_mode)
    x = np.random.default_rng(6).standard_normal(190).astype(np.float32)
    for d in range(4):
        lo, hi = sm.shard_rows(d)
        y_d = _emulate_shard(plan, d, x)
        np.testing.assert_allclose(y_d[: hi - lo], a[lo:hi] @ x,
                                   rtol=1e-4, atol=1e-4)


def test_sharded_plan_cache_keys_on_x_mode_and_config():
    sm = ShardedRgCSR.from_dense(_rand(7, 128, 128, 0.05), n_shards=4)
    p1 = kops.get_sharded_plan(sm)
    p2 = kops.get_sharded_plan(sm, x_mode="split")
    p3 = kops.get_sharded_plan(sm, ordering="adaptive", spill_threshold=8)
    assert p1 is not p2 and p2 is not p3
    assert kops.get_sharded_plan(sm) is p1                 # repeat: hit
    assert kops.get_sharded_plan(sm, x_mode="split") is p2
    stats = kops.sharded_plan_cache_stats()
    assert stats["hits"] >= 2 and stats["misses"] >= 3


def test_sharded_spmv_requires_mesh():
    sm = ShardedRgCSR.from_dense(_rand(8, 64, 64, 0.1), n_shards=2)
    with pytest.raises(ValueError, match="mesh"):
        spmv(sm, jnp.zeros(64))


def test_partitioner_resolves_sparse_rows_axis():
    import jax
    from repro.sharding import Partitioner
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for kind in ("train", "decode"):
        part = Partitioner(mesh, kind)
        assert part.spmv_shard_axis() == "model"
        assert part.spmv_shard_count() == 1


# ---------------------------------------------- shard_map on 8 fake devices


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0 and "OK" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_sharded_spmv_matches_oracle_on_8_devices():
    """The acceptance sweep: ragged, empty-shard, powerlaw and
    spill-bearing matrices × {replicated, split} × {block, adaptive},
    SpMV and SpMM, all equal to the jnp oracle up to fp reassociation —
    plus the ~1/D per-shard stored-slots / grid-steps shrink."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np, jax.numpy as jnp
        from repro.core.formats import RgCSR, ShardedRgCSR
        from repro.core.spmv import spmv, spmm
        from repro.core.suite import generate
        from repro.kernels import ops as kops

        mesh = jax.make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)

        def check(a, **kw):
            sm = ShardedRgCSR.from_dense(a, n_shards=8)
            x = rng.standard_normal(a.shape[1]).astype(np.float32)
            y = np.asarray(spmv(sm, jnp.asarray(x), mesh=mesh, **kw))
            np.testing.assert_allclose(y, a @ x, rtol=1e-4, atol=1e-4)

        def rand(seed, n, m, density):
            r = np.random.default_rng(seed)
            a = (r.uniform(size=(n, m)) < density).astype(np.float32)
            return a * r.uniform(0.5, 1.5, (n, m)).astype(np.float32)

        ragged = rand(1, 300, 280, 0.05)           # 300 = 7·38 + 34
        tiny = rand(2, 20, 64, 0.2)                # shard 7 empty
        power = generate("powerlaw", 256, seed=0)
        skew = rand(3, 256, 240, 0.02)
        for r in np.random.default_rng(4).choice(256, 3, replace=False):
            skew[r, :200] = 1.0                    # spill-bearing rows
        for a in (ragged, tiny, power, skew):
            for x_mode in ("replicated", "split"):
                check(a, x_mode=x_mode)
                check(a, x_mode=x_mode, ordering="adaptive")
        check(skew, ordering="adaptive", spill_threshold=32, x_mode="split")
        sm = ShardedRgCSR.from_dense(skew, n_shards=8)
        plan = kops.get_sharded_plan(sm, ordering="adaptive",
                                     spill_threshold=32, x_mode="split")
        assert sum(plan.shard_spilled_elements) > 0

        # SpMM on the same sharded plans
        X = rng.standard_normal((280, 9)).astype(np.float32)
        smr = ShardedRgCSR.from_dense(ragged, n_shards=8)
        for x_mode in ("replicated", "split"):
            Y = np.asarray(spmm(smr, jnp.asarray(X), mesh=mesh,
                                mesh_axis="model", x_mode=x_mode,
                                ordering="adaptive"))
            np.testing.assert_allclose(Y, ragged @ X, rtol=1e-4, atol=1e-4)

        # ~1/D: per-shard stored slots and grid steps vs the single-device
        # plan of the same matrix/config (uniform profile: no padding floor)
        big = rand(5, 1024, 512, 0.05)
        single = kops.make_plan(RgCSR.from_dense(big), chunks_per_step=2)
        sm8 = ShardedRgCSR.from_dense(big, n_shards=8)
        p8 = kops.get_sharded_plan(sm8, chunks_per_step=2)
        assert max(p8.shard_stored_slots) <= single.stored_slots / 8 * 1.5
        assert max(p8.shard_num_steps) <= single.num_steps / 8 * 1.5
        x = rng.standard_normal(512).astype(np.float32)
        y = np.asarray(kops.sharded_rgcsr_spmv(p8, jnp.asarray(x),
                                               mesh=mesh, axis="model"))
        np.testing.assert_allclose(y, big @ x, rtol=1e-4, atol=1e-4)
        print("OK")
    """)


def test_sharded_engine_warmup_and_partitioner_routing_on_8_devices():
    """Engine.warm_spmv_plans with a mesh: autotuned winner config applied
    per shard, sharded plan staged + stats recorded; core.spmv resolves the
    mesh axis through the partitioner's sparse_rows rule."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_smoke
        from repro.core.formats import ShardedRgCSR
        from repro.core.spmv import spmv
        from repro.core.suite import generate
        from repro.serve import Engine, ServeConfig
        from repro.sharding import Partitioner

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        part = Partitioner(mesh, "decode")
        assert part.spmv_shard_axis() == "model"
        assert part.spmv_shard_count() == 4

        eng = Engine(get_smoke("granite-3-2b"), ServeConfig(max_seq=32))
        mats = [generate("banded", 256, seed=4)]
        winners = eng.warm_spmv_plans(mats, repeats=1, mesh=mesh)
        assert len(winners) == 1
        stats = eng.plan_cache_stats()
        assert stats["sharded_spmv_plans_warmed"] == 1
        assert stats["sharded_plan_cache"]["entries"] >= 1
        shard_stats = eng.sharded_spmv_shard_stats[0]
        assert shard_stats["n_shards"] == 4
        assert len(shard_stats["stored_slots"]) == 4

        # dispatch: mesh_axis defaults to the sparse_rows rule ('model')
        a = generate("uniform", 256, seed=1)
        sm = ShardedRgCSR.from_dense(a, n_shards=4)
        x = np.random.default_rng(2).standard_normal(
            a.shape[1]).astype(np.float32)
        y = np.asarray(spmv(sm, jnp.asarray(x), mesh=mesh))
        np.testing.assert_allclose(y, a @ x, rtol=1e-4, atol=1e-4)
        print("OK")
    """)
