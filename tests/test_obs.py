"""Unified observability subsystem (DESIGN.md §13, ISSUE 10).

Covers the typed metrics registry (counters/gauges/histograms, the
StatsView dict facade, declarative cross-replica merge, JSON snapshot
round-trip), the structured span/event tracer (deterministic under
FakeClock: two identical runs export byte-identical Chrome trace JSON),
the trace-event validator and counter cross-check the CI trace lane
gates on, metrics survival across the §7.6 kill-all drill (no resets, no
double counts), and the kernel-timing provenance path (``time_us``
warmup semantics, ``autotune.timing_source()``).

Determinism note: every engine test runs FakeClock advanced per decode
step with greedy sampling — byte-identity assertions would be impossible
on wall-clock.
"""
import json

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs.trace import NOOP, Tracer
from repro.serve import Engine, Request, Router, RouterConfig, ServeConfig
from repro.serve.paging import SERVE_MERGE_SPEC, merge_replica_stats

S_MAX = 64
PS = 4


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _tick_decode(eng, clock, dt=1.0):
    orig = eng._decode
    orig_fused = eng._fused_decode

    def wrapped(*a):
        clock.advance(dt)
        return orig(*a)

    def wrapped_fused(*a):
        out = orig_fused(*a)
        clock.advance(dt * int(out[1]))
        return out

    eng._decode = wrapped
    eng._fused_decode = wrapped_fused


def _engine(cfg=None, clock=None, params=None, tracer=None, **serve_kw):
    cfg = cfg or get_smoke("granite-3-2b")
    skw = dict(max_seq=S_MAX, n_slots=2, page_size=PS, temperature=0.0,
               eos_id=-1)
    skw.update(serve_kw)
    eng = Engine(cfg, ServeConfig(**skw), params=params)
    if tracer is not None:
        eng.tracer = tracer
    if clock is not None:
        eng.clock = clock
        _tick_decode(eng, clock)
    return cfg, eng


def _reqs(cfg, n, seed=11, prompt_len=8, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, cfg.vocab,
                                        (prompt_len,)).astype(np.int32),
                    max_new_tokens=max_new) for _ in range(n)]


# ------------------------------------------------------------- registry


def test_registry_get_or_create_and_kind_conflict():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("preemptions")
    assert reg.counter("preemptions") is c
    c.inc(3)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("page_high_water")
    g.set_max(5)
    g.set_max(2)
    assert g.value == 5
    # labels distinguish children of one logical metric
    assert reg.counter("faults", replica=0) \
        is not reg.counter("faults", replica=1)
    with pytest.raises(TypeError):
        reg.histogram("preemptions")


def test_stats_view_is_dict_compatible():
    reg = obs_metrics.MetricsRegistry()
    stats = reg.view(counters=("preemptions",), gauges=("peak",))
    stats["preemptions"] += 1
    stats["preemptions"] += 1
    stats["new_counter"] = 7         # created on the fly
    assert stats["preemptions"] == 2
    assert dict(stats) == {"preemptions": 2, "peak": 0, "new_counter": 7}
    assert len(stats) == 3 and "preemptions" in stats
    # the values live in typed registry cells, not a shadow dict
    assert reg.counter("preemptions").value == 2
    with pytest.raises(TypeError):
        del stats["preemptions"]
    with pytest.raises(KeyError):
        stats["never_set"]


def test_histogram_percentiles_and_overflow_visibility():
    h = obs_metrics.Histogram("latency_s")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100 and h.dropped == 0
    pcts = obs_metrics.percentile_summary(h.state())
    assert pcts["p50"] == pytest.approx(50.5)
    assert pcts["p95"] < pcts["p99"] <= 100.0
    assert obs_metrics.percentile_summary({"samples": []}) == {}
    # overflow keeps count/sum exact and counts the discard
    h2 = obs_metrics.Histogram("big")
    h2.MAX_SAMPLES = 10  # instance override keeps the test tiny
    for v in range(25):
        h2.observe(v)
    assert h2.count == 25 and len(h2.samples) == 10 and h2.dropped == 15


def test_registry_snapshot_restore_roundtrip():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("completed").inc(4)
    reg.gauge("peak").set(9)
    reg.histogram("queue_s").observe(0.5)
    reg.histogram("queue_s").observe(1.5)
    snap = json.loads(json.dumps(reg.snapshot()))  # must be JSON-clean
    reg2 = obs_metrics.MetricsRegistry()
    reg2.restore(snap)
    assert reg2.counter("completed").value == 4
    assert reg2.gauge("peak").value == 9
    assert reg2.histogram("queue_s").state() == \
        reg.histogram("queue_s").state()
    assert reg2.snapshot() == reg.snapshot()


def test_merge_stats_serve_spec_semantics():
    a = {"preemptions": 2, "completed": 3, "n_pages": 16, "page_size": 4,
         "page_high_water": 5, "peak_live_tokens": 40,
         "straggler_decode_steps": 1,
         "request_timing": {"latency_s": {"count": 1, "sum": 2.0,
                                          "dropped": 0, "samples": [2.0]}}}
    b = {"preemptions": 1, "completed": 4, "n_pages": 99, "page_size": 4,
         "page_high_water": 7, "straggler_decode_steps": 0,
         "request_timing": {"latency_s": {"count": 1, "sum": 4.0,
                                          "dropped": 0, "samples": [4.0]}}}
    m = merge_replica_stats([a, b])
    assert m["preemptions"] == 3 and m["completed"] == 7      # sum
    assert m["n_pages"] == 16                                  # first
    assert m["page_high_water"] == 7                           # max
    assert m["page_high_water_per_replica"] == [5, 7]          # list_as
    assert m["straggler_decode_steps_per_replica"] == [1, 0]
    # gate: peak_live_tokens merges because page_high_water is present,
    # replica b's missing entry contributing 0
    assert m["peak_live_tokens"] == 40
    # hist_map: samples concatenate, percentiles come from merged samples
    lat = m["request_timing"]["latency_s"]
    assert lat["count"] == 2 and sorted(lat["samples"]) == [2.0, 4.0]
    assert obs_metrics.timing_percentiles(m["request_timing"])[
        "latency_s"]["p50"] == pytest.approx(3.0)
    # keys outside the spec are dropped; empty input merges to {}
    assert "not_a_key" not in merge_replica_stats([{"not_a_key": 1}])
    assert merge_replica_stats([]) == {}
    # every session counter the engine seeds has a rule (schema drift guard)
    for key in ("requests", "completed", "preemptions", "rejected",
                "failed", "timed_out", "restores", "pages_quarantined",
                "decode_steps", "request_timing"):
        assert key in SERVE_MERGE_SPEC


# --------------------------------------------------------------- tracer


def _scripted_tracer():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    req = Request(tokens=np.zeros(4, np.int32), max_new_tokens=2)
    tr.request_begin(req, ("router", "main"), prompt=4)
    clock.advance(0.5)
    tr.begin("prefill", ("replica0", "slot0"), tokens=4)
    clock.advance(1.0)
    tr.end("prefill", ("replica0", "slot0"))
    tr.instant("preempt", ("replica0", "slot0"), slot=0)
    tr.counter("free_pages", ("replica0", "session"), free=3)
    tr.request_point(req, "migrated", ("router", "main"))
    clock.advance(0.25)
    tr.request_end(req, ("router", "main"), status="ok")
    return tr


def test_tracer_export_is_deterministic_and_valid():
    t1, t2 = _scripted_tracer(), _scripted_tracer()
    e1 = obs_export.export_chrome_trace(t1)
    e2 = obs_export.export_chrome_trace(t2)
    assert e1 == e2                      # byte-identical
    doc = json.loads(e1)
    assert obs_export.validate_chrome_trace(doc) == []
    # track naming made it into the metadata records
    names = {ev["args"]["name"] for ev in doc["traceEvents"]
             if ev.get("ph") == "M"}
    assert {"router", "replica0", "main", "slot0", "session"} <= names


def test_noop_tracer_records_nothing():
    req = Request(tokens=np.zeros(2, np.int32), max_new_tokens=1)
    NOOP.begin("x", ("a", "b"))
    NOOP.request_begin(req, ("a", "b"))
    assert NOOP.enabled is False and not hasattr(NOOP, "events")


def test_request_lifeline_guards():
    tr = Tracer(clock=FakeClock())
    req = Request(tokens=np.zeros(2, np.int32), max_new_tokens=1)
    tr.request_point(req, "early", ("r", "m"))   # before begin: dropped
    tr.request_end(req, ("r", "m"))              # before begin: dropped
    assert tr.events == []
    tr.request_begin(req, ("r", "m"))
    tr.request_begin(req, ("r", "m"))            # idempotent
    tr.request_end(req, ("r", "m"))
    assert [e["ph"] for e in tr.events] == ["b", "e"]


def test_validator_catches_malformed_traces():
    def doc(events):
        return {"traceEvents": events}

    base = {"pid": 1, "tid": 1, "cat": "serve"}
    # E without B
    assert obs_export.validate_chrome_trace(doc(
        [{"name": "x", "ph": "E", "ts": 1, **base}]))
    # bad nesting (E closes a differently-named B)
    assert obs_export.validate_chrome_trace(doc(
        [{"name": "a", "ph": "B", "ts": 1, **base},
         {"name": "b", "ph": "E", "ts": 2, **base}]))
    # unclosed B
    assert obs_export.validate_chrome_trace(doc(
        [{"name": "a", "ph": "B", "ts": 1, **base}]))
    # timestamps must be non-decreasing per (pid, tid)
    assert obs_export.validate_chrome_trace(doc(
        [{"name": "a", "ph": "i", "ts": 5, **base},
         {"name": "b", "ph": "i", "ts": 3, **base}]))
    # async instant outside its lifeline
    assert obs_export.validate_chrome_trace(doc(
        [{"name": "request", "ph": "n", "ts": 1, "id": 7, **base}]))
    # missing required keys / unknown phase
    assert obs_export.validate_chrome_trace(doc([{"ph": "i", "ts": 0}]))
    assert obs_export.validate_chrome_trace(doc(
        [{"name": "a", "ph": "?", "ts": 1, **base}]))


def test_export_closes_abandoned_spans():
    """A crash kills the process mid-span: the export synthesizes closers
    (tagged abandoned) so the trace still validates."""
    clock = FakeClock()
    tr = Tracer(clock=clock)
    req = Request(tokens=np.zeros(2, np.int32), max_new_tokens=1)
    tr.begin("decode_chunk", ("replica0", "session"))
    tr.request_begin(req, ("router", "main"))
    clock.advance(2.0)
    doc = json.loads(obs_export.export_chrome_trace(tr))
    assert obs_export.validate_chrome_trace(doc) == []
    closers = [ev for ev in doc["traceEvents"]
               if (ev.get("args") or {}).get("abandoned")]
    assert {ev["ph"] for ev in closers} == {"E", "e"}


def test_cross_check_counters_exact_at_least_and_attribution():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    tr.instant("migrate", ("replica1", "session"), replica=1)
    tr.instant("preempt", ("replica0", "slot0"), slot=0)
    doc = json.loads(obs_export.export_chrome_trace(tr))
    ok = {"migrations": 1, "preemptions": 1}
    assert obs_export.cross_check_counters(doc, ok) == []
    # count mismatch is caught in exact mode, tolerated upward in at_least
    assert obs_export.cross_check_counters(doc, {"migrations": 2})
    under = {"migrations": 0, "preemptions": 1}
    assert obs_export.cross_check_counters(doc, under, mode="at_least") \
        == []
    assert obs_export.cross_check_counters(doc, {"preemptions": 2},
                                           mode="at_least")
    with pytest.raises(ValueError):
        obs_export.cross_check_counters(doc, ok, mode="bogus")
    # replica-attribution: an event tagged replica=N on the wrong process
    tr2 = Tracer(clock=FakeClock())
    tr2.instant("migrate", ("replica0", "session"), replica=1)
    doc2 = json.loads(obs_export.export_chrome_trace(tr2))
    assert obs_export.cross_check_counters(doc2, {"migrations": 1})


def test_span_summary_counts_and_durations():
    tr = _scripted_tracer()
    summ = obs_export.span_summary(tr)
    assert summ["spans"]["prefill"]["n"] == 1
    assert summ["spans"]["prefill"]["total_s"] == pytest.approx(1.0)
    assert summ["events"]["preempt"] == 1
    assert summ["events"]["migrated"] == 1   # request_point by args.point


# -------------------------------------------------- engine integration


def _traced_serve(seed_params=None):
    clock = FakeClock()
    tracer = Tracer(clock=clock)
    cfg, eng = _engine(clock=clock, params=seed_params, tracer=tracer)
    reqs = _reqs(cfg, 3)
    eng.serve(reqs)
    assert all(r.ok_like for r in reqs)
    return eng, tracer


def test_engine_trace_deterministic_byte_identical():
    """THE determinism acceptance: two identical FakeClock serves export
    byte-identical Chrome traces, and the trace validates + cross-checks
    against the run's own stats."""
    eng1, t1 = _traced_serve()
    eng2, t2 = _traced_serve(seed_params=eng1.params)
    e1 = obs_export.export_chrome_trace(t1)
    e2 = obs_export.export_chrome_trace(t2)
    assert e1 == e2
    doc = json.loads(e1)
    assert obs_export.validate_chrome_trace(doc) == []
    assert obs_export.cross_check_counters(doc, eng1.paging_stats) == []
    # the span taxonomy actually showed up
    summ = obs_export.span_summary(doc)
    assert summ["spans"]["request"]["n"] == 3
    assert summ["spans"]["prefill"]["n"] == 3
    assert summ["spans"]["decode_chunk"]["n"] >= 1
    assert summ["events"]["fused_dispatch"] >= 1


def test_session_stats_are_registry_backed_with_percentiles():
    clock = FakeClock()
    cfg, eng = _engine(clock=clock)
    reqs = _reqs(cfg, 3)
    eng.serve(reqs)
    st = eng.paging_stats
    assert st["completed"] == 3
    timing = st["request_timing"]
    assert timing["latency_s"]["count"] == 3
    assert timing["queue_s"]["count"] == 3
    pcts = st["latency_percentiles"]
    assert set(pcts["latency_s"]) == {"p50", "p95", "p99"}
    # FakeClock ticks once per decode step → latencies are exact step
    # counts, so the percentiles are deterministic values, not just shapes
    assert pcts["latency_s"]["p50"] > 0


def test_metrics_survive_kill_all_snapshot_restore():
    """§7.6 drill: counters and histograms ride the snapshot — restored
    totals continue from the pre-crash values (no reset), re-enqueued
    requests are not re-counted (no double count), and the continuous
    trace cross-checks against the restored stats in at_least mode."""
    clock = FakeClock()
    tracer = Tracer(clock=clock)
    cfg, eng = _engine(clock=clock, tracer=tracer)
    reqs = _reqs(cfg, 4, max_new=6)
    sess = eng.start_session(list(reqs))
    sess.step(4)
    pre = dict(sess.stats)
    pre_timing = {k: dict(v) for k, v in sess.snapshot()
                  ["request_timing"].items()}
    snap = json.loads(json.dumps(sess.snapshot()))
    assert pre["requests"] == 4

    # "new process": fresh engine + fresh host state, params survive
    _, eng2 = _engine(clock=clock, params=eng.params, tracer=tracer)
    sess2, restored = eng2.restore_session(snap)
    st = dict(sess2.stats)
    assert st["requests"] == pre["requests"]        # no double count
    assert st["completed"] == pre["completed"]      # no reset
    assert st["restores"] == 1
    # pre-crash histogram population carried over
    timing = {k: v for k, v in sess2.snapshot()["request_timing"].items()}
    for name, state in pre_timing.items():
        assert timing[name]["count"] >= state["count"]
    sess2.drain()
    final = sess2.stats_snapshot()
    assert final["completed"] == 4
    assert final["requests"] == 4                   # still no double count
    assert final["request_timing"]["latency_s"]["count"] >= 4
    # the continuous trace (same tracer across the "kill") validates and
    # cross-checks: restore rolled counters back to the snapshot, so the
    # trace may hold MORE events than the counters — never fewer
    doc = json.loads(obs_export.export_chrome_trace(tracer))
    assert obs_export.validate_chrome_trace(doc) == []
    assert obs_export.cross_check_counters(doc, final,
                                           mode="at_least") == []
    names = {(ev.get("args") or {}).get("point") or ev["name"]
             for ev in doc["traceEvents"] if ev.get("ph") in ("i", "n")}
    assert {"snapshot", "restore"} <= names


def test_router_stats_trace_cross_check_on_kill():
    """Failover drill with tracing: the migrate/fault/restart instants
    land on the right replica tracks and match the router counters
    exactly."""
    from repro.train.fault import FaultConfig, FaultInjector
    clock = FakeClock()
    tracer = Tracer(clock=clock)
    cfg = get_smoke("granite-3-2b")
    scfg = ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                       temperature=0.0, eos_id=-1)
    fault_cfg = FaultConfig(max_restarts=3, backoff_s=0.5)
    first = Engine(cfg, scfg, fault_cfg=fault_cfg)
    engines = [first, Engine(cfg, scfg, params=first.params,
                             fault_cfg=fault_cfg)]
    engines[1].fault_injector = FaultInjector(
        fail_at_steps=(("replica", 2),))
    for e in engines:
        e.clock = clock
        _tick_decode(e, clock)
    router = Router(engines, cfg=RouterConfig(n_replicas=2),
                    fault_cfg=fault_cfg, clock=clock, sleep=clock.advance,
                    tracer=tracer)
    reqs = _reqs(cfg, 4, max_new=5)
    router.serve(reqs)
    assert all(r.ok_like for r in reqs)
    st = router.stats()
    assert st["replica_faults"] == 1 and st["migrations"] >= 1
    assert "latency_percentiles" in st
    doc = json.loads(obs_export.export_chrome_trace(tracer))
    assert obs_export.validate_chrome_trace(doc) == []
    assert obs_export.cross_check_counters(doc, st) == []
    # the fault landed on replica1's track, by name
    pnames = {ev["pid"]: ev["args"]["name"] for ev in doc["traceEvents"]
              if ev.get("ph") == "M" and ev.get("name") == "process_name"}
    faults = [ev for ev in doc["traceEvents"]
              if ev.get("name") == "replica_fault" and ev.get("ph") == "i"]
    assert faults and all(pnames[ev["pid"]] == "replica1" for ev in faults)


# ------------------------------------------------- timing provenance


def test_time_us_warmup_zero_and_blocking():
    """Satellite regression: warmup=0 must run zero warmup calls (the old
    ``range(max(warmup, 1))`` forced one), and every warmup iteration is
    blocked, not just dispatched."""
    from repro.core.timing import time_us
    calls = []

    def fn():
        calls.append(1)
        return np.zeros(1)

    time_us(fn, repeats=2, warmup=0)
    assert len(calls) == 2
    calls.clear()
    time_us(fn, repeats=2, warmup=3)
    assert len(calls) == 5


def test_timing_source_provenance(monkeypatch, deterministic_autotune):
    """The autotuner records HOW it timed: a monkeypatched ``time_us``
    (the deterministic_autotune fixture) must force wallclock provenance,
    and the recorded TuneResult carries it."""
    from repro.kernels import autotune
    # fixture patched autotune.time_us → source must report wallclock
    assert autotune.timing_source() == "wallclock"
    rng = np.random.default_rng(0)
    a = (rng.uniform(size=(64, 64)) < 0.1).astype(np.float32)
    result = autotune.autotune_spmv(a, repeats=1)
    assert result.timing_source == "wallclock"
    with pytest.raises(ValueError):
        autotune.set_timing_source("bogus")
    autotune.set_timing_source("wallclock")
    try:
        assert autotune.timing_source() == "wallclock"
    finally:
        autotune.set_timing_source("auto")
