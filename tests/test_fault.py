"""Fault-tolerance primitives (train/fault.py): Watchdog EWMA straggler
detection, RestartableLoop bounded retry + checkpoint replay, and the
site-qualified FaultInjector the serving engine threads through its
per-request paths (DESIGN.md §6.4).

Determinism note (the PR 3 lesson): nothing here asserts on wall-clock —
watchdog step times are synthetic floats and the retry backoff sleeps are
monkeypatched into a recording list, so the suite cannot flake under load.
"""
import pytest

from repro.train.fault import (FaultConfig, FaultInjector, ProcessKilled,
                               RestartableLoop, Watchdog)

# ------------------------------------------------------------- watchdog


def _cfg(**kw):
    base = dict(straggler_ewma_alpha=0.5, straggler_factor=2.0,
                min_samples=3)
    base.update(kw)
    return FaultConfig(**base)


def test_watchdog_warmup_never_flags():
    """No straggler verdicts before min_samples observations — the first
    steps (compile, cold caches) are legitimately slow."""
    wd = Watchdog(_cfg())
    assert not wd.observe(0, 100.0)       # ewma not yet seeded
    assert not wd.observe(1, 100.0)       # n < min_samples
    assert not wd.observe(2, 100.0)
    assert wd.events == []


def test_watchdog_flags_straggler_and_ewma_adapts():
    wd = Watchdog(_cfg())
    for step in range(3):
        assert not wd.observe(step, 1.0)
    assert wd.ewma == pytest.approx(1.0)
    # 2.5 > factor(2.0) * ewma(1.0) -> flagged, with the pre-update ewma
    assert wd.observe(3, 2.5)
    assert wd.events == [(3, 2.5, pytest.approx(1.0))]
    # the flagged dt feeds the EWMA *clamped at the threshold* (2.0, not
    # the raw 2.5): 0.5*1.0 + 0.5*2.0 = 1.5 — so a still-slow 3.1s step
    # (> 2*1.5) keeps being flagged instead of being absorbed
    assert wd.ewma == pytest.approx(1.5)
    assert wd.observe(4, 3.1)
    assert len(wd.events) == 2


def test_watchdog_sustained_slowdown_keeps_flagging():
    """Regression for EWMA pollution: pre-clamp, folding a straggler's raw
    dt into the EWMA inflated the baseline so fast that a *step-function*
    slowdown (host goes 1.0s → 10.0s and stays there) was flagged exactly
    once and then became invisible.  With the clamp the baseline adapts
    geometrically (×factor per flagged step), so the slowdown is flagged
    for several consecutive steps — long enough for a router health policy
    to mark the replica degraded — before becoming the new normal."""
    wd = Watchdog(_cfg(straggler_ewma_alpha=1.0))  # worst case: EWMA = last
    for step in range(3):
        wd.observe(step, 1.0)
    flags = [wd.observe(3 + i, 10.0) for i in range(6)]
    # baseline climbs 1.0 → 2.0 → 4.0 → 8.0 (clamped ×2 per step); the
    # 10.0s steps flag until 2*ewma catches up, then stop
    assert flags == [True, True, True, False, False, False]
    # pre-clamp behavior (alpha=1.0 folds the raw 10.0 in immediately):
    # exactly one flag, then silence — the bug this guards against
    assert sum(flags) >= 3


def test_watchdog_on_straggler_callback():
    calls = []
    wd = Watchdog(_cfg(), on_straggler=lambda *a: calls.append(a))
    for step in range(4):
        wd.observe(step, 1.0)
    wd.observe(4, 9.0)
    assert calls == [(4, 9.0, pytest.approx(1.0))]


# ------------------------------------------------------- restartable loop


def test_restartable_loop_retry_backoff_and_exact_replay(monkeypatch):
    """Two injected failures: each restart sleeps backoff_s * restarts
    (recorded, not slept), restores the latest checkpoint, and replays to
    the same final state as a fault-free run (deterministic data)."""
    sleeps = []
    monkeypatch.setattr("repro.train.fault.time.sleep", sleeps.append)
    loop = RestartableLoop(FaultConfig(max_restarts=3, backoff_s=0.1))
    inj = FaultInjector(fail_at_steps=(2, 4))
    ckpt = {"state": 0, "step": 0}

    def step_fn(state, step):
        inj.check(step)
        state = state + step
        if step % 2 == 0:                 # checkpoint every other step
            ckpt.update(state=state, step=step + 1)
        return state

    state, step = loop.run(0, 0, 6, step_fn, lambda: (ckpt["state"],
                                                      ckpt["step"]))
    assert (state, step) == (sum(range(6)), 6)    # replay is exact
    assert loop.restarts == 2
    assert sleeps == [pytest.approx(0.1), pytest.approx(0.2)]
    assert inj.fired == [(None, 2), (None, 4)]


def test_restartable_loop_injectable_sleep_and_clock():
    """Backoff via injected hooks (no monkeypatching, no wall-clock):
    sleep= records instead of sleeping and clock= stamps restart_log, so
    the exact backoff schedule is assertable on a fake timer."""
    sleeps = []
    t = [100.0]

    def clock():
        t[0] += 1.0
        return t[0]

    loop = RestartableLoop(FaultConfig(max_restarts=3, backoff_s=0.5),
                           sleep=sleeps.append, clock=clock)
    inj = FaultInjector(fail_at_steps=(1, 3))

    def step_fn(state, step):
        inj.check(step)
        return state + 1

    state, step = loop.run(0, 0, 4, step_fn, lambda: (0, 0))
    assert step == 4
    # backoff_s * restarts: 0.5 then 1.0, through the injected sleep only
    assert sleeps == [pytest.approx(0.5), pytest.approx(1.0)]
    assert [(s, pytest.approx(b)) for s, b, _ in loop.restart_log] == \
        [(1, pytest.approx(0.5)), (3, pytest.approx(1.0))]
    # timestamps come from the injected clock (strictly increasing fakes)
    assert [ts for _, _, ts in loop.restart_log] == [101.0, 102.0]


def test_restartable_loop_budget_exhausted_reraises():
    loop = RestartableLoop(FaultConfig(max_restarts=2, backoff_s=0.0))

    def step_fn(state, step):
        raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError, match="persistent failure"):
        loop.run(0, 0, 4, step_fn, lambda: (0, 0))
    assert loop.restarts == loop.cfg.max_restarts + 1


# ---------------------------------------------------------- fault injector


def test_fault_injector_site_qualified_and_bare_steps():
    inj = FaultInjector(fail_at_steps=(("prefill", 1), 3), exc=ValueError)
    inj.check(1)                          # bare step: tuple key untouched
    inj.check(0, site="prefill")          # wrong step
    inj.check(1, site="decode")           # wrong site
    with pytest.raises(ValueError, match="injected fault at prefill 1"):
        inj.check(1, site="prefill")
    inj.check(1, site="prefill")          # fires exactly once
    with pytest.raises(ValueError, match="injected fault at decode 3"):
        inj.check(3, site="decode")       # bare int matches any site
    inj.check(3)
    assert inj.fired == [("prefill", 1), ("decode", 3)]
    assert inj.fail_at == set()


def test_fault_injector_disarm():
    inj = FaultInjector(fail_at_steps=(0,))
    inj.armed = False
    inj.check(0)                          # disarmed: nothing fires
    assert inj.fired == []
    inj.armed = True
    with pytest.raises(RuntimeError):
        inj.check(0)


def test_process_site_requires_exact_match():
    """A bare site-agnostic int may escalate request-tier sites, but must
    NOT kill the whole process: the engine checks the "process" site with
    exact=True, which ignores bare ints."""
    inj = FaultInjector(fail_at_steps=(3,))
    inj.check(3, site="process", exact=True)      # bare int ignored
    assert inj.fired == []
    with pytest.raises(RuntimeError):
        inj.check(3, site="decode")               # non-exact still matches
    inj2 = FaultInjector(fail_at_steps=(("process", 7),))
    assert inj2.next_armed("process", 0, 10, exact=True) == 7
    with pytest.raises(ProcessKilled, match="process 7"):
        inj2.check(7, site="process", exact=True)
    inj2.check(7, site="process", exact=True)     # fires exactly once
    assert inj2.fired == [("process", 7)]


def test_take_drains_corruption_sites_without_raising():
    """take() pops the smallest armed index for a site and never raises —
    the corruption-site drain: the fault is the page scribble, detection
    must come from the integrity layer."""
    inj = FaultInjector(fail_at_steps=(("page", 4), ("page", 2),
                                       ("page_nan", 9), 5))
    assert inj.take("page") == 2
    assert inj.take("page") == 4
    assert inj.take("page") is None               # drained
    assert inj.take("page_nan") == 9
    assert ("page", 2) in inj.fired and ("page_nan", 9) in inj.fired
    with pytest.raises(RuntimeError):
        inj.check(5)                              # bare ints untouched
    inj.armed = False
    inj3 = FaultInjector(fail_at_steps=(("page", 1),))
    inj3.armed = False
    assert inj3.take("page") is None              # disarmed drain is a no-op
