"""Crash-consistent serving (DESIGN.md §7.6, ISSUE 9).

Covers the session/router snapshot–restore path (host state only — the
KV cache is rebuilt by re-prefilling prompt + generated prefix through
the recompute machinery, so restored streams are token-identical to the
greedy ``generate()`` oracle), the on-disk :class:`SnapshotManager`
(atomic publish, LATEST pointer, rolling retention), the whole-process
kill drill (``("process", k)`` → :class:`ProcessKilled` → rebuild fleet →
restore → drain, zero failures), and the KV-page integrity layer: silent
corruption (``("page", idx)``) detected by commit-boundary crc32
verification, in-window corruption (``("page_nan", idx)``) caught by the
fused loop's non-finite logit screen before the tainted token commits —
both quarantine the poisoned page(s) and recompute-preempt exactly the
touching request.

Determinism note (the PR 3 lesson): engines run FakeClock advanced per
decode step, streams are greedy, and fault sites fire on exact decode-step
or page indices — nothing here asserts on wall-clock.
"""
import json
import os

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.serve import Engine, Request, Router, RouterConfig, ServeConfig
from repro.train import checkpoint
from repro.train.fault import FaultConfig, FaultInjector, ProcessKilled

S_MAX = 64
PS = 4


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _tick_decode(eng, clock, dt=1.0):
    orig = eng._decode
    orig_fused = eng._fused_decode

    def wrapped(*a):
        clock.advance(dt)
        return orig(*a)

    def wrapped_fused(*a):
        out = orig_fused(*a)
        clock.advance(dt * int(out[1]))
        return out

    eng._decode = wrapped
    eng._fused_decode = wrapped_fused


def _engine(cfg=None, clock=None, **serve_kw):
    cfg = cfg or get_smoke("granite-3-2b")
    skw = dict(max_seq=S_MAX, n_slots=2, page_size=PS)
    skw.update(serve_kw)
    eng = Engine(cfg, ServeConfig(**skw))
    if clock is not None:
        eng.clock = clock
        _tick_decode(eng, clock)
    return cfg, eng


def _clone(cfg, eng, clock=None, **serve_kw):
    """A "new process": fresh engine, fresh host state, surviving params."""
    skw = dict(max_seq=S_MAX, n_slots=2, page_size=PS)
    skw.update(serve_kw)
    eng2 = Engine(cfg, ServeConfig(**skw), params=eng.params)
    if clock is not None:
        eng2.clock = clock
        _tick_decode(eng2, clock)
    return eng2


def _reqs(cfg, n, seed=21, prompt_len=8, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, cfg.vocab,
                                        (prompt_len,)).astype(np.int32),
                    max_new_tokens=max_new) for _ in range(n)]


def _oracle_map(eng, reqs):
    return {r.tokens.tobytes(): list(eng.generate(
        r.tokens[None, :], max_new_tokens=r.max_new_tokens)[0])
        for r in reqs}


def _assert_all_match(done, oracle, n_expected):
    assert len(done) == n_expected
    assert all(r.done and r.ok_like for r in done)
    for r in done:
        assert r.out == oracle[r.tokens.tobytes()], \
            "stream drifted across snapshot/restore"


# ------------------------------------------------- on-disk snapshots


def test_snapshot_manager_roundtrip_retention_atomicity(tmp_path):
    d = str(tmp_path / "snaps")
    mgr = checkpoint.SnapshotManager(d, keep=3)
    for i in range(5):
        mgr.save({"seq": i})
    files = sorted(f for f in os.listdir(d) if f.startswith("snap_"))
    assert files == [f"snap_{i:09d}.json" for i in (2, 3, 4)]
    assert not any(f.endswith(".tmp") for f in os.listdir(d))
    assert checkpoint.latest_snapshot(d) == 4
    state, seq = mgr.restore_latest()
    assert state == {"seq": 4} and seq == 4
    assert checkpoint.restore_snapshot(d, 3) == {"seq": 3}
    with pytest.raises(FileNotFoundError):
        checkpoint.restore_snapshot(d, 0)          # pruned by retention
    assert mgr.next_seq == 5


def test_snapshot_manager_empty_dir_raises(tmp_path):
    mgr = checkpoint.SnapshotManager(str(tmp_path / "none"))
    with pytest.raises(FileNotFoundError):
        mgr.restore_latest()


# ------------------------------------- session snapshot/restore core


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_session_snapshot_restore_midstream_token_identical(layout):
    clock = FakeClock()
    cfg, eng = _engine(clock=clock, kv_layout=layout)
    reqs = _reqs(cfg, 4)
    oracle = _oracle_map(eng, reqs)
    sess = eng.start_session(list(reqs))
    sess.step(3)
    snap = sess.snapshot()
    json.dumps(snap)                  # must be plain-JSON serializable
    eng2 = _clone(cfg, eng, clock=clock, kv_layout=layout)
    sess2, restored = eng2.restore_session(snap)
    assert restored, "mid-stream snapshot restored no requests"
    sess2.drain()
    done = [r for r in reqs if r.done] + restored
    _assert_all_match(done, oracle, len(reqs))
    st = sess2.stats_snapshot()
    assert st["restores"] == 1 and st["failed"] == 0
    # prefix-bearing requests are re-prefilled: the recompute budget is
    # prompt + generated prefix for each one restored mid-stream
    assert st["restore_recompute_tokens"] >= max(
        len(r.tokens) for r in restored)


def test_restore_layout_mismatch_rejected():
    cfg, eng = _engine(kv_layout="paged")
    sess = eng.start_session(_reqs(cfg, 1))
    snap = sess.snapshot()
    _, eng2 = _engine(cfg=cfg, kv_layout="dense")
    with pytest.raises(ValueError):
        eng2.start_session([]).restore(snap)


@pytest.mark.parametrize("layout", ["paged", "dense"])
@pytest.mark.parametrize("chunk", [1, 8])
def test_snapshot_at_every_chunk_boundary_equivalence(layout, chunk):
    """THE tentpole acceptance sweep: snapshot at *every* chunk boundary
    of a serving session; each snapshot, restored into a fresh engine and
    drained, must finish every request token-identical to the oracle."""
    clock = FakeClock()
    cfg, eng = _engine(clock=clock, kv_layout=layout, decode_chunk=chunk)
    # chunk=8 drains 3×4-token requests inside ONE step() call (a single
    # boundary) — lengthen generations there so the sweep crosses at
    # least one mid-stream boundary; chunk=1 snapshots every decode step
    reqs = _reqs(cfg, 3, seed=22, prompt_len=6,
                 max_new=(4 if chunk == 1 else 6))
    oracle = _oracle_map(eng, reqs)
    sess = eng.start_session(list(reqs))
    snaps = []
    while not sess.idle:
        snaps.append(sess.snapshot())
        sess.step(chunk)
    assert len(snaps) >= (5 if chunk == 1 else 2)   # the sweep swept
    for snap in snaps:
        eng2 = _clone(cfg, eng, clock=clock, kv_layout=layout,
                      decode_chunk=chunk)
        sess2, restored = eng2.restore_session(snap)
        sess2.drain()
        # requests finished before this boundary are not in the snapshot;
        # the restored tail must cover exactly the rest
        finished_before = len(reqs) - len(restored)
        assert 0 <= finished_before <= len(reqs)
        _assert_all_match(restored, oracle, len(restored))
    # the original session also ran to completion, unperturbed
    _assert_all_match(reqs, oracle, len(reqs))


# --------------------------------------------- whole-process kill drill


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_kill_all_drill_restore_drain_token_identical(tmp_path, layout):
    """Boundary snapshots + ("process", k) kill: everything dies, a fresh
    engine restores the latest on-disk snapshot and drains — every
    request completes, token-identical, zero failed."""
    clock = FakeClock()
    cfg, eng = _engine(clock=clock, kv_layout=layout)
    eng.fault_injector = FaultInjector(fail_at_steps=(("process", 5),))
    reqs = _reqs(cfg, 4, seed=23, prompt_len=8, max_new=8)
    oracle = _oracle_map(eng, reqs)
    mgr = checkpoint.SnapshotManager(str(tmp_path / "snaps"))
    sess = eng.start_session(list(reqs))
    with pytest.raises(ProcessKilled):
        while not sess.idle:
            mgr.save(sess.snapshot())
            sess.step(4)
    eng2 = _clone(cfg, eng, clock=clock, kv_layout=layout)
    state, seq = mgr.restore_latest()
    assert seq >= 1                   # at least one mid-stream snapshot
    sess2, restored = eng2.restore_session(state)
    sess2.drain()
    _assert_all_match(restored, oracle, len(restored))
    # nothing completed pre-kill with these lengths: full coverage
    assert len(restored) == len(reqs)
    st = sess2.stats_snapshot()
    assert st["failed"] == 0 and st["restores"] == 1


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_kill_all_drill_router_fleet_restore(tmp_path, layout):
    """The fleet-level drill: 2 replicas share one injector, the process
    fault raises through ``run_round`` (NOT handled as a replica fault),
    a rebuilt fleet restores the router snapshot and drains."""
    clock = FakeClock()
    cfg = get_smoke("granite-3-2b")
    scfg = ServeConfig(max_seq=S_MAX, n_slots=2, page_size=PS,
                       kv_layout=layout)
    fc = FaultConfig(max_restarts=2, backoff_s=0.5)
    first = Engine(cfg, scfg, fault_cfg=fc)
    engines = [first, Engine(cfg, scfg, params=first.params, fault_cfg=fc)]
    inj = FaultInjector(fail_at_steps=(("process", 3),))
    for e in engines:
        e.clock = clock
        _tick_decode(e, clock)
        e.fault_injector = inj

    def build_router(es):
        return Router(es, cfg=RouterConfig(n_replicas=2, queue_limit=16),
                      fault_cfg=fc, clock=clock, sleep=clock.advance)

    router = build_router(engines)
    reqs = _reqs(cfg, 6, seed=24, prompt_len=8, max_new=8)
    oracle = _oracle_map(first, reqs)
    for r in reqs:
        router.submit(r)
    mgr = checkpoint.SnapshotManager(str(tmp_path / "rsnaps"))
    with pytest.raises(ProcessKilled):
        while not router.idle:
            mgr.save(router.snapshot())
            router.run_round()
    # the whole fleet is gone; rebuild from surviving params and restore
    engines2 = [Engine(cfg, scfg, params=first.params, fault_cfg=fc)
                for _ in range(2)]
    for e in engines2:
        e.clock = clock
        _tick_decode(e, clock)
    router2 = build_router(engines2)
    state, _ = mgr.restore_latest()
    restored = router2.restore(state)
    while not router2.idle:
        router2.run_round()
    _assert_all_match(restored, oracle, len(reqs))
    st = router2.stats()
    assert st["failed"] == 0
    assert "straggler_decode_steps_per_replica" in st


# ------------------------------------------------ KV-page integrity


def test_page_corruption_detected_quarantined_exact_victim():
    """Silent at-rest corruption: ("page", idx) scribbles over a live
    page after the boundary fingerprints; the next boundary's crc verify
    flags it, quarantines the page, and recompute-preempts exactly the
    owning request — which still finishes token-identical."""
    clock = FakeClock()
    cfg, eng = _engine(clock=clock, kv_integrity=True)
    reqs = _reqs(cfg, 3, seed=25, prompt_len=8, max_new=10)
    oracle = _oracle_map(eng, reqs)
    inj = FaultInjector(fail_at_steps=(("page", 1),))
    sess = eng.start_session(list(reqs), inj)
    sess.drain()
    _assert_all_match(reqs, oracle, len(reqs))
    st = sess.stats_snapshot()
    assert inj.fired == [("page", 1)]
    assert 1 in sess.alloc.quarantined
    assert st["pages_quarantined"] >= 1
    assert st["preemptions"] == 1, "corruption must preempt exactly one"
    assert st["nonfinite_logits"] == 0          # silent path: crc caught it
    assert st["failed"] == 0
    # exact victim: page 1 belonged to the first-admitted request
    victims = [r for r in reqs if r.status.startswith("preempted")]
    assert victims == [reqs[0]]
    # quarantined page is out of circulation for good
    assert 1 not in sess.alloc.free
    assert sess.alloc.owner_of(1) is None


def test_page_nan_screen_blocks_commit():
    """In-window corruption: ("page_nan", idx) poisons a page after the
    boundary verify; the fused loop's non-finite logit screen blocks the
    tainted commit, the page is quarantined, only the victim preempts."""
    clock = FakeClock()
    cfg, eng = _engine(clock=clock, kv_integrity=True)
    reqs = _reqs(cfg, 3, seed=26, prompt_len=8, max_new=10)
    oracle = _oracle_map(eng, reqs)
    inj = FaultInjector(fail_at_steps=(("page_nan", 1),))
    sess = eng.start_session(list(reqs), inj)
    sess.drain()
    _assert_all_match(reqs, oracle, len(reqs))
    st = sess.stats_snapshot()
    assert st["nonfinite_logits"] >= 1          # the screen fired
    assert st["pages_quarantined"] >= 1
    assert st["preemptions"] == 1
    assert st["failed"] == 0
    victims = [r for r in reqs if r.status.startswith("preempted")]
    assert victims == [reqs[0]]


def test_integrity_clean_run_no_false_positives():
    clock = FakeClock()
    cfg, eng = _engine(clock=clock, kv_integrity=True)
    reqs = _reqs(cfg, 4, seed=27)
    oracle = _oracle_map(eng, reqs)
    eng.serve(reqs)
    _assert_all_match(reqs, oracle, len(reqs))
    st = eng.paging_stats
    assert st["preemptions"] == 0 and st["pages_quarantined"] == 0
    assert st["nonfinite_logits"] == 0


def test_quarantine_persists_across_restore():
    """A page retired by the integrity checker stays retired in the
    restored process: the snapshot carries the quarantine set, so pool
    capacity does not silently come back after a crash."""
    clock = FakeClock()
    cfg, eng = _engine(clock=clock, kv_integrity=True)
    reqs = _reqs(cfg, 3, seed=28, prompt_len=8, max_new=12)
    oracle = _oracle_map(eng, reqs)
    inj = FaultInjector(fail_at_steps=(("page", 1),))
    sess = eng.start_session(list(reqs), inj)
    sess.step(6)
    sess.step(1)                     # next boundary: verify + quarantine
    assert 1 in sess.alloc.quarantined
    snap = sess.snapshot()
    eng2 = _clone(cfg, eng, clock=clock, kv_integrity=True)
    sess2, restored = eng2.restore_session(snap)
    assert 1 in sess2.alloc.quarantined
    assert sess2.alloc.usable == sess2.alloc.geom.usable_pages - 1
    sess2.drain()
    _assert_all_match(restored, oracle, len(restored))
    st = sess2.stats_snapshot()
    assert st["pages_quarantined"] >= 1 and st["failed"] == 0
