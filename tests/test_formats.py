"""Property + unit tests for the sparse formats (the paper's core)."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import FORMATS, from_dense, spmm, spmv
from repro.core.analyze import GTX280, peak_model_gflops, row_stats
from repro.core.formats import RgCSR, _hybrid_split_k
from repro.core.ordering import ORDERINGS, descending_ordering, permute_rows
from repro.core.suite import generate, paper_twins

FMT_KWARGS = {
    "rgcsr": dict(group_size=32, slot_pad=4),
    "sliced_ellpack": dict(group_size=32, slot_pad=4),
}


def _rand_sparse(seed, n, m, density):
    rng = np.random.default_rng(seed)
    a = (rng.uniform(size=(n, m)) < density).astype(np.float32)
    a *= rng.uniform(0.5, 1.5, size=(n, m)).astype(np.float32)
    return a


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n=st.integers(1, 96),
       m=st.integers(1, 96), density=st.floats(0.0, 0.3),
       fmt=st.sampled_from(sorted(FORMATS)))
def test_roundtrip_and_spmv(seed, n, m, density, fmt):
    a = _rand_sparse(seed, n, m, density)
    mat = from_dense(a, fmt, **FMT_KWARGS.get(fmt, {}))
    np.testing.assert_allclose(mat.to_dense(), a, atol=1e-6)
    x = np.random.default_rng(seed + 1).standard_normal(m).astype(np.float32)
    y = np.asarray(spmv(mat, jnp.asarray(x)))
    np.testing.assert_allclose(y, a @ x, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), fmt=st.sampled_from(sorted(FORMATS)))
def test_spmm(seed, fmt):
    a = _rand_sparse(seed, 48, 40, 0.1)
    x = np.random.default_rng(seed).standard_normal((40, 7)).astype(np.float32)
    mat = from_dense(a, fmt, **FMT_KWARGS.get(fmt, {}))
    np.testing.assert_allclose(np.asarray(spmm(mat, jnp.asarray(x))), a @ x,
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), g=st.sampled_from([4, 8, 32]))
def test_rgcsr_fill_nonnegative_and_counts(seed, g):
    a = _rand_sparse(seed, 50, 50, 0.08)
    mat = from_dense(a, "rgcsr", group_size=g, slot_pad=4)
    assert mat.nnz == int((a != 0).sum())
    assert mat.stored_elements >= mat.nnz
    assert mat.fill_ratio() >= 0.0
    # group pointers are monotone and multiples of group size
    gp = np.asarray(mat.group_pointers)
    assert (np.diff(gp) >= 0).all()
    assert (np.diff(gp) % g == 0).all()


def test_rgcsr_storage_vs_sliced_ellpack():
    """RgCSR = sliced ELLPACK + rowLengths (the paper's exact delta)."""
    a = _rand_sparse(3, 64, 64, 0.1)
    rg = from_dense(a, "rgcsr", group_size=32, slot_pad=4)
    se = from_dense(a, "sliced_ellpack", group_size=32, slot_pad=4)
    assert rg.storage_bytes() - se.storage_bytes() == 4 * a.shape[0]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_descending_ordering_minimizes_fill(seed, ):
    """Paper §4.4.2: descending row-length ordering is optimal for fill."""
    a = _rand_sparse(seed, 60, 60, 0.07)
    base = from_dense(a, "rgcsr", group_size=16, slot_pad=1)
    desc = from_dense(permute_rows(a, descending_ordering(a)), "rgcsr",
                      group_size=16, slot_pad=1)
    assert desc.stored_elements <= base.stored_elements


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       oname=st.sampled_from(sorted(ORDERINGS)))
def test_ordering_preserves_spmv_up_to_permutation(seed, oname):
    a = _rand_sparse(seed, 40, 40, 0.1)
    perm = ORDERINGS[oname](a)
    x = np.random.default_rng(seed).standard_normal(40).astype(np.float32)
    y_base = np.asarray(spmv(from_dense(a, "rgcsr", group_size=8,
                                        slot_pad=1), jnp.asarray(x)))
    y_perm = np.asarray(spmv(from_dense(permute_rows(a, perm), "rgcsr",
                                        group_size=8, slot_pad=1),
                             jnp.asarray(x)))
    np.testing.assert_allclose(y_perm, y_base[perm], rtol=2e-4, atol=2e-4)


def test_hybrid_split_heuristic():
    # uniform rows → K1 ≈ row length; one dense row → spills to COO
    lens = np.full(5000, 6)
    lens[0] = 4000
    k1 = _hybrid_split_k(lens)
    assert 1 <= k1 <= 10


def test_peak_model_matches_paper_table1():
    assert abs(peak_model_gflops(GTX280, 4, False) - 23.5) < 0.5
    assert abs(peak_model_gflops(GTX280, 8, False) - 14.1) < 0.1
    assert abs(peak_model_gflops(GTX280, 4, True) - 35.25) < 0.1
    assert abs(peak_model_gflops(GTX280, 8, True) - 23.5) < 0.1


def test_paper_twins_signatures():
    twins = paper_twins(scale=64)
    st4 = row_stats(twins["trans4_twin"])
    st_fd = row_stats(twins["fd18_twin"])
    # the pathology: max row ≫ mean (trans4) vs max ≈ mean (fd18)
    assert st4["row_nnz_max"] > 50 * st4["row_nnz_mean"]
    assert st_fd["row_nnz_max"] < 3 * st_fd["row_nnz_mean"]


@pytest.mark.parametrize("family", ["stencil", "fem2d", "powerlaw",
                                    "uniform", "circuit", "blockrand",
                                    "banded"])
def test_suite_families_deterministic(family):
    a = generate(family, 64, seed=5)
    b = generate(family, 64, seed=5)
    np.testing.assert_array_equal(a, b)
    assert (a != 0).sum() > 0
