"""End-to-end behaviour tests for the paper's system.

The chain the paper cares about, exercised through the public API:
matrices → formats → (Pallas-validated) SpMV → SparseLinear inside an LM →
train → checkpoint → serve.
"""
import dataclasses
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.configs.base import SparsityConfig
from repro.core import from_dense, spmv
from repro.core.suite import generate
from repro.kernels import make_plan, rgcsr_spmv
from repro.serve import Engine, ServeConfig
from repro.train import TrainConfig, Trainer
from repro.train.optimizer import OptimizerConfig


def test_spmv_pipeline_end_to_end():
    """suite → RgCSR → plan → Pallas(interpret) == CSR oracle == dense."""
    dense = generate("fem2d", 400, seed=1)
    x = np.random.default_rng(0).standard_normal(
        dense.shape[1]).astype(np.float32)
    rg = from_dense(dense, "rgcsr", group_size=128)
    csr = from_dense(dense, "csr")
    y_kernel = np.asarray(rgcsr_spmv(make_plan(rg), jnp.asarray(x),
                                     interpret=True))
    y_csr = np.asarray(spmv(csr, jnp.asarray(x)))
    np.testing.assert_allclose(y_kernel, y_csr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_kernel, dense @ x, rtol=1e-4, atol=1e-4)


def test_train_then_serve():
    cfg = get_smoke("granite-3-2b")
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=12, ckpt_every=6, ckpt_dir=d, log_every=100,
                         opt=OptimizerConfig(lr=3e-3, warmup_steps=2,
                                             decay_steps=50))
        tr = Trainer(cfg, tc)
        state = tr.init_state(seq_len=32, global_batch=4)
        (params, _), _ = tr.run(state)
    eng = Engine(cfg, ServeConfig(max_seq=64), params=params)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab,
                                                (2, 8)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=4)
    assert out.shape == (2, 4)
    assert (out < cfg.padded_vocab).all()


def test_sparse_ffn_model_trains():
    """The paper's technique as a first-class LM feature: RgCSR FFN weights
    train end-to-end (structure frozen, values learned)."""
    base = get_smoke("granite-3-2b")
    cfg = dataclasses.replace(
        base, sparsity=SparsityConfig(enabled=True, density=0.5,
                                      group_size=128, impl="ref"))
    tc = TrainConfig(steps=10, log_every=100,
                     opt=OptimizerConfig(lr=3e-3, warmup_steps=2,
                                         decay_steps=50))
    tr = Trainer(cfg, tc)
    state = tr.init_state(seq_len=32, global_batch=4)
    state, _ = tr.run(state)
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0]
