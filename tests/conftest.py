"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py (and the
subprocess-isolated distributed tests) force a fake device count."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def deterministic_autotune(monkeypatch):
    """Replace the autotuner's measured timer with a deterministic cost
    model so winner assertions cannot flake under machine load.

    The model mirrors what interpret mode actually pays: a dominant
    per-grid-step cost (Python-level step overhead), a stored-bytes term,
    and an adaptive epilogue penalty (inverse gather + spill segment-sum).
    Each candidate is still *executed* once — plan construction and the
    kernel launch path stay covered; only the µs that rank the winners are
    synthesized.  The memo is cleared on both sides so fake-timed winners
    never leak into (or from) other tests.
    """
    from repro.kernels import autotune

    def fake_time_us(run, plan, cfg, **kwargs):
        run(plan, cfg)
        us = 100.0 * plan.num_steps + 1e-3 * plan.stored_elements
        if plan.ordering == "adaptive":
            us += 20.0 + 5e-3 * plan.n_spilled_elements
        return us

    monkeypatch.setattr(autotune, "time_us", fake_time_us)
    autotune.clear_memo()
    yield
    autotune.clear_memo()


def random_sparse(rng, n, m=None, density=0.05, dtype=np.float32):
    m = m or n
    a = (rng.uniform(size=(n, m)) < density).astype(dtype)
    a *= rng.uniform(0.5, 1.5, size=(n, m)).astype(dtype)
    return a
