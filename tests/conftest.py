"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py (and the
subprocess-isolated distributed tests) force a fake device count."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_sparse(rng, n, m=None, density=0.05, dtype=np.float32):
    m = m or n
    a = (rng.uniform(size=(n, m)) < density).astype(dtype)
    a *= rng.uniform(0.5, 1.5, size=(n, m)).astype(dtype)
    return a
