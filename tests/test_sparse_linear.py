"""SparseLinear: the paper's RgCSR as LM weight storage (DESIGN.md §4)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import SparsityConfig
from repro.configs import get_smoke
from repro.models.ffn import (sparse_linear_apply, sparse_linear_init_mask,
                              sparse_linear_spec)
from repro.models.spec import init_from_spec

KEY = jax.random.PRNGKey(0)


def _build(cfg, d_in, d_out):
    spec = sparse_linear_spec(cfg, d_in, d_out)
    params = init_from_spec(KEY, spec)
    cols, cgrp, cfirst = sparse_linear_init_mask(KEY, cfg, d_in, d_out)
    params["columns2d"] = cols
    params["chunk_group"] = cgrp
    params["chunk_first"] = cfirst
    return params


def _dense_equivalent(params, cfg, d_in, d_out):
    """Reconstruct the dense W (d_out, d_in) from the slot-major storage."""
    g = cfg.sparsity.group_size
    vals = np.asarray(params["values2d"], np.float32)
    cols = np.asarray(params["columns2d"])
    grp = np.repeat(np.asarray(params["chunk_group"]), 8)
    w = np.zeros((int(grp.max() + 1) * g, d_in), np.float32)
    for srow in range(vals.shape[0]):
        rows = grp[srow] * g + np.arange(g)
        np.add.at(w, (rows, cols[srow]), vals[srow])
    return w[:d_out]


def test_sparse_linear_matches_dense_reference():
    cfg = dataclasses.replace(
        get_smoke("granite-3-2b"),
        sparsity=SparsityConfig(enabled=True, density=0.25, group_size=128,
                                impl="ref"))
    d_in, d_out = 96, 200
    params = _build(cfg, d_in, d_out)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (4, d_in)).astype(np.float32))
    y = np.asarray(sparse_linear_apply(params, cfg, x, d_out))
    w = _dense_equivalent(params, cfg, d_in, d_out)
    np.testing.assert_allclose(y, np.asarray(x) @ w.T, rtol=2e-4, atol=2e-4)


def test_sparse_linear_kernel_matches_ref():
    cfg = dataclasses.replace(
        get_smoke("granite-3-2b"),
        sparsity=SparsityConfig(enabled=True, density=0.25, group_size=128,
                                impl="ref"))
    cfg_k = dataclasses.replace(
        cfg, sparsity=dataclasses.replace(cfg.sparsity, impl="kernel"))
    d_in, d_out = 64, 140
    params = _build(cfg, d_in, d_out)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (3, d_in)).astype(np.float32))
    y_ref = np.asarray(sparse_linear_apply(params, cfg, x, d_out))
    y_k = np.asarray(sparse_linear_apply(params, cfg_k, x, d_out))
    np.testing.assert_allclose(y_k, y_ref, rtol=1e-4, atol=1e-4)


def test_sparse_linear_is_trainable():
    cfg = dataclasses.replace(
        get_smoke("granite-3-2b"),
        sparsity=SparsityConfig(enabled=True, density=0.5, group_size=128,
                                impl="ref"))
    d = 64
    params = _build(cfg, d, d)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        (8, d)).astype(np.float32))
    target = jnp.asarray(np.random.default_rng(4).standard_normal(
        (8, d)).astype(np.float32))

    def loss(values):
        p = dict(params, values2d=values)
        y = sparse_linear_apply(p, cfg, x, d)
        return jnp.mean((y - target) ** 2)

    v = params["values2d"]
    l0 = float(loss(v))
    for _ in range(50):
        g = jax.grad(loss)(v)
        v = v - 0.05 * g
    assert float(loss(v)) < 0.7 * l0
