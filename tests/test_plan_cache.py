"""Plan construction, chunk coarsening, PlanCache semantics, autotuner.

The coarsened kernel must be bit-identical (up to fp reassociation) to the
jnp oracle at every ``chunks_per_step``; the cache must hit on repeat
lookups, miss across configs, and evict with its matrix.
"""
import gc

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import from_dense
from repro.core.spmv import spmv
from repro.core.suite import generate
from repro.kernels import autotune
from repro.kernels.ops import (PLAN_CACHE, PlanCache, get_plan, make_plan,
                               plan_from_params, rgcsr_spmv, rgcsr_spmm,
                               warm_plans_from_params)

CPS_ALL = (1, 2, 4, 8)


def _rand(seed, n, m, density):
    rng = np.random.default_rng(seed)
    a = (rng.uniform(size=(n, m)) < density).astype(np.float32)
    a *= rng.uniform(0.5, 1.5, size=(n, m)).astype(np.float32)
    return a


# ---------------------------------------------------------------- plan shape


@pytest.mark.parametrize("cps", CPS_ALL)
def test_plan_empty_matrix(cps):
    a = np.zeros((0, 40), np.float32)
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat, chunks_per_step=cps)
    assert plan.num_steps >= 1                     # one padded group
    y = np.asarray(rgcsr_spmv(plan, jnp.zeros(40), interpret=True))
    assert y.shape == (0,)


@pytest.mark.parametrize("cps", CPS_ALL)
def test_plan_single_group(cps):
    a = _rand(0, 100, 80, 0.1)                     # 100 rows < one 128-group
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat, chunks_per_step=cps)
    assert plan.n_groups == 1
    assert plan.stored_slots % (8 * cps) == 0
    x = np.random.default_rng(1).standard_normal(80).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cps", CPS_ALL)
def test_plan_ragged_last_group(cps):
    a = _rand(1, 300, 120, 0.08)                   # 300 = 2 full + 44 ragged
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat, chunks_per_step=cps)
    assert plan.n_groups == 3
    x = np.random.default_rng(2).standard_normal(120).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


def test_cps_exceeds_chunks_in_group_masking():
    """Groups with a single 8-slot chunk padded up to an 8-chunk step: the
    padding rows are exact zeros (ghost column 0) — masked accumulation."""
    a = _rand(2, 256, 64, 0.03)                    # sparse: K_g = 8 per group
    mat = from_dense(a, "rgcsr", group_size=128)
    base = make_plan(mat, chunks_per_step=1)
    assert base.stored_slots == 16                 # 2 groups x 8 slots
    plan = make_plan(mat, chunks_per_step=8)
    assert plan.stored_slots == 128                # padded to 64 slots each
    assert plan.num_steps == 2                     # one coarse step per group
    x = np.random.default_rng(3).standard_normal(64).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


def test_plan_rejects_bad_chunks_per_step():
    mat = from_dense(_rand(3, 64, 64, 0.1), "rgcsr", group_size=128)
    with pytest.raises(ValueError):
        make_plan(mat, chunks_per_step=3)


# ------------------------------------------------- oracle equivalence sweep


@pytest.mark.parametrize("family", ["stencil", "uniform", "circuit",
                                    "powerlaw", "banded"])
@pytest.mark.parametrize("cps", CPS_ALL)
def test_coarsened_matches_oracle_on_corpus(family, cps):
    a = generate(family, 256, seed=0)
    mat = from_dense(a, "rgcsr", group_size=128)
    x = np.random.default_rng(4).standard_normal(a.shape[1]).astype(np.float32)
    ref = np.asarray(spmv(mat, jnp.asarray(x), impl="ref"))
    plan = make_plan(mat, chunks_per_step=cps)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_spmv_x_tiling_matches_untiled():
    a = _rand(5, 130, 1000, 0.02)
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat, chunks_per_step=2)
    x = np.random.default_rng(6).standard_normal(1000).astype(np.float32)
    whole = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    tiled = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True,
                                  x_tile=128))
    np.testing.assert_allclose(tiled, whole, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(tiled, a @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cps", (1, 4))
def test_coarsened_spmm(cps):
    a = _rand(7, 150, 140, 0.07)
    mat = from_dense(a, "rgcsr", group_size=128)
    plan = make_plan(mat, chunks_per_step=cps)
    x = np.random.default_rng(8).standard_normal((140, 9)).astype(np.float32)
    got = np.asarray(rgcsr_spmm(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- cache


def test_plan_cache_hit_miss_semantics():
    cache = PlanCache(maxsize=8)
    mat = from_dense(_rand(9, 64, 64, 0.1), "rgcsr", group_size=128)
    p1 = cache.get(mat)
    assert cache.stats() == {"hits": 0, "misses": 1, "entries": 1}
    p2 = cache.get(mat)
    assert p2 is p1                                # same object, no rebuild
    assert cache.stats()["hits"] == 1
    p4 = cache.get(mat, chunks_per_step=4)        # different config → miss
    assert p4 is not p1
    assert cache.stats() == {"hits": 1, "misses": 2, "entries": 2}
    other = from_dense(_rand(10, 64, 64, 0.1), "rgcsr", group_size=128)
    cache.get(other)                               # different matrix → miss
    assert cache.stats()["misses"] == 3


def test_plan_cache_evicts_on_gc():
    cache = PlanCache(maxsize=8)
    mat = from_dense(_rand(11, 64, 64, 0.1), "rgcsr", group_size=128)
    cache.get(mat)
    cache.get(mat, chunks_per_step=2)
    assert len(cache) == 2
    del mat
    gc.collect()
    assert len(cache) == 0


def test_plan_cache_lru_bound():
    cache = PlanCache(maxsize=2)
    mats = [from_dense(_rand(20 + i, 64, 64, 0.1), "rgcsr", group_size=128)
            for i in range(4)]
    for m in mats:
        cache.get(m)
    assert len(cache) == 2                         # oldest two evicted


def test_global_get_plan_and_spmv_kernel_dispatch():
    mat = from_dense(_rand(12, 96, 96, 0.08), "rgcsr", group_size=128)
    x = np.random.default_rng(13).standard_normal(96).astype(np.float32)
    before = PLAN_CACHE.stats()
    y_k = np.asarray(spmv(mat, jnp.asarray(x), impl="kernel"))
    y_r = np.asarray(spmv(mat, jnp.asarray(x), impl="ref"))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
    spmv(mat, jnp.asarray(x), impl="kernel")      # second call: cache hit
    after = PLAN_CACHE.stats()
    assert after["misses"] == before["misses"] + 1
    assert after["hits"] >= before["hits"] + 1
    assert get_plan(mat) is get_plan(mat)


# ----------------------------------------------------- param plans / warmup


def _sparse_params(seed, n_groups=2, g=128, k=16, d_in=64):
    rng = np.random.default_rng(seed)
    s = n_groups * k
    cols = np.stack([np.sort(rng.choice(d_in, size=k, replace=False))
                     for _ in range(n_groups * g)]).astype(np.int32)
    cols = cols.reshape(n_groups, g, k).transpose(0, 2, 1).reshape(s, g)
    return {
        "values2d": jnp.asarray(rng.standard_normal((s, g)).astype(np.float32)),
        "columns2d": jnp.asarray(cols),
        "chunk_group": jnp.asarray(
            np.repeat(np.arange(n_groups, dtype=np.int32), k // 8)),
        "chunk_first": jnp.asarray(np.tile(
            np.eye(1, k // 8, dtype=np.int32)[0], n_groups)),
    }


def test_plan_from_params_memoizes_on_identity():
    params = _sparse_params(0)
    p1 = plan_from_params(params, jnp.float32, d_out=200, d_in=64,
                          group_size=128)
    p2 = plan_from_params(params, jnp.float32, d_out=200, d_in=64,
                          group_size=128)
    assert p2 is p1
    # new values (a training step) invalidates the memo
    params2 = dict(params, values2d=params["values2d"] + 1.0)
    p3 = plan_from_params(params2, jnp.float32, d_out=200, d_in=64,
                          group_size=128)
    assert p3 is not p1


def test_warm_plans_from_params_walks_tree():
    tree = {"layer0": {"ffn": {"w_out": _sparse_params(1)}},
            "layer1": {"dense": {"w": jnp.zeros((4, 4))}}}
    assert warm_plans_from_params(tree) == 1


# ------------------------------------------------------------- autotune


def test_autotune_picks_valid_config_and_memoizes():
    autotune.clear_memo()
    a = generate("uniform", 256, seed=0)
    res = autotune.autotune_spmv(a, repeats=1)
    assert res.config.chunks_per_step in CPS_ALL
    assert res.config.group_size in autotune.DEFAULT_GROUP_SIZES
    assert res.us_per_call > 0 and len(res.timings) >= 2
    assert not res.from_memo
    res2 = autotune.autotune_spmv(a, repeats=1)
    assert res2.from_memo and res2.config == res.config
    # same signature bucket → winner reuse without re-timing
    res3 = autotune.autotune_spmv(generate("uniform", 256, seed=1), repeats=1)
    assert res3.from_memo


def test_autotune_prefers_coarsening_on_chunky_matrix(deterministic_autotune):
    """Interpret mode pays per grid step, so a matrix with many chunks per
    group must tune to chunks_per_step > 1 (the acceptance criterion's
    'selects coarsening on at least one corpus matrix').  Restricted to the
    block-ordering grid: this asserts the *coarsening* axis specifically.
    The winner ranking runs on the deterministic fake timer (conftest) —
    real measured medians made this assertion flake under parallel load."""
    a = generate("banded", 256, seed=0)            # ~4 chunks per group
    res = autotune.autotune_spmv(a, repeats=1,
                                 candidates=autotune.candidate_configs())
    assert res.config.chunks_per_step > 1
    assert res.speedup >= 1.0


def test_tuned_plan_roundtrip():
    autotune.clear_memo()
    a = generate("circuit", 256, seed=0)
    plan, res = autotune.tuned_plan(a, repeats=1)
    assert plan.chunks_per_step == res.config.chunks_per_step
    x = np.random.default_rng(0).standard_normal(a.shape[1]).astype(np.float32)
    got = np.asarray(rgcsr_spmv(plan, jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, a @ x, rtol=1e-4, atol=1e-4)


def test_tuned_plan_survives_gc_and_reuses():
    """The winning matrix is retained, so the PLAN_CACHE entry must not be
    evicted at return and repeat calls must hand back the same plan."""
    autotune.clear_memo()
    a = generate("banded", 256, seed=3)
    plan1, _ = autotune.tuned_plan(a, repeats=1)
    gc.collect()                                   # would fire the finalizer
    plan2, res2 = autotune.tuned_plan(a, repeats=1)
    assert plan2 is plan1
    assert res2.from_memo


def test_spmv_impl_validated_for_all_formats():
    csr = from_dense(_rand(30, 32, 32, 0.1), "csr")
    x = jnp.zeros(32)
    with pytest.raises(ValueError, match="unknown impl"):
        spmv(csr, x, impl="kernal")                # typo'd, non-RgCSR input


def test_auto_dispatch_skips_kernel_incompatible(monkeypatch):
    """impl='auto' on TPU must leave small modeled group sizes (the format
    tests sweep g ∈ {4,8,32}) on the oracle instead of crashing in
    make_plan."""
    import importlib
    spmv_mod = importlib.import_module("repro.core.spmv")
    monkeypatch.setattr(spmv_mod.jax, "default_backend", lambda: "tpu")
    small = from_dense(_rand(31, 40, 40, 0.1), "rgcsr", group_size=32,
                       slot_pad=4)
    assert not spmv_mod._use_kernel(small, "auto")
    ok = from_dense(_rand(32, 40, 40, 0.1), "rgcsr", group_size=128)
    assert spmv_mod._use_kernel(ok, "auto")


def test_autotune_restricted_candidates_not_shadowed():
    """A candidate-restricted search must never be answered from the memo
    of a wider search: its winner must come from its own candidate set."""
    autotune.clear_memo()
    a = generate("uniform", 256, seed=0)
    autotune.autotune_spmv(a, repeats=1)           # full-grid winner memoized
    cands = [autotune.TuneConfig(1, 128), autotune.TuneConfig(2, 128)]
    res = autotune.autotune_spmv(a, repeats=1, candidates=cands)
    assert not res.from_memo
    assert res.config in cands
