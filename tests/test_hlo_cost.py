"""Validation of the loop-aware HLO cost model (launch/hlo_cost.py).

The key check: XLA's own cost_analysis counts while-loop bodies once; ours
multiplies by trip count and matches hand-derived flops exactly on plain,
scanned, nested-scan and SPMD-sharded modules.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_plain_matmul_matches_xla():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _compile(lambda x, w: jnp.tanh(x @ w), x, w)
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    mine = analyze_hlo(c.as_text(), 1)
    assert mine.flops == ca["flops"] == 2 * 128 * 256 * 512


def test_scan_flops_multiplied_by_trip_count():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = _compile(f, x, w)
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    mine = analyze_hlo(c.as_text(), 1)
    expected = 10 * 2 * 128 * 256 * 256
    assert mine.flops == expected
    assert ca["flops"] < expected  # XLA's known single-visit undercount
    assert 10 in mine.loops.values()


def test_nested_scan():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def h(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = _compile(h, x, w)
    mine = analyze_hlo(c.as_text(), 1)
    assert mine.flops == 12 * 2 * 128 * 256 * 256


def test_collectives_counted_inside_loops():
    """A psum inside a scan must be multiplied by the trip count."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_cost import analyze_hlo
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        def f(ws, x):
            def body(c, w):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, ws)
            return y.sum()
        ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        with mesh:
            fn = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P(None, None, "model")),
                NamedSharding(mesh, P("data", None))))
            c = fn.lower(ws, x).compile()
        res = analyze_hlo(c.as_text(), 8)
        expected = 5 * 2 * 4 * 64 * 16
        assert res.flops == expected, (res.flops, expected)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=_env())
    assert "OK" in out.stdout, out.stderr[-2000:]


def _env():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    env.pop("XLA_FLAGS", None)
    return env
