"""Per-architecture smoke tests (reduced same-family configs) + decode
consistency.  Required by the assignment: one forward/train step on CPU per
arch asserting output shapes + no NaNs."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke, concrete_inputs
from repro.models import LanguageModel

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke(arch)
    model = LanguageModel(cfg)
    params = model.init(KEY)
    seq = 48 if cfg.family == "vlm" else 32
    batch = concrete_inputs(cfg, batch=2, seq=seq, kind="train")
    logits, _, _ = model.forward(params, batch)
    n_text = batch["tokens"].shape[1] + (cfg.frontend_tokens
                                         if cfg.family == "vlm" else 0)
    assert logits.shape == (2, n_text, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < 1.2 * np.log(cfg.padded_vocab) + 2.0


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_smoke_train_step(arch):
    from repro.launch.steps import make_train_step
    from repro.train.optimizer import OptimizerConfig

    cfg = get_smoke(arch)
    model = LanguageModel(cfg)
    params = model.init(KEY)
    step, opt_init = make_train_step(model, OptimizerConfig(lr=1e-3),
                                     microbatches=1)
    opt_state = opt_init(params)
    seq = 48 if cfg.family == "vlm" else 32
    batch = concrete_inputs(cfg, batch=2, seq=seq, kind="train")
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(metrics["loss"])
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda acc, t: acc + float(jnp.sum(jnp.abs(t[0] - t[1]))),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, new_params),
        0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_decode_matches_forward(arch):
    cfg = get_smoke(arch)
    model = LanguageModel(cfg)
    params = model.init(KEY)
    seq = 48 if cfg.family == "vlm" else 32
    batch = concrete_inputs(cfg, batch=2, seq=seq, kind="train")
    logits_full, _, _ = model.forward(params, batch)
    ntok = batch["tokens"].shape[1]
    pre = dict(batch)
    pre.pop("labels", None)
    pre["tokens"] = pre["tokens"][:, : ntok - 1]
    _, caches = model.prefill(params, pre, s_max=seq + 4)
    logits_dec, _ = model.decode_step(params, caches,
                                      batch["tokens"][:, ntok - 1: ntok])
    a = np.asarray(logits_full[:, -1, :], np.float32)
    b = np.asarray(logits_dec[:, 0, :], np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 2e-2, rel


def test_exact_configs_match_assignment():
    """The full configs carry the published hyperparameters."""
    spec = {
        "deepseek-v3-671b": (61, 7168, 128, 128, 129_280),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 49_155),
        "mamba2-780m": (48, 1536, 1, 1, 50_280),
        "recurrentgemma-9b": (38, 4096, 16, 1, 256_000),
        "granite-3-2b": (40, 2048, 32, 8, 49_155),
        "nemotron-4-15b": (32, 6144, 48, 8, 256_000),
        "qwen1.5-32b": (64, 5120, 40, 40, 152_064),
        "minicpm3-4b": (62, 2560, 40, 40, 73_448),
        "pixtral-12b": (40, 5120, 32, 8, 131_072),
        "seamless-m4t-medium": (12, 1024, 16, 16, 256_206),
    }
    for arch, (L, d, h, kv, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.vocab == v, arch
        # pattern consistency
        assert cfg.n_layers == len(cfg.prefix_pattern) + \
            cfg.pattern_repeats * len(cfg.layer_pattern), arch


def test_moe_dispatch_modes_agree():
    """GShard einsum dispatch vs sort/scatter dispatch: same math."""
    cfg = get_smoke("granite-moe-1b-a400m")
    model_e = LanguageModel(dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="einsum",
                                     capacity_factor=8.0)))
    model_s = LanguageModel(dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="scatter",
                                     capacity_factor=8.0)))
    params = model_e.init(KEY)
    batch = concrete_inputs(cfg, batch=2, seq=16, kind="train")
    le, _, _ = model_e.forward(params, batch)
    ls, _, _ = model_s.forward(params, batch)
    a, b = np.asarray(le, np.float32), np.asarray(ls, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 2e-2, rel


def test_flash_attention_exact():
    from repro.models.attention import MaskInfo, _flash_attend, attend
    b, s, hq, hkv, d = 2, 300, 8, 2, 16
    q = jax.random.normal(KEY, (b, s, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, hkv, d))
    mi = MaskInfo(causal=True, window=64)
    direct = attend(q, k, v, mask_info=mi)
    qg = q.reshape(b, s, hkv, hq // hkv, d)
    flash = _flash_attend(qg, k, v, mi, d ** -0.5, q_chunk=32,
                          k_chunk=64).reshape(b, s, hq, d)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(flash),
                               atol=2e-5)


def test_int8_kv_cache_close_to_bf16():
    cfg = dataclasses.replace(get_smoke("granite-3-2b"),
                              kv_cache_dtype="int8")
    cfg_ref = get_smoke("granite-3-2b")
    m8, mr = LanguageModel(cfg), LanguageModel(cfg_ref)
    params = mr.init(KEY)
    batch = concrete_inputs(cfg_ref, batch=2, seq=24, kind="prefill")
    l8, c8 = m8.prefill(params, batch, s_max=32)
    lr, cr = mr.prefill(params, batch, s_max=32)
    a, b = np.asarray(l8, np.float32), np.asarray(lr, np.float32)
    rel = np.abs(a - b).max() / (np.abs(b).max() + 1e-9)
    assert rel < 0.1, rel
