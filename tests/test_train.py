"""Training substrate: optimizer math, schedules, checkpoints, fault drill."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.train.checkpoint import CheckpointManager, latest_step, restore, \
    save
from repro.train.data import DataConfig, make_batch
from repro.train.fault import FaultConfig, FaultInjector, Watchdog
from repro.train.optimizer import OptimizerConfig, clip_by_global_norm, \
    global_norm, make_optimizer
from repro.train.trainer import TrainConfig, Trainer


# --------------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    init, update = make_optimizer(OptimizerConfig(
        name="adamw", lr=0.1, weight_decay=0.0, warmup_steps=0,
        decay_steps=10_000, schedule="constant"))
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adafactor_decreases_quadratic_matrix():
    init, update = make_optimizer(OptimizerConfig(
        name="adafactor", lr=0.1, weight_decay=0.0, warmup_steps=0,
        schedule="constant"))
    params = {"w": jnp.ones((8, 8)) * 3.0}
    state = init(params)
    assert "vr" in state["stats"]["w"]          # factored for 2-D
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_weight_decay_mask_skips_1d():
    cfgo = OptimizerConfig(name="adamw", lr=0.0, weight_decay=1.0,
                           warmup_steps=0, schedule="constant")
    init, update = make_optimizer(cfgo)
    params = {"kernel": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    state = init(params)
    zero_grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    new, _ = update(zero_grads, state, params)
    # lr = 0 → nothing moves regardless; use lr>0 to see decay effect
    cfgo2 = OptimizerConfig(name="adamw", lr=0.1, weight_decay=1.0,
                            warmup_steps=0, schedule="constant")
    init2, update2 = make_optimizer(cfgo2)
    new2, _ = update2(zero_grads, init2(params), params)
    assert float(new2["kernel"][0, 0]) < 1.0      # decayed
    assert float(new2["scale"][0]) == 1.0         # masked (1-D)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_schedule_warmup_and_decay():
    from repro.train.optimizer import warmup_cosine
    cfgo = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=100)
    fn = warmup_cosine(cfgo)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert abs(float(fn(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(fn(jnp.asarray(100))) < 1e-6


# --------------------------------------------------------------------- data
def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=8)
    b1 = make_batch(cfg, 3)
    b2 = make_batch(cfg, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    h0 = make_batch(cfg, 3, host_id=0, n_hosts=2)
    h1 = make_batch(cfg, 3, host_id=1, n_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 97).all()
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_latest():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray(7, np.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save(d, 5, tree)
        save(d, 9, jax.tree_util.tree_map(lambda x: x + 1, tree))
        assert latest_step(d) == 9
        restored, manifest = restore(d, tree)
        np.testing.assert_array_equal(restored["a"], tree["a"] + 1)
        restored5, _ = restore(d, tree, step=5)
        np.testing.assert_array_equal(restored5["a"], tree["a"])


def test_checkpoint_structure_mismatch_detected():
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, {"a": np.zeros(3)})
        with pytest.raises(ValueError):
            restore(d, {"b": np.zeros(3)})


def test_checkpoint_manager_retention_and_async():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_write=True)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": np.full(4, s, np.float32)})
        mgr.wait()
        steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                       if p.startswith("step_"))
        assert steps == [3, 4]


# -------------------------------------------------------------------- fault
def test_watchdog_flags_stragglers():
    wd = Watchdog(FaultConfig(min_samples=3, straggler_factor=2.0))
    flags = [wd.observe(i, 0.1) for i in range(6)]
    assert not any(flags)
    assert wd.observe(6, 0.5) is True


def test_trainer_loss_decreases_and_survives_fault():
    cfg = get_smoke("granite-3-2b")
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=24, log_every=100, ckpt_every=8, ckpt_dir=d,
                         opt=OptimizerConfig(lr=3e-3, warmup_steps=4,
                                             decay_steps=100),
                         microbatches=2)
        tr = Trainer(cfg, tc, fault_injector=FaultInjector(
            fail_at_steps=[13]))
        state = tr.init_state(seq_len=32, global_batch=8)
        state, step = tr.run(state)
        losses = [h["loss"] for h in tr.history]
        assert losses[-1] < losses[0] - 0.3
        # replayed steps after the fault saw identical data (determinism):
        by_step = {}
        replay_match = True
        for h in tr.history:
            if h["step"] in by_step:
                replay_match &= abs(by_step[h["step"]] - h["loss"]) < 5e-2
            by_step[h["step"]] = h["loss"]
        assert replay_match
