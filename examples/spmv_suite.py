"""The paper's own experiment, miniaturized: run every format over a corpus
slice and print the Table-5-style comparison.

Run:  PYTHONPATH=src python examples/spmv_suite.py [--full]
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import from_dense
from repro.core.ordering import descending_ordering, permute_rows
from repro.core.suite import corpus, paper_twins
from benchmarks.common import spmv_gflops_measured


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    specs = corpus(small_n=(256, 1024), large_n=(2048,), seeds=(0,)) \
        if args.full else corpus(small_n=(256,), large_n=(1024,), seeds=(0,))
    print(f"{'matrix':24s} {'csr':>8s} {'hybrid':>8s} {'rgcsr':>8s} "
          f"{'rg fill%':>9s}  winner")
    wins = {"csr": 0, "hybrid": 0, "rgcsr": 0}
    for spec in specs:
        dense = spec.build()
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            dense.shape[1]).astype(np.float32))
        row = {}
        for fmt, kw in (("csr", {}), ("hybrid", {}),
                        ("rgcsr", {"group_size": 128})):
            mat = from_dense(dense, fmt, **kw)
            gf, _ = spmv_gflops_measured(mat, x, repeats=3)
            row[fmt] = gf
            if fmt == "rgcsr":
                fill = mat.fill_ratio()
        winner = max(row, key=row.get)
        wins[winner] += 1
        print(f"{spec.name:24s} {row['csr']:8.3f} {row['hybrid']:8.3f} "
              f"{row['rgcsr']:8.3f} {fill:8.1f}%  {winner}")

    print("\nwin counts:", wins)
    print("\n=== the pathological twins (paper Table 6) + descending fix ===")
    for name, dense in paper_twins(scale=32).items():
        rg = from_dense(dense, "rgcsr", group_size=128)
        rg_desc = from_dense(permute_rows(dense, descending_ordering(dense)),
                             "rgcsr", group_size=128)
        print(f"{name:20s} fill {rg.fill_ratio():9.1f}% -> descending "
              f"{rg_desc.fill_ratio():9.1f}%")


if __name__ == "__main__":
    main()
