"""End-to-end driver: train a ~100M-param GQA LM for a few hundred steps.

Demonstrates the full production substrate on CPU: deterministic data,
AdamW + cosine schedule, microbatch gradient accumulation, async
checkpoints, the step-time watchdog, and (optionally) the paper's RgCSR
sparse-FFN feature (--sparse).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--sparse]
      (--tiny for a seconds-scale demo)
"""
import argparse
import dataclasses
import logging
import tempfile

from repro.configs.base import ModelConfig, SparsityConfig
from repro.models import LanguageModel
from repro.train import TrainConfig, Trainer
from repro.train.optimizer import OptimizerConfig

logging.basicConfig(level=logging.INFO,
                    format="%(asctime)s %(name)s %(message)s")


def model_100m(tiny: bool = False) -> ModelConfig:
    if tiny:
        return ModelConfig(
            name="demo-tiny", family="dense", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
            layer_pattern=("attn",))
    # ~105M params: 12L × 768 (GPT-2-small-like, GQA kv=4, SwiGLU)
    return ModelConfig(
        name="demo-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048, vocab=32_000,
        layer_pattern=("attn",))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--sparse", action="store_true",
                    help="store FFN down-projections in RgCSR (the paper's "
                         "technique as an LM feature)")
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    cfg = model_100m(args.tiny)
    if args.sparse:
        cfg = dataclasses.replace(cfg, sparsity=SparsityConfig(
            enabled=True, density=0.25, group_size=128, impl="ref"))
    model = LanguageModel(cfg)
    print(f"model: {cfg.name}  params={model.n_params():,}  "
          f"sparse_ffn={args.sparse}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainConfig(
            steps=args.steps if not args.tiny else 30,
            microbatches=2,
            log_every=10,
            ckpt_every=100,
            ckpt_dir=ckpt_dir,
            opt=OptimizerConfig(lr=3e-4 if not args.tiny else 3e-3,
                                warmup_steps=20, decay_steps=args.steps,
                                weight_decay=0.1),
        )
        trainer = Trainer(cfg, tc)
        state = trainer.init_state(seq_len=args.seq if not args.tiny else 32,
                                   global_batch=args.batch)
        state, step = trainer.run(state)

    first = trainer.history[0]["loss"]
    last = trainer.history[-1]["loss"]
    print(f"\ntrained {step} steps: loss {first:.3f} -> {last:.3f}")
    ewma = trainer.watchdog.ewma or 0.0
    print(f"step-time EWMA {ewma:.3f}s; stragglers flagged: "
          f"{len(trainer.watchdog.events)}")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
