"""Serving example: mixed-length request queue through the paged engine.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np

from repro.configs import get_smoke
from repro.serve import Engine, Request, ServeConfig


def main():
    cfg = get_smoke("granite-3-2b")
    eng = Engine(cfg, ServeConfig(max_seq=128, n_slots=4, temperature=0.0))
    rng = np.random.default_rng(0)

    print("=== batch generate ===")
    prompts = rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=16)
    dt = time.time() - t0
    print(f"generated {out.size} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s on CPU)")

    print("\n=== continuous mixed-length batching over 10 requests ===")
    reqs = [Request(tokens=rng.integers(0, cfg.vocab,
                                        (8 + 2 * i,)).astype(np.int32),
                    max_new_tokens=6 + i % 5) for i in range(10)]
    t0 = time.time()
    done = eng.serve(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s; "
          f"all done: {all(r.done for r in done)}")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt_len={len(r.tokens)} -> {r.out}")
    ps = eng.paging_stats
    print(f"paging: peak {ps['page_high_water']} pages in use "
          f"({ps['paged_peak_tokens']} tokens vs "
          f"{ps['dense_equiv_tokens']} dense), fragmentation at peak "
          f"{ps['frag_at_high_water']:.3f}")


if __name__ == "__main__":
    main()
