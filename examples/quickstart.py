"""Quickstart: the paper's format end-to-end in five minutes.

1. Build a sparse matrix from the synthetic corpus.
2. Store it in every format the paper discusses; compare fill/bytes.
3. Run SpMV through the Pallas RgCSR kernel (interpret mode on CPU) and
   check it against the CSR oracle.
4. Reproduce the paper's Table 1 peak model for GTX280 and TPU v5e.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import FORMATS, from_dense, spmv
from repro.core.analyze import GTX280, TPU_V5E, format_report, \
    peak_model_gflops
from repro.core.suite import generate
from repro.kernels import make_plan, rgcsr_spmv


def main():
    print("=== 1. build a matrix (2-D FEM Laplacian, 1,024 unknowns) ===")
    dense = generate("fem2d", 1024, seed=0)
    nnz = int((dense != 0).sum())
    print(f"shape={dense.shape} nnz={nnz} "
          f"density={100 * nnz / dense.size:.2f}%")

    print("\n=== 2. every format from the paper ===")
    kw = {"rgcsr": dict(group_size=128), "sliced_ellpack": dict(group_size=128)}
    for name in FORMATS:
        mat = from_dense(dense, name, **kw.get(name, {}))
        rep = format_report(mat)
        print(f"{name:16s} stored={rep['stored_elements']:8d} "
              f"fill={rep['artificial_zeros_pct']:7.1f}% "
              f"bytes={rep['storage_bytes']:9d} "
              f"modeled_gflops(v5e)={rep['gflops_cached']:.1f}")

    print("\n=== 3. Pallas RgCSR SpMV (interpret mode) vs oracle ===")
    x = np.random.default_rng(0).standard_normal(
        dense.shape[1]).astype(np.float32)
    rg = from_dense(dense, "rgcsr", group_size=128)
    y_kernel = np.asarray(rgcsr_spmv(make_plan(rg), jnp.asarray(x)))
    y_ref = np.asarray(spmv(from_dense(dense, "csr"), jnp.asarray(x)))
    err = np.abs(y_kernel - y_ref).max()
    print(f"max |kernel - oracle| = {err:.2e}")
    assert err < 1e-4

    print("\n=== 4. paper Table 1: peak SpMV model ===")
    for hw, pair in ((GTX280, (("single", 4), ("double", 8))),
                     (TPU_V5E, (("bf16", 2), ("fp32", 4)))):
        for prec, nbytes in pair:
            un = peak_model_gflops(hw, nbytes, False)
            ca = peak_model_gflops(hw, nbytes, True)
            print(f"{hw.name:8s} {prec:6s}: {un:7.1f} GFLOPS uncached, "
                  f"{ca:7.1f} cached")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
