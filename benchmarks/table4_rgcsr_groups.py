"""Table 4 — RgCSR group-size sweep: artificial zeros + throughput.

Paper claims reproduced:
* fill ("artificial zeros") grows with group size — avg 105% at G=32 →
  304% at G=256 on the complete set; pathological max ≫ 1000%,
* throughput peaks at an intermediate group size (G=128 on GTX280 —
  occupancy vs fill trade-off; on TPU the trade is pipeline utilization vs
  DMA padding, same shape of curve, DESIGN.md §2).

Group sizes: the paper's {32, 64} are modeled only (below the 128-lane TPU
minimum); {128, 256, 512} are both measured (jnp schedule) and modeled.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import LARGE_BOUNDARY, bench_corpus, emit, \
    spmv_gflops_measured
from repro.core import from_dense
from repro.core.analyze import modeled_gflops

GROUPS_MODEL_ONLY = (32, 64)
GROUPS_MEASURED = (128, 256, 512)


def run(small_only: bool = False):
    print("# table4: RgCSR group sweep — name,us_per_call,"
          "derived(fill%|GFLOPS)")
    stats = {g: [] for g in GROUPS_MODEL_ONLY + GROUPS_MEASURED}
    for spec in bench_corpus(small_only):
        dense = spec.build()
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            dense.shape[1]).astype(np.float32))
        for g in GROUPS_MODEL_ONLY + GROUPS_MEASURED:
            mat = from_dense(dense, "rgcsr", group_size=g,
                             slot_pad=8 if g >= 128 else 1)
            fill = mat.fill_ratio()
            model = modeled_gflops(mat)
            rec = {"name": spec.name, "n": spec.n, "fill": fill,
                   "model": model}
            if g in GROUPS_MEASURED:
                gf, us = spmv_gflops_measured(mat, x)
                rec["meas"] = gf
                emit(f"table4/{spec.name}/g{g}", us,
                     f"fill={fill:.1f}%|meas={gf:.3f}|model={model:.2f}")
            else:
                emit(f"table4/{spec.name}/g{g}", 0.0,
                     f"fill={fill:.1f}%|model={model:.2f}")
            stats[g].append(rec)

    for g, recs in stats.items():
        for subset, sel in (("complete", recs),
                            ("small", [r for r in recs
                                       if r["n"] < LARGE_BOUNDARY]),
                            ("large", [r for r in recs
                                       if r["n"] >= LARGE_BOUNDARY])):
            if not sel:
                continue
            fills = np.array([r["fill"] for r in sel])
            emit(f"table4/g{g}/{subset}/fill_avg", 0.0, f"{fills.mean():.1f}%")
            emit(f"table4/g{g}/{subset}/fill_max", 0.0, f"{fills.max():.1f}%")
            models = np.array([r["model"] for r in sel])
            emit(f"table4/g{g}/{subset}/model_gflops_avg", 0.0,
                 f"{models.mean():.2f}")
            if "meas" in sel[0]:
                meas = np.array([r["meas"] for r in sel])
                emit(f"table4/g{g}/{subset}/meas_gflops_avg", 0.0,
                     f"{meas.mean():.3f}")
    return stats


if __name__ == "__main__":
    run()
